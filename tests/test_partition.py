"""microservice.partition invariants across every registered config."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.microservice.partition import decompose, to_application


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("n_stages", [1, 2, 4])
def test_decompose_invariants(arch, n_stages):
    cfg = get_config(arch)
    stages = decompose(cfg, n_core_stages=n_stages)

    assert all(s.kind in ("core", "light") for s in stages)
    names = [s.name for s in stages]
    assert names[0] == "tokenize" and names[-1] == "detokenize"
    assert "sample" in names

    # decoder core stages partition [0, n_layers) in order
    dec = [s for s in stages if s.kind == "core" and s.name != "encoder"]
    assert len(dec) == n_stages
    assert dec[0].layer_range[0] == 0
    assert dec[-1].layer_range[1] == cfg.n_layers
    for a, b in zip(dec, dec[1:]):
        assert a.layer_range[1] == b.layer_range[0]
    for s in dec:
        lo, hi = s.layer_range
        assert lo < hi
        assert s.flops_per_token > 0 and s.param_bytes > 0

    # enc-dec models get a dedicated encoder core stage
    enc = [s for s in stages if s.name == "encoder"]
    if cfg.is_encoder_decoder:
        assert len(enc) == 1 and enc[0].kind == "core"
        assert enc[0].layer_range == (0, cfg.n_encoder_layers)
    else:
        assert not enc

    # lights bracket the cores
    kinds = [s.kind for s in stages]
    first_core, last_core = kinds.index("core"), (
        len(kinds) - 1 - kinds[::-1].index("core"))
    assert all(k == "core" for k in kinds[first_core:last_core + 1])


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x7b",
                                  "seamless-m4t-medium"])
def test_to_application_deterministic(arch):
    cfg = get_config(arch)
    stages = decompose(cfg, n_core_stages=2)
    apps = [to_application(cfg, stages, np.random.default_rng(42),
                           measured_ms={"stage0": 1.5})
            for _ in range(2)]
    for a, b in zip(apps[0].services, apps[1].services):
        assert (a.name, a.kind) == (b.name, b.kind)
        assert np.array_equal(a.r, b.r)
        for f in ("a", "b", "f_det", "f_shape", "f_scale",
                  "c_dp", "c_mt", "c_pl"):
            assert getattr(a, f) == getattr(b, f), (a.name, f)
    t0, t1 = apps[0].task_types[0], apps[1].task_types[0]
    assert t0.edges == t1.edges and t0.deadline == t1.deadline
    assert t0.validate_inverse_tree()
    # pipeline is a chain: every service appears once, linearly ordered
    assert t0.ms_ids == list(range(len(apps[0].services)))
    assert t0.edges == [(i, i + 1) for i in range(len(t0.ms_ids) - 1)]
