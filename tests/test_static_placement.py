"""Static placement IP: feasibility always; optimality vs brute force on
small random instances (hypothesis property tests)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # fall back to the seeded shim (see _propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core.static_placement import (PlacementProblem, brute_force,
                                         solve)


def _problem(rng, v=3, m=2, kappa=0):
    cost = {i: float(rng.uniform(1, 10)) for i in range(m)}
    q = {i: rng.uniform(0, 20, size=v) for i in range(m)}
    z = {i: rng.uniform(0, 1.2, size=v) for i in range(m)}
    box = {i: rng.integers(1, 4, size=v) for i in range(m)}
    return PlacementProblem(cost=cost, q=q, z=z, box=box, kappa=kappa,
                            xi=float(rng.uniform(0.0, 1.0)))


@given(seed=st.integers(0, 10_000), kappa=st.integers(0, 4))
@settings(max_examples=60, deadline=None)
def test_solver_feasible(seed, kappa):
    rng = np.random.default_rng(seed)
    prob = _problem(rng, v=4, m=3, kappa=kappa)
    x = solve(prob)
    # demand always covered; box always respected
    for m in prob.core_ids:
        assert (x[m] <= prob.box[m]).all()
        assert (x[m] >= 0).all()
        assert x[m].sum() >= prob.demand(m)
    # kappa honored when honorable
    max_sites = sum((prob.box[m] > 0).sum() for m in prob.core_ids)
    if kappa <= max_sites:
        assert prob.open_sites(x) >= min(kappa, max_sites)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_solver_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    prob = _problem(rng, v=3, m=2, kappa=int(rng.integers(0, 4)))
    x = solve(prob)
    best = brute_force(prob, max_inst=3)
    if best is None:  # kappa infeasible for brute force too
        return
    obj = prob.objective(x)
    obj_best = prob.objective(best)
    # exact on these instances (allow fp noise)
    assert obj <= obj_best + 1e-6, (obj, obj_best)


def test_diversity_prevents_single_point():
    rng = np.random.default_rng(0)
    prob = _problem(rng, v=5, m=2, kappa=6)
    x = solve(prob)
    assert prob.open_sites(x) >= 6
