"""Regression tests for tests/_propcheck.py failure reporting.

The bug being pinned: ``given`` used to annotate failures only by
mutating ``e.args[0]``.  Exceptions that do not render their args
(``OSError`` prints from ``errno``/``strerror``) or that pass through
several nested ``given`` layers silently *lost* the per-case seed and
falsifying example.  ``attach_note`` now also records notes on
``e._propcheck_notes`` and prints them to stderr, so the reproduction
recipe (qualname seed + case index) survives any exception type.
"""
import sys

import numpy as np
import pytest

sys.path.insert(0, __file__.rsplit("/", 1)[0])

from _propcheck import attach_note, given, settings, st  # noqa: E402


def _fail_on(predicate, exc_factory):
    """A property that raises ``exc_factory()`` on the first drawn
    value satisfying ``predicate``."""
    @given(x=st.integers(0, 100))
    @settings(max_examples=20)
    def prop(x):
        if predicate(x):
            raise exc_factory(x)
    return prop


def test_plain_failure_keeps_example_and_seed():
    prop = _fail_on(lambda x: x > 50, lambda x: AssertionError(f"x={x}"))
    with pytest.raises(AssertionError) as ei:
        prop()
    msg = str(ei.value)
    assert "falsifying example" in msg
    assert "seed=" in msg and "case " in msg
    assert ei.value._propcheck_notes  # machine-readable channel


def test_oserror_style_exception_does_not_lose_seed(capsys):
    """OSError(errno, strerror) renders from errno/strerror — args
    mutation is invisible in str(e).  The note must still reach the
    notes attribute and stderr."""
    prop = _fail_on(lambda x: x > 50,
                    lambda x: OSError(2, "No such file or directory"))
    with pytest.raises(OSError) as ei:
        prop()
    notes = getattr(ei.value, "_propcheck_notes", [])
    assert notes and "seed=" in notes[0]
    err = capsys.readouterr().err
    assert "_propcheck: falsifying example" in err
    assert "seed=" in err


def test_nested_given_keeps_both_layers(capsys):
    """A property that itself runs a nested check must report the
    falsifying example of *every* layer, innermost first."""
    @given(y=st.integers(0, 10))
    @settings(max_examples=5)
    def inner(y):
        if y >= 0:  # always fails on the first case
            raise ValueError("inner boom")

    @given(x=st.integers(0, 10))
    @settings(max_examples=5)
    def outer(x):
        inner()

    with pytest.raises(ValueError) as ei:
        outer()
    notes = ei.value._propcheck_notes
    assert len(notes) == 2
    assert "inner" in notes[0] and "outer" in notes[1]
    err = capsys.readouterr().err
    assert err.count("_propcheck: falsifying example") == 2


def test_failure_is_reproducible():
    """The same property fails with the same falsifying example on
    every run (the seeded-stream contract the note's seed records)."""
    def make():
        return _fail_on(lambda x: x % 7 == 3, AssertionError)
    notes = []
    for _ in range(2):
        with pytest.raises(AssertionError) as ei:
            make()()
        notes.append(ei.value._propcheck_notes[0])
    assert notes[0] == notes[1]


def test_passing_property_draws_deterministically():
    """No regression to the draw stream: the sequence of examples a
    property sees is unchanged by the reporting fix (stable across
    runs and keyed by qualified name)."""
    seen = []

    @given(x=st.integers(0, 1000), b=st.booleans())
    @settings(max_examples=10)
    def prop(x, b):
        seen.append((x, b))

    prop()
    first = list(seen)
    seen.clear()
    prop()
    assert seen == first
    assert len(set(first)) > 1  # actually random, not constant


def test_attach_note_tolerates_hostile_exceptions():
    class Stubborn(Exception):
        @property
        def args(self):
            return ()

        @args.setter
        def args(self, v):
            raise TypeError("no")

    e = Stubborn()
    attach_note(e, "note-1")  # must not raise
    assert e._propcheck_notes == ["note-1"]
