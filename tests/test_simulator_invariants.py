"""Simulator invariants across seeds/strategies: DAG precedence, metric
bounds, cost monotonicity, and the determinism lock the parallel
replication runner depends on."""
import numpy as np
import pytest

from repro.core.baselines import LBRRStrategy
from repro.core.experiment import run_trial, spawn_rng, stable_seed
from repro.core.graph import make_application
from repro.core.network import make_network
from repro.core.online_controller import ProposalStrategy
from repro.core.simulator import Simulator
from repro.experiments.runner import TrialSpec, run_grid, run_one

SEEDS = (0, 3)
STRATS = ("proposal", "lbrr")


def _run_sim(seed, strategy_cls, horizon=12, **sim_kw):
    rng = np.random.default_rng(seed)
    app = make_application(rng)
    net = make_network(rng)
    sim = Simulator(app, net, strategy_cls(),
                    rng=np.random.default_rng(seed + 1),
                    horizon_slots=horizon, drain_slots=200, **sim_kw)
    metrics = sim.run()
    return sim, metrics


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("strategy_cls", [ProposalStrategy, LBRRStrategy])
def test_finish_times_respect_dag_precedence(seed, strategy_cls):
    """Every recorded stage finish obeys its task DAG's edges, and a
    task's overall finish is its sink stage's finish."""
    sim, _ = _run_sim(seed, strategy_cls)
    checked = 0
    for task in sim.tasks.values():
        for src, dst in task.tt.edges:
            if src in task.done and dst in task.done:
                assert task.done[dst] >= task.done[src] - 1e-9
                checked += 1
        if task.finish is not None:
            assert task.finish == task.done[task.tt.sink()]
            assert task.finish >= task.t_gen
    assert checked > 0


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("strategy", STRATS)
def test_metric_bounds(seed, strategy):
    """On-time tasks are a subset of completed tasks; rates live in
    [0, 1]; costs are non-negative."""
    (m,) = run_trial(seed, strategy_names=[strategy], horizon_slots=10)
    assert 0.0 <= m["on_time"] <= m["completed"] <= 1.0
    assert m["core_cost"] >= 0.0
    assert m["light_cost"] >= 0.0
    assert m["total_cost"] == pytest.approx(
        m["core_cost"] + m["light_cost"])


@pytest.mark.parametrize("seed", SEEDS)
def test_cost_monotone_in_horizon(seed):
    """A longer horizon accrues at least the shorter one's cost (LBRR's
    static placement is horizon-independent, so the comparison is
    apples-to-apples; maintenance cost strictly accumulates)."""
    costs = []
    for horizon in (8, 16, 32):
        _, m = _run_sim(seed, LBRRStrategy, horizon=horizon)
        costs.append(m["total_cost"])
    assert costs[0] <= costs[1] <= costs[2]
    assert costs[0] < costs[2]


def test_identical_seeds_identical_metrics():
    """Determinism lock for the replication runner: the same spec
    replays to identical metric dicts, run-to-run and worker-to-worker,
    and matches the sequential run_trial code path."""
    spec = TrialSpec(seed=7, strategy="proposal", scenario="bursty_mmpp",
                     horizon_slots=10)
    a, b = run_one(spec), run_one(spec)
    assert a == b
    par = run_grid([spec, spec], n_workers=2)
    assert par[0] == a and par[1] == a
    (seq,) = run_trial(7, strategy_names=["proposal"], horizon_slots=10,
                       scenario="bursty_mmpp")
    assert seq == a


def test_stable_seed_is_process_independent():
    """crc32, not hash(): fixed values locked so 'fixed-seed' trials
    reproduce across interpreter launches (PYTHONHASHSEED salting broke
    this for the old hash(name) scheme)."""
    assert stable_seed("proposal") == 3219494002
    assert stable_seed("lbrr") == 3102049165
    s1 = spawn_rng(1, stable_seed("proposal")).integers(1 << 30)
    s2 = spawn_rng(1, stable_seed("proposal")).integers(1 << 30)
    assert s1 == s2


def test_churn_recovery_restores_service():
    """Generalized churn: fail-then-recover must not do worse than
    failing the same node forever."""
    from repro.core.simulator import ChurnEvent
    perm = [ChurnEvent(slot=3, node=6, action="fail")]
    rec = [ChurnEvent(slot=3, node=6, action="fail"),
           ChurnEvent(slot=6, node=6, action="recover")]
    rng = np.random.default_rng(11)
    app = make_application(rng)
    net = make_network(rng)
    out = {}
    for name, churn in (("perm", perm), ("rec", rec)):
        sim = Simulator(app, net, ProposalStrategy(kappa=12),
                        rng=np.random.default_rng(12),
                        horizon_slots=14, drain_slots=200, churn=churn)
        out[name] = sim.run()
    assert out["rec"]["completed"] >= out["perm"]["completed"] - 1e-9
