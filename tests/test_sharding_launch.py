"""Sharding rules + launch plumbing (1x1 host mesh: no 512-device flag —
the big-mesh path is exercised by launch/dryrun.py, see EXPERIMENTS.md)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import SHAPES
from repro.configs import get_smoke_config
from repro.launch.hlo_analysis import (collective_stats, roofline_terms,
                                       _shape_bytes)
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import input_specs, make_step, param_shardings
from repro.models import build_model
from repro.sharding.specs import constrain, fit_spec, param_spec


def test_fit_spec_drops_nondividing():
    mesh = make_host_mesh()
    ns = fit_spec((7, 3), P("data", "model"), mesh)
    assert ns.spec == P(None, None) or all(
        s is None or mesh.shape[s] == 1 for s in ns.spec)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert constrain(x, "act_btd") is x


def test_param_spec_heuristics():
    mesh = make_host_mesh()  # sizes 1 -> everything fits
    spec = param_spec("blocks/segments/0/mlp/w_gate", (64, 128), mesh)
    assert isinstance(spec, P)


@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x7b",
                                  "falcon-mamba-7b", "seamless-m4t-medium"])
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_make_step_lowers_on_host_mesh(arch, shape):
    """Every step kind lowers+compiles on the trivial mesh with a smoke
    config (fast proxy for the production dry-run)."""
    import dataclasses
    cfg = get_smoke_config(arch)
    shp = dataclasses.replace(SHAPES[shape], seq_len=32, global_batch=2)
    mesh = make_host_mesh()
    with mesh:
        fn, args = make_step(cfg, shp, mesh)
        compiled = jax.jit(fn).lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_param_shardings_cover_tree():
    cfg = get_smoke_config("zamba2-7b")
    model = build_model(cfg)
    mesh = make_host_mesh()
    ps = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    sh = param_shardings(mesh, model, ps)
    n_leaves = len(jax.tree.leaves(ps))
    assert len(jax.tree.leaves(sh)) == n_leaves


# ----------------------------------------------------------------------
# HLO collective parsing
# ----------------------------------------------------------------------
def test_shape_bytes():
    assert _shape_bytes("f32[2,3]") == 24
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(f32[2], s32[4])") == 24


def test_collective_stats_parsing():
    hlo = """
  %ag = f32[16,4096]{1,0} all-gather(f32[1,4096]{1,0} %x), dims={0}
  %ar = bf16[1024]{0} all-reduce(bf16[1024]{0} %y), to_apply=%add
  %rs = f32[8]{0} reduce-scatter(f32[64]{0} %z), dimensions={0}
  %nop = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
"""
    st = collective_stats(hlo)
    assert st.count_by_kind["all-gather"] == 1
    assert st.bytes_by_kind["all-gather"] == 16 * 4096 * 4
    assert st.count_by_kind["all-reduce"] == 1
    assert st.bytes_by_kind["reduce-scatter"] == 32
    assert st.total_bytes > 0


def test_roofline_terms_dominance():
    r = roofline_terms(flops=1e15, hbm_bytes=1e9, coll_bytes=1e6,
                       n_chips=256)
    assert r["dominant"] == "compute"
    r = roofline_terms(flops=1e9, hbm_bytes=1e13, coll_bytes=1e6,
                       n_chips=256)
    assert r["dominant"] == "memory"
