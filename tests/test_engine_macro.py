"""Decode hot-loop regressions: dispatches/syncs per token stay at the
macro-step bound (the win can't silently rot), and the run loop
surfaces requests left in flight instead of dropping them."""
import pytest

from repro.configs import get_smoke_config
from repro.serving import PagedServingEngine, Request, ServingEngine
from repro.serving.engine import chunk_sizes
from repro.serving.instrument import instrument


def _drain(eng, prompt, new_tokens):
    eng.submit(Request(id=0, prompt=list(prompt), max_new_tokens=new_tokens))
    (done,) = eng.run()
    assert len(done.out_tokens) == new_tokens
    return done


# ----------------------------------------------------------------------
# dispatch accounting: decode dispatches per generated token must be
# <= 1/K (+ the prefill terms, counted separately)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 8])
def test_dispatches_per_token_bound_dense(k):
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=2, cache_len=64, prefill_chunk=4,
                        decode_steps=k)
    counts = instrument(eng)
    prompt, new = list(range(1, 9)), 32
    _drain(eng, prompt, new)
    # steady-state decode: exactly ceil(new / K) fused dispatches
    assert counts.decode_dispatches == -(-new // k)
    assert counts.decode_dispatches / new <= 1.0 / k
    # host syncs track dispatches one-for-one (one materialization per
    # macro-step, never per token, and never a logits transfer)
    assert eng.n_host_syncs == counts.decode_dispatches
    # prefill cost is the chunk decomposition of prompt[:-1], no more
    assert counts.prefill_dispatches == len(chunk_sizes(len(prompt) - 1, 4))
    assert counts.counts["reset"] == 1


@pytest.mark.parametrize("k", [8])
def test_dispatches_per_token_bound_paged(k):
    cfg = get_smoke_config("smollm-360m")
    eng = PagedServingEngine(cfg, max_rows=2, max_len=64, block_size=8,
                             prefill_chunk=4, decode_steps=k)
    counts = instrument(eng)
    _drain(eng, list(range(1, 9)), 32)
    # an ample pool never clips the opportunistic block growth, so the
    # paged macro scheduler hits the same 1/K dispatch bound
    assert counts.decode_dispatches == -(-32 // k)
    assert eng.n_host_syncs == counts.decode_dispatches
    # block tables upload at most once per ledger change — bounded by
    # growth events (one per block) + admission, not by tokens
    assert eng.pc.n_meta_uploads <= 32 // 8 + 2


# ----------------------------------------------------------------------
# compile accounting: program count must be a function of the *shape
# vocabulary* (chunk/macro sizes), never of how many requests ran
# ----------------------------------------------------------------------
def test_compile_count_stable_across_traces():
    """Re-tracing is the quiet way to lose the macro-step win: a jit
    keyed on a per-request Python value (or a drifting shape) recompiles
    every trace and no parity test notices.  Pin the program budget
    across a two-trace run: the second, identically-shaped trace must
    add ZERO compiled programs, and a third request needing one new
    power-of-two tail macro must add exactly one."""
    import jax
    # absolute program counts need a cold cache: jax shares executable
    # caches by underlying-function identity, so the module-level
    # reset jit would otherwise see other tests' engines' compiles
    jax.clear_caches()
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=2, cache_len=64, prefill_chunk=4,
                        decode_steps=8)
    counts = instrument(eng)

    _drain(eng, list(range(1, 9)), 32)          # trace 1
    c1 = counts.compiled_programs()
    if c1 == 0:
        pytest.skip("this jax build exposes no compilation-cache sizes")
    # prompt[:-1] = 7 tokens -> prefill shapes {4, 2, 1}; decode runs
    # only full K=8 macros -> {decode8}; plus the one reset program
    assert c1 == 5
    d1 = counts.total_dispatches

    _drain(eng, list(range(3, 11)), 32)         # trace 2: same shapes
    assert counts.total_dispatches > d1         # it really ran...
    assert counts.compiled_programs() == c1     # ...compiling nothing

    _drain(eng, [5, 6, 7, 8, 9], 12)            # trace 3: 12 = 8 + 4
    # new tail macro (decode4) is the single new program: the 4-token
    # prefill chunk and the reset re-use trace 1's shapes
    assert counts.compiled_programs() == c1 + 1
    assert "decode4" in eng._jits and "decode8" in eng._jits


def test_max_macro_tokens_tracks_full_budget():
    """steady_syncs_per_token in benchmarks/engine_bench.py is
    1/max_macro_tokens; a full-budget scan must reach K tokens."""
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=1, cache_len=64, prefill_chunk=4,
                        decode_steps=8)
    _drain(eng, [3, 1, 4], 16)
    assert eng.max_macro_tokens >= 8


def test_run_step_budget_not_overshot_by_macro_steps():
    """run(max_steps) is a device-step budget: a K=16 engine given
    max_steps=4 must clamp its macro-step, not burn 16 steps."""
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=1, cache_len=64, prefill_chunk=4,
                        decode_steps=16)
    eng.submit(Request(id=0, prompt=[5, 6, 7], max_new_tokens=32))
    t0 = eng.t
    done = eng.run(max_steps=4)
    assert done == []
    assert eng.t - t0 == 4
    assert len(eng.unfinished[0].out_tokens) == 4
    # the budget-clamped prefix must match an unclamped run's stream
    eng2 = ServingEngine(cfg, max_batch=1, cache_len=64, prefill_chunk=4,
                         decode_steps=16)
    eng2.submit(Request(id=0, prompt=[5, 6, 7], max_new_tokens=32))
    (full,) = eng2.run()
    assert full.out_tokens[:4] == eng.unfinished[0].out_tokens


# ----------------------------------------------------------------------
# run() must surface in-flight work at the step budget, not drop it
# ----------------------------------------------------------------------
@pytest.mark.parametrize("make", [
    lambda cfg: ServingEngine(cfg, max_batch=1, cache_len=64,
                              prefill_chunk=4),
    lambda cfg: PagedServingEngine(cfg, max_rows=1, max_len=64,
                                   block_size=8, prefill_chunk=4),
])
def test_run_surfaces_unfinished(make):
    cfg = get_smoke_config("smollm-360m")
    eng = make(cfg)
    eng.submit(Request(id=0, prompt=[5, 6, 7], max_new_tokens=10))
    eng.submit(Request(id=1, prompt=[9, 10], max_new_tokens=10))
    done = eng.run(max_steps=5)
    assert done == []
    # id 0 still holds its row mid-generation, id 1 is still queued —
    # both are surfaced, neither has a completion stamp
    assert [r.id for r in eng.unfinished] == [0, 1]
    assert all(r.t_done is None for r in eng.unfinished)
    assert 0 < len(eng.unfinished[0].out_tokens) < 10
    # the surfaced requests are resumable: a further run() drains them
    done = eng.run()
    assert sorted(r.id for r in done) == [0, 1]
    assert all(len(r.out_tokens) == 10 for r in done)
    assert eng.unfinished == []
