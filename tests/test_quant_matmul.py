"""Weight-only quantization units: Pallas dequant-matmul kernels vs the
ref.py oracles, quantization-error pins vs the dense matmul, and the
models/quantize.py pytree contract (key gating, idempotence, qdot
dispatch equivalence).

Tolerances (documented in kernels/quant_matmul.py): Pallas vs ref is
f32 round-off only (both dequantize to f32 before the dot; the
accumulation order differs) — atol 1e-3 at unit scale.  Ref vs the
*unquantized* dense matmul is the quantization error itself: rel-RMS
~1e-2 for int8 (per-channel), ~1e-1 for int4 (per-64-group), pinned
from both sides so a silently-dense path (error ~0) fails too.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import quant_matmul
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.models import quantize as qz

SHAPES = [
    (4, 64, 32),     # small, single tile
    (3, 128, 96),    # odd rows, non-multiple-of-block N
    (2, 96, 48),     # K=96: int4 group falls back to gcd(96, 64) = 32
    (1, 256, 300),   # decode row, N padding
]


def _weights(k, n, key=0):
    kw, kx = jax.random.split(jax.random.PRNGKey(key))
    w = jax.random.normal(kw, (k, n), jnp.float32)
    return w, kx


def _qs(w, fmt):
    packed = qz.quantize_int8(w) if fmt == "int8" else qz.quantize_int4(w)
    return packed["q"], packed["s"]


def _rel_rms(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return float(np.sqrt(np.mean((a - b) ** 2)) / np.sqrt(np.mean(b ** 2)))


# ----------------------------------------------------------------------
# Pallas kernel vs ref oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_pallas_matches_ref(m, k, n, fmt):
    w, kx = _weights(k, n)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    q, s = _qs(w, fmt)
    exp = (ref.quant_matmul_int8_ref(x, q, s) if fmt == "int8"
           else ref.quant_matmul_int4_ref(x, q, s))
    out = quant_matmul_pallas(x, q, s, block_m=8, block_n=64,
                              interpret=True)
    assert out.shape == exp.shape and out.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-3, rtol=0)


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_ops_wrapper_dispatches(fmt):
    w, kx = _weights(128, 64)
    x = jax.random.normal(kx, (2, 128), jnp.float32)
    q, s = _qs(w, fmt)
    np.testing.assert_allclose(
        np.asarray(quant_matmul(x, q, s, use_pallas=True, interpret=True)),
        np.asarray(quant_matmul(x, q, s, use_pallas=False)),
        atol=1e-3, rtol=0)


def test_batched_x_reshapes():
    w, kx = _weights(64, 32)
    q, s = _qs(w, "int8")
    x = jax.random.normal(kx, (2, 3, 64), jnp.float32)
    out = quant_matmul_pallas(x, q, s, interpret=True)
    assert out.shape == (2, 3, 32)
    flat = quant_matmul_pallas(x.reshape(6, 64), q, s, interpret=True)
    np.testing.assert_array_equal(np.asarray(out.reshape(6, 32)),
                                  np.asarray(flat))


# ----------------------------------------------------------------------
# Quantization error vs the dense matmul — pinned from both sides
# ----------------------------------------------------------------------
@pytest.mark.parametrize("fmt,lo,hi", [("int8", 1e-4, 3e-2),
                                       ("int4", 1e-2, 2e-1)])
def test_quant_error_pinned(fmt, lo, hi):
    w, kx = _weights(256, 128, key=7)
    x = jax.random.normal(kx, (8, 256), jnp.float32)
    dense = x @ w
    q, s = _qs(w, fmt)
    out = (ref.quant_matmul_int8_ref(x, q, s) if fmt == "int8"
           else ref.quant_matmul_int4_ref(x, q, s))
    err = _rel_rms(out, dense)
    assert lo < err < hi, err


# ----------------------------------------------------------------------
# models/quantize.py: pack/unpack, pytree contract, qdot dispatch
# ----------------------------------------------------------------------
def test_int4_pack_unpack_roundtrip():
    q = jnp.clip(jax.random.randint(jax.random.PRNGKey(3), (64, 16),
                                    -8, 8), -8, 7).astype(jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(qz.unpack_int4(qz.pack_int4(q))), np.asarray(q))


def test_dequantize_bounds():
    w, _ = _weights(128, 64, key=5)
    for fmt, tol in (("int8", 0.02), ("int4", 0.2)):
        packed = qz._quantize_leaf(w, fmt, qz.DEFAULT_GROUP)
        assert qz.is_quantized(packed)
        err = np.max(np.abs(np.asarray(qz.dequantize(packed) - w)))
        # symmetric per-channel/group scales bound the error by s/2-ish
        assert err < tol * float(np.max(np.abs(np.asarray(w)))), err


def test_quantize_params_gating_and_idempotence():
    key = jax.random.PRNGKey(0)
    params = {
        "wq": jax.random.normal(key, (32, 64)),
        "w_up": jax.random.normal(key, (32, 128)),
        "embed": jax.random.normal(key, (100, 32)),   # not a QUANT_KEY
        "scale": jnp.ones((32,)),                      # norm, stays dense
        "bq": jnp.zeros((64,)),                        # bias, ndim < 2
        "conv_w": jax.random.normal(key, (4, 32)),     # SSM, not gated in
    }
    out = qz.quantize_params(params, "int8")
    assert qz.is_quantized(out["wq"]) and qz.is_quantized(out["w_up"])
    for k in ("embed", "scale", "bq", "conv_w"):
        assert not qz.is_quantized(out[k])
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(params[k]))
    # idempotent: re-quantizing a packed tree is a no-op
    again = qz.quantize_params(out, "int8")
    np.testing.assert_array_equal(np.asarray(again["wq"]["q"]),
                                  np.asarray(out["wq"]["q"]))
    # None / "bf16" are identity; unknown formats raise
    assert qz.quantize_params(params, None) is params
    assert qz.quantize_params(params, "bf16") is params
    with pytest.raises(ValueError):
        qz.quantize_params(params, "fp8")


def test_odd_k_stays_dense_for_int4():
    params = {"wq": jax.random.normal(jax.random.PRNGKey(1), (33, 64))}
    out = qz.quantize_params(params, "int4")
    assert not qz.is_quantized(out["wq"])   # int4 packs K-pairs
    out8 = qz.quantize_params(params, "int8")
    assert qz.is_quantized(out8["wq"])      # int8 has no such constraint


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qdot_dense_is_exact_einsum(dtype):
    w, kx = _weights(64, 32)
    w = w.astype(dtype)
    x = jax.random.normal(kx, (2, 5, 64), dtype)
    np.testing.assert_array_equal(
        np.asarray(qz.qdot(x, w)),
        np.asarray(jnp.einsum("...k,kn->...n", x, w)))


@pytest.mark.parametrize("fmt,tol", [("int8", 3e-2), ("int4", 2e-1)])
def test_qdot_quant_close_to_dense(fmt, tol):
    w, kx = _weights(192, 64, key=11)   # K=192: int4 group 64, 3 groups
    x = jax.random.normal(kx, (4, 192), jnp.float32)
    packed = qz._quantize_leaf(w, fmt, qz.DEFAULT_GROUP)
    assert _rel_rms(qz.qdot(x, packed), x @ w) < tol
    # and the scan-chunked path agrees with the flat ref dequant
    refd = (ref.quant_matmul_int8_ref(x, packed["q"], packed["s"])
            if fmt == "int8"
            else ref.quant_matmul_int4_ref(x, packed["q"], packed["s"]))
    np.testing.assert_allclose(np.asarray(qz.qdot(x, packed)),
                               np.asarray(refd), atol=1e-3, rtol=0)


def test_chunk_len_divides():
    for k in (1, 2, 64, 96, 192, 1000, 4096):
        c = qz._chunk_len(k)
        assert k % c == 0 and c <= 256
    assert qz._chunk_len(192, multiple=64) == 192
    assert qz._chunk_len(4096, multiple=64) == 256
