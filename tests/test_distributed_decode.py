"""shard_map seq-parallel flash-decode vs the single-host oracle.

Runs in a subprocess with 4 forced host devices (the main test process
must keep seeing 1 device — see conftest)."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.serving.decode import distributed_decode_attention
    from repro.kernels.ref import decode_attention_ref

    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2),
                ("data", "model"))
    key = jax.random.PRNGKey(0)
    B, H, KV, S, D = 4, 8, 2, 256, 64
    q = jax.random.normal(key, (B, H, D))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, KV, S, D))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, KV, S, D))
    pos = jnp.array([3, 100, 255, 17], jnp.int32)
    out = distributed_decode_attention(q, kc, vc, pos, mesh)
    exp = decode_attention_ref(q, kc, vc, pos)
    err = float(jnp.max(jnp.abs(out - exp)))
    assert err < 1e-4, err
    # HLO check: no all-gather of the cache — only small psum/pmax traffic
    lowered = jax.jit(lambda *a: distributed_decode_attention(
        *a, mesh)).lower(q, kc, vc, pos)
    hlo = lowered.compile().as_text()
    big = B * KV * S * D * 4
    import re
    for line in hlo.splitlines():
        if "all-gather" in line and f"{S}" in line:
            # cache-sized all-gather would defeat the point
            assert False, "cache all-gather found: " + line[:160]
    print("OK", err)
""")


def test_distributed_decode_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
