"""Paged KV cache + continuous batching: dense↔paged token parity,
macro-step (K-fused decode) parity, block-ledger invariants,
preemption-by-recompute, request robustness.

``golden_decode.json`` pins the committed engines' greedy token streams
(captured from the pre-macro-step per-token engines; regenerate by
running ``_outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
prefill_chunk=4))`` per arch) — every engine variant and every
macro-step size K must reproduce them byte-identically.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.kvcache import PagedCache
from repro.serving import (PagedPipelinedEngine, PagedServingEngine,
                           PipelinedEngine, Request, ServingEngine)
from repro.serving.scheduler import goodput

PROMPTS = [[5, 6, 7, 2, 9, 3, 8, 1], [9, 10, 4], [11, 3, 5, 7, 2]]

_GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden_decode.json").read_text())


def _golden(arch):
    return {int(i): toks for i, toks in _GOLDEN[arch].items()}


def _outputs(eng, new_tokens=5):
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=new_tokens))
    return {r.id: r.out_tokens for r in eng.run()}


# ----------------------------------------------------------------------
# tentpole acceptance: paged == dense, greedy, token-identical
# (dense + MoE + SSM + weight-shared hybrid + sliding-window)
# ----------------------------------------------------------------------
PARITY_ARCHS = ["smollm-360m", "mixtral-8x7b", "falcon-mamba-7b",
                "zamba2-7b", "gemma3-12b"]

#: tier split (TOOLING.md §Test tiers): every sweep keeps one arch in
#: tier-1 (`make test`); the remaining columns are tier2 — still run by
#: `make test-full` and any bare `pytest` invocation
SWEEP_ARCHS = [PARITY_ARCHS[0]] + [
    pytest.param(a, marks=pytest.mark.tier2) for a in PARITY_ARCHS[1:]]


@pytest.mark.parametrize("arch", SWEEP_ARCHS)
def test_paged_matches_dense(arch):
    cfg = get_smoke_config(arch)
    dense = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                   prefill_chunk=4))
    assert dense == _golden(arch)  # pinned to the committed engines
    # max_rows=2 < len(PROMPTS) forces row reuse: the zeroed SSM state
    # row / stale-KV masking must isolate a row's next occupant
    eng = PagedServingEngine(cfg, max_rows=2, max_len=32, block_size=8,
                             prefill_chunk=4)
    paged = _outputs(eng)
    assert paged == dense
    eng.pc.check()
    assert eng.pc.used_blocks == 0  # every block returned on completion


# ----------------------------------------------------------------------
# macro-step parity: the fused K-step scan must be invisible in greedy
# outputs for every K, every arch, across preemption and mid-stream
# admission (SERVING.md §The decode hot loop)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("k", [2, 8])
@pytest.mark.parametrize("arch", SWEEP_ARCHS)
def test_macro_step_parity(arch, k):
    cfg = get_smoke_config(arch)
    ref = _golden(arch)
    assert _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                  prefill_chunk=4, decode_steps=k)) == ref
    eng = PagedServingEngine(cfg, max_rows=2, max_len=32, block_size=8,
                             prefill_chunk=4, decode_steps=k)
    assert _outputs(eng) == ref
    eng.pc.check()
    assert eng.pc.used_blocks == 0


@pytest.mark.parametrize("k", [8])
def test_macro_step_parity_pipelined(k):
    cfg = get_smoke_config("smollm-360m")
    ref = _golden("smollm-360m")
    assert _outputs(PipelinedEngine(cfg, n_stages=2, max_batch=3,
                                    cache_len=32, prefill_chunk=4,
                                    decode_steps=k)) == ref
    eng = PagedPipelinedEngine(cfg, n_stages=2, max_rows=3, max_len=32,
                               block_size=8, prefill_chunk=4,
                               decode_steps=k)
    assert _outputs(eng) == ref
    eng.pc.check()


@pytest.mark.parametrize("arch,k", [("smollm-360m", 2),
                                    ("smollm-360m", 8),
                                    ("falcon-mamba-7b", 8)])
def test_macro_preemption_then_resume(arch, k):
    """Pool exhaustion mid-run must stay invisible at every K: the
    macro scheduler's opportunistic growth may shift *when* preemption
    fires, but never what tokens come out."""
    cfg = get_smoke_config(arch)
    eng = PagedServingEngine(cfg, max_rows=3, max_len=32, block_size=8,
                             num_blocks=3, prefill_chunk=4, decode_steps=k)
    assert _outputs(eng) == _golden(arch)
    assert eng.n_preemptions > 0
    eng.pc.check()
    assert eng.pc.used_blocks == 0


def test_macro_step_parity_moe_capacity_coupled():
    """Wide batch + staggered budgets, MoE arch: expert capacity ranks
    slot claims over the whole co-batch, so a masked row's compute is
    *visible* to live rows.  The scan must feed a freed row token 0
    from the step after its last live step — exactly what the per-token
    loop's `_next_tokens` does — or K changes other requests' streams
    (caught in review: the feedback mask was off by one step)."""
    cfg = get_smoke_config("mixtral-8x7b")

    def run(k):
        eng = ServingEngine(cfg, max_batch=12, cache_len=32,
                            prefill_chunk=4, decode_steps=k)
        for i in range(12):  # staggered budgets force mid-scan masking
            eng.submit(Request(id=i, prompt=[3 + i, 1, 4],
                               max_new_tokens=3 + (i % 5)))
        return {r.id: r.out_tokens for r in eng.run()}

    assert run(8) == run(1)


@pytest.mark.parametrize("k", [4, 8])
def test_macro_mid_stream_admission(k):
    """A request submitted while another is mid-generation joins only
    at a macro-step boundary — which must not change either stream."""
    cfg = get_smoke_config("smollm-360m")

    def run(mk):
        eng = mk()
        eng.submit(Request(id=0, prompt=[5, 6, 7], max_new_tokens=12))
        eng.step()  # id 0 is now mid-stream
        eng.submit(Request(id=1, prompt=[9, 10, 4], max_new_tokens=8))
        return {r.id: r.out_tokens for r in eng.run()}

    ref = run(lambda: ServingEngine(cfg, max_batch=2, cache_len=32,
                                    prefill_chunk=4))
    assert run(lambda: ServingEngine(cfg, max_batch=2, cache_len=32,
                                     prefill_chunk=4,
                                     decode_steps=k)) == ref
    assert run(lambda: PagedServingEngine(cfg, max_rows=2, max_len=32,
                                          block_size=8, prefill_chunk=4,
                                          decode_steps=k)) == ref


@pytest.mark.parametrize("arch", ["smollm-360m", "falcon-mamba-7b"])
def test_paged_pipelined_matches_dense(arch):
    cfg = get_smoke_config(arch)
    dense = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                   prefill_chunk=4))
    eng = PagedPipelinedEngine(cfg, n_stages=2, max_rows=3, max_len=32,
                               block_size=8, prefill_chunk=4)
    assert _outputs(eng) == dense
    eng.pc.check()


# ----------------------------------------------------------------------
# goodput parity sweep: deadline-driven scheduling (serving/scheduler.py)
# reorders which rows run, never what they compute
# ----------------------------------------------------------------------
# Regression trace: two batch hogs ahead of four interactive requests.
# FIFO admits in submission order, so every interactive TTFT (16 steps)
# blows while the hogs decode; EDF admits the interactive tier first
# (earlier deadline) and the hogs' 512-step budget absorbs the wait.
GOODPUT_TRACE = [
    ("batch", [5, 6, 7], 20),
    ("batch", [9, 10, 4], 20),
    ("interactive", [11, 3, 5], 4),
    ("interactive", [2, 8], 4),
    ("interactive", [7, 7, 1], 4),
    ("interactive", [4, 9, 9, 2], 4),
]


def _goodput_run(cfg, policy, k):
    # max_rows=2 keeps MoE co-batches small enough to stay out of the
    # expert-capacity coupling carve-out (SERVING.md): parity must hold
    # even though FIFO and EDF co-batch different request pairs
    eng = PagedServingEngine(cfg, max_rows=2, max_len=32, block_size=8,
                             prefill_chunk=4, decode_steps=k,
                             policy=policy)
    reqs = [Request(id=i, prompt=list(p), max_new_tokens=n, qos=q)
            for i, (q, p, n) in enumerate(GOODPUT_TRACE)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert not eng.rejected and not eng.unfinished
    eng.pc.check()
    return {r.id: list(r.out_tokens) for r in reqs}, goodput(reqs), reqs


@pytest.mark.parametrize("k", [1, 8])
@pytest.mark.parametrize("arch", SWEEP_ARCHS)
def test_goodput_parity_sweep(arch, k):
    cfg = get_smoke_config(arch)
    fifo_out, fifo_g, _ = _goodput_run(cfg, "fifo", k)
    edf_out, edf_g, edf_reqs = _goodput_run(cfg, "edf", k)
    # scheduling changes WHICH rows run, never WHAT they compute
    assert edf_out == fifo_out
    # ... and the reorder is real: EDF admits interactive before batch
    admits = {r.qos: r.t_admit for r in edf_reqs}
    assert admits["interactive"] < admits["batch"]
    # deadline-aware admission strictly improves goodput on this trace
    assert fifo_g < 1.0
    assert edf_g >= fifo_g
    assert edf_g == 1.0
@pytest.mark.parametrize("arch", ["smollm-360m", "falcon-mamba-7b"])
def test_preemption_then_resume(arch):
    cfg = get_smoke_config(arch)
    dense = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                   prefill_chunk=4))
    # 3 blocks of 8 cannot hold all three requests' full footprints
    # (2 + 1 + 2 blocks), so decode growth must preempt at least once
    eng = PagedServingEngine(cfg, max_rows=3, max_len=32, block_size=8,
                             num_blocks=3, prefill_chunk=4)
    assert _outputs(eng) == dense
    assert eng.n_preemptions > 0
    eng.pc.check()
    assert eng.pc.used_blocks == 0


def test_preempted_request_keeps_original_admit_stamp():
    cfg = get_smoke_config("smollm-360m")
    eng = PagedServingEngine(cfg, max_rows=3, max_len=32, block_size=8,
                             num_blocks=3, prefill_chunk=4)
    done = []
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=5))
    while eng.queue or eng.active_rows:
        done += eng.step()
    assert eng.n_preemptions > 0
    for r in done:
        assert r.t_submit <= r.t_admit <= r.t_done
        # completion latency covers the generated tokens even across
        # a preempt/recompute round-trip
        assert r.t_done - r.t_admit >= r.max_new_tokens - 1


# ----------------------------------------------------------------------
# continuous admission: equal cache memory, higher concurrency
# ----------------------------------------------------------------------
def test_token_level_admission_beats_slot_granularity():
    """At dense-equivalent memory (2 slots x 32 tokens), short requests
    must co-run beyond the dense slot count: the dense engine admits 2,
    the paged engine admits as many as the pool's blocks allow."""
    cfg = get_smoke_config("smollm-360m")
    eng = PagedServingEngine(cfg, max_rows=6, max_len=32, block_size=8,
                             num_blocks=8, prefill_chunk=4)
    for i in range(6):
        eng.submit(Request(id=i, prompt=[3 + i, 1, 4], max_new_tokens=4))
    peak = 0
    done = []
    while eng.queue or eng.active_rows:
        done += eng.step()
        peak = max(peak, eng.active_rows)
    assert len(done) == 6
    assert peak > 2, f"peak concurrency {peak} no better than dense slots"
    eng.pc.check()


def test_paged_oversized_request_rejected_not_fatal():
    cfg = get_smoke_config("smollm-360m")
    eng = PagedServingEngine(cfg, max_rows=2, max_len=16, block_size=8)
    eng.submit(Request(id=0, prompt=list(range(1, 15)), max_new_tokens=8))
    eng.submit(Request(id=1, prompt=[3, 1, 4], max_new_tokens=4))
    done = eng.run()
    assert [r.id for r in eng.rejected] == [0]
    assert "exceeds capacity" in eng.rejected[0].error
    assert [(r.id, len(r.out_tokens)) for r in done] == [(1, 4)]
    eng.pc.check()


# ----------------------------------------------------------------------
# block-ledger invariants (host-side, no jax)
# ----------------------------------------------------------------------
def _ledger(num_blocks=6, max_rows=3, max_len=32, bs=8):
    cfg = get_smoke_config("smollm-360m")
    return PagedCache(cfg, max_rows=max_rows, max_len=max_len,
                      block_size=bs, num_blocks=num_blocks)


def test_ledger_admit_grow_release_cycle():
    pc = _ledger()
    assert pc.free_blocks == 6
    assert pc.admit(0, 9)            # 9 tokens -> 2 blocks
    assert pc.used_blocks == 2
    assert (pc.tables[0, :2] > 0).all() and (pc.tables[0, 2:] == 0).all()
    assert pc.ensure(0, 9) and pc.ensure(0, 15)   # inside held blocks
    assert pc.used_blocks == 2
    assert pc.ensure(0, 16)          # crosses into block 2 -> grow
    assert pc.used_blocks == 3
    pc.check()
    pc.release(0)
    assert pc.free_blocks == 6 and (pc.tables[0] == 0).all()
    pc.check()


def test_ledger_exhaustion_and_no_partial_admit():
    pc = _ledger(num_blocks=3)
    assert pc.admit(0, 17)           # 3 blocks
    assert not pc.can_admit(1)
    assert not pc.admit(1, 1)        # refused whole, nothing leaked
    assert pc.used_blocks == 3 and not pc._held["attn"][1]
    pc.check()
    pc.release(0)
    assert pc.free_blocks == 3


def test_ledger_double_free_guard_and_scratch():
    pc = _ledger()
    assert pc.admit(0, 8)
    blk = pc._held["attn"][0][0]
    assert blk != 0                  # scratch block never allocated
    pc.release(0)
    pc.release(0)                    # releasing an empty row is a no-op
    pc.check()
    # forging a double-booked block must trip the guard — a RuntimeError,
    # not an assert, so it survives ``python -O``
    pc._held["attn"][0].append(blk)
    with pytest.raises(RuntimeError):
        pc.release(0)


def test_ledger_fits_and_watermark():
    pc = _ledger(num_blocks=4)
    assert pc.fits(32) and not pc.fits(33)
    pc.watermark_blocks = 2
    assert not pc.can_admit(17)      # 3 blocks + 2 watermark > 4
    assert pc.can_admit(17, watermark=0)
    assert not pc.admit(0, 17)       # default path honors the watermark
    assert pc.utilization() == 0.0
    assert pc.admit(0, 17, watermark=0)  # the scheduler's idle override
    assert pc.utilization() == pytest.approx(0.75)


def test_ledger_meta_reuploads_only_on_change():
    """Incremental device block tables: the full-table snapshot is
    rebuilt only when the ledger changed (admission/growth/release) —
    steady-state decode reuses the same immutable device arrays."""
    pc = _ledger()
    assert pc.admit(0, 9)
    m1 = pc.meta()
    assert pc.meta() is m1 and pc.n_meta_uploads == 1  # cached reuse
    assert pc.ensure(0, 15)                 # inside held blocks: no change
    assert pc.meta() is m1 and pc.n_meta_uploads == 1
    assert pc.ensure(0, 16)                 # growth -> new snapshot
    m2 = pc.meta()
    assert m2 is not m1 and pc.n_meta_uploads == 2
    assert int(m2["tables"][0, 2]) == pc.tables[0, 2] != 0
    pc.meta(row=0)                          # per-row prefill view is
    assert pc.meta() is m2                  # fresh, never the cache
    pc.release(0)
    assert (np.asarray(pc.meta()["tables"]) == 0).all()
    assert pc.n_meta_uploads == 3


def test_ledger_deterministic_reallocation():
    pc1, pc2 = _ledger(), _ledger()
    for pc in (pc1, pc2):
        pc.admit(0, 9)
        pc.admit(1, 3)
        pc.release(0)
        pc.admit(2, 20)
    np.testing.assert_array_equal(pc1.tables, pc2.tables)


# ----------------------------------------------------------------------
# prefix-sharing parity sweep: sharing ON vs OFF, byte-identical
# streams on overlapping-prefix prompts (SERVING.md §Prefix sharing)
# ----------------------------------------------------------------------
# PROMPTS share no full-block prefix, so the engine-default
# prefix_sharing=True is exercised as a no-op by every test above (the
# golden streams pin that).  This sweep uses prompts built on a shared
# full block so the sharing machinery actually fires where supported.
SHARED_PROMPTS = [[5, 6, 7, 2, 9, 3, 8, 1] + t
                  for t in ([4, 2], [9, 9, 1], [3])]
# archs whose paged cache can share (pure-attention pools; SWA/SSM
# archs auto-gate sharing off and the sweep pins that path too)
SHARING_ARCHS = {"smollm-360m"}


def _shared_outputs(eng, new_tokens=5):
    for i, p in enumerate(SHARED_PROMPTS):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=new_tokens))
    out = {r.id: r.out_tokens for r in eng.run()}
    eng.pc.check()
    assert eng.pc.used_blocks == 0
    return out


@pytest.mark.parametrize("k", [1, 8])
@pytest.mark.parametrize("arch", SWEEP_ARCHS)
def test_prefix_sharing_on_off_parity_sweep(arch, k):
    cfg = get_smoke_config(arch)

    def run(sharing):
        return PagedServingEngine(cfg, max_rows=2, max_len=32,
                                  block_size=8, prefill_chunk=4,
                                  decode_steps=k, prefix_sharing=sharing)

    on = run(True)
    out_on = _shared_outputs(on)
    off = run(False)
    assert _shared_outputs(off) == out_on  # sharing never changes tokens
    if arch in SHARING_ARCHS:
        assert on.pc.n_prefix_hits > 0     # ... and it actually fired
        assert on.prefill_tokens < off.prefill_tokens
    else:
        assert not on.pc.sharing_supported  # SWA/SSM: auto-gated off
        assert on.pc.n_prefix_hits == 0


@pytest.mark.parametrize("k", [1, 8])
def test_prefix_sharing_preemption_resume_parity(k):
    """Preemption of a request whose prefix blocks are shared: the
    refcounted release keeps the survivor's blocks resident, resume
    re-matches the prefix, and the streams stay identical to the
    sharing-off run.  num_blocks=4 cannot hold all three grown
    footprints (3 blocks each at 8 new tokens), so decode growth must
    preempt in both runs."""
    cfg = get_smoke_config("smollm-360m")

    def run(sharing):
        eng = PagedServingEngine(cfg, max_rows=3, max_len=32,
                                 block_size=8, num_blocks=4,
                                 prefill_chunk=4, decode_steps=k,
                                 prefix_sharing=sharing)
        return _shared_outputs(eng, new_tokens=8), eng

    out_on, on = run(True)
    out_off, off = run(False)
    assert out_on == out_off
    assert on.n_preemptions > 0 and off.n_preemptions > 0
    assert on.pc.n_prefix_hits > 0
    assert on.pc.free_blocks == 4
