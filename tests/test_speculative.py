"""Real-model speculative-decoding parity tests.

The exactness contract: speculative streams are byte-identical to
non-speculative greedy streams for every supported arch × K ∈ {1, 4, 8}
— including preemption-resume and mid-stream admission — because every
accepted draft IS the greedy target at its position and rollback is a
pure position decrement (SERVING.md §Speculative decoding).

Tier split: the smollm-360m column runs in tier-1; the bigger
supported archs (qwen2-72b, command-r-35b) and the model-draft
end-to-end cell are ``tier2`` (see TOOLING.md §Test tiers).
tests/test_differential.py fuzzes the cross-engine diagonal;
tests/test_spec_decode.py pins the JAX-free scheduler accounting.
"""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving.engine import PagedServingEngine, Request, ServingEngine
from repro.serving.instrument import instrument
from repro.serving.speculative import spec_supported

ARCHS = ["smollm-360m",
         pytest.param("qwen2-72b", marks=pytest.mark.tier2),
         pytest.param("command-r-35b", marks=pytest.mark.tier2)]

PROMPTS = [[1, 2, 3, 4], [7, 8, 9], [5, 6, 5, 6, 5], [11, 3, 7, 2]]


def run_paged(cfg, spec, *, num_blocks=10, max_rows=2, n=18):
    """Tight pool (forces preemption) + mid-stream admission."""
    eng = PagedServingEngine(cfg, seed=0, speculative=spec,
                             max_rows=max_rows, max_len=48, block_size=8,
                             num_blocks=num_blocks)
    for i, p in enumerate(PROMPTS[:2]):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=n))
    for _ in range(3):
        eng.step()
    for i, p in enumerate(PROMPTS[2:], start=2):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=n))
    done = eng.run()
    assert len(done) == len(PROMPTS)
    return eng, {r.id: r.out_tokens for r in done}


@pytest.fixture(scope="module")
def baselines():
    cache = {}

    def get(arch):
        if arch not in cache:
            cache[arch] = run_paged(get_smoke_config(arch), None)[1]
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("k", [1, 4, 8])
def test_spec_parity_paged(arch, k, baselines):
    cfg = get_smoke_config(arch)
    assert spec_supported(cfg)
    eng, got = run_paged(cfg, k)
    assert got == baselines(arch)
    assert eng.spec_rounds > 0
    assert 0.0 <= eng.acceptance_rate <= 1.0


def test_spec_parity_dense_smollm():
    cfg = get_smoke_config("smollm-360m")

    def run(spec):
        eng = ServingEngine(cfg, seed=0, speculative=spec, max_batch=3,
                            cache_len=48)
        for i, p in enumerate(PROMPTS):
            eng.submit(Request(id=i, prompt=list(p), max_new_tokens=16))
        return eng, {r.id: r.out_tokens for r in eng.run()}

    _, base = run(None)
    for k in (1, 4, 8):
        eng, got = run(k)
        assert got == base
        # host syncs: exactly one per verify round, fewer rounds than
        # emitted tokens once anything is accepted
        assert eng.n_host_syncs == eng.spec_rounds


@pytest.mark.tier2
def test_model_draft_end_to_end(baselines):
    """smollm-360m drafting for qwen2-72b: still byte-identical, and
    the draft's own jit dispatches are visible under the ``draft.``
    instrumentation prefix."""
    cfg = get_smoke_config("qwen2-72b")
    eng = PagedServingEngine(cfg, seed=0, max_rows=2, max_len=48,
                             block_size=8, num_blocks=10,
                             speculative={"k": 4, "draft": "model",
                                          "draft_cfg": "smollm-360m"})
    counts = instrument(eng)
    for i, p in enumerate(PROMPTS[:2]):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=18))
    for _ in range(3):
        eng.step()
    for i, p in enumerate(PROMPTS[2:], start=2):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=18))
    done = eng.run()
    got = {r.id: r.out_tokens for r in done}
    assert got == baselines("qwen2-72b")
    assert counts.verify_dispatches == eng.spec_rounds
    assert counts.draft_dispatches > 0
    assert counts.decode_dispatches == 0  # spec replaces the macro scan


def test_verify_dispatch_accounting():
    cfg = get_smoke_config("smollm-360m")
    eng = PagedServingEngine(cfg, seed=0, speculative=4, max_rows=2,
                             max_len=48, block_size=8, num_blocks=16)
    counts = instrument(eng)
    eng.submit(Request(id=0, prompt=[1, 2, 3], max_new_tokens=12))
    eng.run()
    assert counts.verify_dispatches == eng.spec_rounds > 0
    assert counts.draft_dispatches == 0  # n-gram drafts are host-only
    assert counts.decode_dispatches == 0
    # one fused program for the whole run: the verify chunk shape is
    # fixed at K+1, so no recompiles as rows finish
    assert counts.counts["verify5"] == eng.spec_rounds


def test_golden_decode_unchanged():
    """The committed golden streams (recorded long before speculative
    decoding existed) must be bit-for-bit reproducible with
    speculation *on* — the strongest regression gate this PR has.
    Engine parameters mirror tests/test_paged.py's golden capture."""
    import json
    import pathlib
    golden = json.loads((pathlib.Path(__file__).parent
                         / "golden_decode.json").read_text())
    want = {int(i): t for i, t in golden["smollm-360m"].items()}
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=3, cache_len=32, prefill_chunk=4,
                        speculative=8)
    for i, p in enumerate([[5, 6, 7, 2, 9, 3, 8, 1], [9, 10, 4],
                           [11, 3, 5, 7, 2]]):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=5))
    done = {r.id: r.out_tokens for r in eng.run()}
    assert done == want
