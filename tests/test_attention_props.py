"""Attention-layer properties: blockwise == naive, SWA ring cache,
GQA group correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import attention as attn


def _cfg(**kw):
    base = get_smoke_config("qwen2-72b")
    return dataclasses.replace(base, **kw)


def test_blockwise_equals_naive():
    """Long-seq q-chunked path == single-block path."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    params = attn.attention_init(key, cfg, jnp.float32)
    b, s = 2, 96
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out_naive, _ = attn.self_attention(params, x, pos, cfg, "attn")
    old = attn.Q_CHUNK
    try:
        attn.Q_CHUNK = 32
        out_block, _ = attn.self_attention(params, x, pos, cfg, "attn")
    finally:
        attn.Q_CHUNK = old
    assert float(jnp.max(jnp.abs(out_naive - out_block))) < 1e-4


def test_swa_equals_full_when_window_covers():
    cfg_full = _cfg()
    cfg_swa = _cfg(window=4096)
    key = jax.random.PRNGKey(2)
    params = attn.attention_init(key, cfg_full, jnp.float32)
    b, s = 1, 48
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s, cfg_full.d_model))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    o1, _ = attn.self_attention(params, x, pos, cfg_full, "attn")
    o2, _ = attn.self_attention(params, x, pos, cfg_swa, "swa")
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-5


def test_swa_ring_buffer_decode_wraps():
    """Decoding past the window: ring cache must equal a fresh windowed
    attention computed from full history."""
    w = 8
    cfg = _cfg(window=w)
    key = jax.random.PRNGKey(4)
    params = attn.attention_init(key, cfg, jnp.float32)
    b, total = 1, 20
    xs = jax.random.normal(jax.random.PRNGKey(5), (b, total, cfg.d_model))
    pos_all = jnp.broadcast_to(jnp.arange(total), (b, total))

    # sequential decode through the ring
    cache = {"k": jnp.zeros((b, w, cfg.n_kv_heads, cfg.head_dim)),
             "v": jnp.zeros((b, w, cfg.n_kv_heads, cfg.head_dim))}
    outs = []
    for t in range(total):
        o, cache = attn.decode_self_attention(
            params, xs[:, t:t + 1], cache,
            jnp.full((b,), t, jnp.int32), cfg, "swa")
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)

    full, _ = attn.self_attention(params, xs, pos_all, cfg, "swa")
    assert float(jnp.max(jnp.abs(dec - full))) < 1e-4


def test_gqa_reduces_to_mha_when_kv_equals_heads():
    cfg = _cfg()
    assert cfg.n_heads == cfg.n_kv_heads  # smoke config promotes to MHA
    key = jax.random.PRNGKey(6)
    params = attn.attention_init(key, cfg, jnp.float32)
    b, s = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(7), (b, s, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    out, kv = attn.self_attention(params, x, pos, cfg, "attn")
    assert out.shape == (b, s, cfg.d_model)
    assert kv["k"].shape == (b, s, cfg.n_kv_heads, cfg.head_dim)


def test_causality():
    """Future tokens must not influence past outputs."""
    cfg = _cfg()
    params = attn.attention_init(jax.random.PRNGKey(8), cfg, jnp.float32)
    b, s = 1, 24
    x = jax.random.normal(jax.random.PRNGKey(9), (b, s, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    o1, _ = attn.self_attention(params, x, pos, cfg, "attn")
    x2 = x.at[:, -1].set(1000.0)
    o2, _ = attn.self_attention(params, x2, pos, cfg, "attn")
    assert float(jnp.max(jnp.abs(o1[:, :-1] - o2[:, :-1]))) < 1e-5
