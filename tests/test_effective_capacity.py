"""Effective capacity map g_{m,eps}(y): theory properties + Monte-Carlo
validation of the violation probability."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # fall back to the seeded shim (see _propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core.effective_capacity import (ECMap, effective_capacity,
                                           latency_budget)


@given(shape=st.floats(0.8, 3.0), scale=st.floats(0.5, 20.0),
       theta=st.floats(0.01, 10.0))
@settings(max_examples=50, deadline=None)
def test_ec_below_mean(shape, scale, theta):
    """E_c(theta) <= E[f] always (Jensen), approaches it as theta -> 0."""
    ec = effective_capacity(theta, shape, scale)
    assert ec <= shape * scale + 1e-9
    ec_small = effective_capacity(1e-6, shape, scale)
    assert ec_small == pytest.approx(shape * scale, rel=1e-3)


@given(shape=st.floats(0.8, 3.0), scale=st.floats(0.5, 20.0))
@settings(max_examples=30, deadline=None)
def test_g_monotone(shape, scale):
    ec = ECMap(a_mb=1.0, shape=shape, scale=scale, eps=0.2, y_max=16)
    tbl = ec.table
    assert (np.diff(tbl) > 0).all()              # g grows with y
    assert (tbl >= ec.mean_table[:16] - 1e-9).all()  # conservative vs mean


@given(eps1=st.floats(0.05, 0.3), eps2=st.floats(0.35, 0.8))
@settings(max_examples=20, deadline=None)
def test_g_decreases_with_eps(eps1, eps2):
    g1 = latency_budget(1.5, 5.0, eps1, workload=2.0)
    g2 = latency_budget(1.5, 5.0, eps2, workload=2.0)
    assert g1 >= g2  # stricter guarantee -> bigger budget


@pytest.mark.parametrize("shape,scale,y", [(1.0, 5.0, 1), (2.0, 10.0, 4),
                                           (1.5, 2.0, 8)])
def test_violation_probability_monte_carlo(shape, scale, y):
    """Empirical P{completion time > g(y)} <= eps for the paper's
    cumulative service process F(0,t) = sum of i.i.d. Gamma slot rates
    (the process the simulator implements)."""
    eps = 0.2
    a = 1.0
    ec = ECMap(a_mb=a, shape=shape, scale=scale, eps=eps, y_max=16)
    g = ec.g(y)
    rng = np.random.default_rng(0)
    n = 20_000
    work = a * y
    # vectorized cumulative-service completion times
    max_slots = int(np.ceil(g)) + 40
    rates = rng.gamma(shape, scale, size=(n, max_slots))
    cum = np.cumsum(rates, axis=1)
    done_slot = np.argmax(cum >= work, axis=1)
    unfinished = cum[:, -1] < work
    prev = np.where(done_slot > 0,
                    cum[np.arange(n), np.maximum(done_slot - 1, 0)], 0.0)
    frac = (work - prev) / rates[np.arange(n), done_slot]
    latency = done_slot + frac
    latency[unfinished] = max_slots + 1.0
    viol = float(np.mean(latency > g))
    assert viol <= eps + 0.02, (viol, g)


def test_max_parallelism():
    ec = ECMap(a_mb=1.0, shape=1.5, scale=10.0, eps=0.2, y_max=32)
    assert ec.max_parallelism(ec.g(4) + 1e-9) >= 4
    assert ec.max_parallelism(0.0) == 0
