"""Simulator behaviour + strategy integration (one short trial each)."""
import numpy as np
import pytest

from repro.core.baselines import GAStrategy, LBRRStrategy
from repro.core.experiment import run_trial, summarize
from repro.core.graph import make_application
from repro.core.lyapunov import VirtualQueues
from repro.core.network import make_network
from repro.core.online_controller import PropAvgStrategy, ProposalStrategy
from repro.core.simulator import Simulator


@pytest.mark.parametrize("cls", [ProposalStrategy, PropAvgStrategy,
                                 LBRRStrategy, GAStrategy])
def test_strategy_runs(cls):
    rng = np.random.default_rng(0)
    app = make_application(rng)
    net = make_network(rng)
    kw = {"gens": 5, "pop": 8} if cls is GAStrategy else {}
    sim = Simulator(app, net, cls(**kw), rng=np.random.default_rng(1),
                    horizon_slots=15, drain_slots=150)
    m = sim.run()
    assert m["generated"] > 0
    assert 0.0 <= m["on_time"] <= m["completed"] <= 1.0
    assert m["total_cost"] > 0


def test_virtual_queue_floor():
    q = VirtualQueues(zeta=2.0)
    q.admit(1)
    assert q.get(1) == 2.0
    q.update(1, latency_so_far=1.0, deadline=50.0)   # way under deadline
    assert q.get(1) == 2.0                            # floored, not zero
    q.update(1, latency_so_far=80.0, deadline=50.0)
    assert q.get(1) == pytest.approx(32.0)            # 2 + 80 - 50


def test_latency_recursion_max_over_parents():
    """Eq. (4): completion at a merge node waits for ALL parents."""
    rng = np.random.default_rng(3)
    app = make_application(rng)
    net = make_network(rng)
    sim = Simulator(app, net, ProposalStrategy(), rng=np.random.default_rng(4),
                    horizon_slots=8, drain_slots=200)
    sim.run()
    for task in sim.tasks.values():
        if task.finish is None:
            continue
        for src, dst in task.tt.edges:
            if dst in task.done and src in task.done:
                assert task.done[dst] >= task.done[src] - 1e-9


def test_run_trial_and_summarize():
    rows = run_trial(0, strategy_names=["proposal", "lbrr"],
                     horizon_slots=10)
    s = summarize(rows)
    assert set(s) == {"proposal", "lbrr"}
    for v in s.values():
        assert v["n_trials"] == 1


def test_core_instances_queue_fifo_capacity():
    """A core instance never runs two tasks at once."""
    rng = np.random.default_rng(5)
    app = make_application(rng)
    net = make_network(rng)
    sim = Simulator(app, net, ProposalStrategy(), rng=np.random.default_rng(6),
                    horizon_slots=10, drain_slots=200)
    sim.run()
    # reconstruct: for each (v,m) free-times array only moves forward
    for (v, m), free in sim.core_free.items():
        assert (free >= 0).all()


def test_node_failure_degrades_but_not_zero():
    """Fault injection: killing an ES mid-run hurts completion but the
    diversity-spread backbone keeps serving (validates C6's purpose)."""
    rng = np.random.default_rng(11)
    app = make_application(rng)
    net = make_network(rng)
    base = Simulator(app, net, ProposalStrategy(kappa=12),
                     rng=np.random.default_rng(12),
                     horizon_slots=20, drain_slots=200).run()
    failed = Simulator(app, net, ProposalStrategy(kappa=12),
                       rng=np.random.default_rng(12),
                       horizon_slots=20, drain_slots=200,
                       fail_node=6, fail_at=10).run()
    assert failed["completed"] <= base["completed"] + 1e-9
    assert failed["completed"] > 0.2   # spread backbone survives
