"""Simulator behaviour + strategy integration (one short trial each)."""
import numpy as np
import pytest

from repro.core.baselines import GAStrategy, LBRRStrategy
from repro.core.experiment import run_trial, summarize
from repro.core.graph import make_application
from repro.core.lyapunov import VirtualQueues
from repro.core.network import make_network
from repro.core.online_controller import PropAvgStrategy, ProposalStrategy
from repro.core.simulator import Simulator


@pytest.mark.parametrize("cls", [ProposalStrategy, PropAvgStrategy,
                                 LBRRStrategy, GAStrategy])
def test_strategy_runs(cls):
    rng = np.random.default_rng(0)
    app = make_application(rng)
    net = make_network(rng)
    kw = {"gens": 5, "pop": 8} if cls is GAStrategy else {}
    sim = Simulator(app, net, cls(**kw), rng=np.random.default_rng(1),
                    horizon_slots=15, drain_slots=150)
    m = sim.run()
    assert m["generated"] > 0
    assert 0.0 <= m["on_time"] <= m["completed"] <= 1.0
    assert m["total_cost"] > 0


def test_virtual_queue_floor():
    q = VirtualQueues(zeta=2.0)
    q.admit(1)
    assert q.get(1) == 2.0
    q.update(1, latency_so_far=1.0, deadline=50.0)   # way under deadline
    assert q.get(1) == 2.0                            # floored, not zero
    q.update(1, latency_so_far=80.0, deadline=50.0)
    assert q.get(1) == pytest.approx(32.0)            # 2 + 80 - 50


def test_latency_recursion_max_over_parents():
    """Eq. (4): completion at a merge node waits for ALL parents."""
    rng = np.random.default_rng(3)
    app = make_application(rng)
    net = make_network(rng)
    sim = Simulator(app, net, ProposalStrategy(), rng=np.random.default_rng(4),
                    horizon_slots=8, drain_slots=200)
    sim.run()
    for task in sim.tasks.values():
        if task.finish is None:
            continue
        for src, dst in task.tt.edges:
            if dst in task.done and src in task.done:
                assert task.done[dst] >= task.done[src] - 1e-9


def test_run_trial_and_summarize():
    rows = run_trial(0, strategy_names=["proposal", "lbrr"],
                     horizon_slots=10)
    s = summarize(rows)
    assert set(s) == {"proposal", "lbrr"}
    for v in s.values():
        assert v["n_trials"] == 1


def test_core_instances_queue_fifo_capacity():
    """A core instance never runs two tasks at once."""
    rng = np.random.default_rng(5)
    app = make_application(rng)
    net = make_network(rng)
    sim = Simulator(app, net, ProposalStrategy(), rng=np.random.default_rng(6),
                    horizon_slots=10, drain_slots=200)
    sim.run()
    # reconstruct: for each (v,m) free-times array only moves forward
    for (v, m), free in sim.core_free.items():
        assert (free >= 0).all()


def test_node_failure_degrades_but_not_zero():
    """Fault injection: killing an ES mid-run hurts completion but the
    diversity-spread backbone keeps serving (validates C6's purpose)."""
    rng = np.random.default_rng(11)
    app = make_application(rng)
    net = make_network(rng)
    base = Simulator(app, net, ProposalStrategy(kappa=12),
                     rng=np.random.default_rng(12),
                     horizon_slots=20, drain_slots=200).run()
    failed = Simulator(app, net, ProposalStrategy(kappa=12),
                       rng=np.random.default_rng(12),
                       horizon_slots=20, drain_slots=200,
                       fail_node=6, fail_at=10).run()
    assert failed["completed"] <= base["completed"] + 1e-9
    # tasks keep completing (pre-failure cohort + surviving sites).  The
    # old threshold of 0.2 encoded two pre-PR bugs that inflated
    # completions past the failure window: source stages started one
    # uplink too early, and slow services were silently truncated at 8
    # sample blocks (EXPERIMENTS.md §Vectorized engine, metric drift).
    # Fixed-semantics value for this seed is ~0.061 (kappa counts TOTAL
    # open sites, so this placement concentrates C1-C3 on the failed
    # node); 0.03 keeps headroom while still catching a collapse to
    # "only the first slots' tasks finish".
    assert failed["completed"] > 0.03
    assert base["completed"] > 0.9     # no-failure run is healthy


# ----------------------------------------------------------------------
# PR 3 regressions: uplink-gated source readiness, no silent service
# truncation, vectorized data-readiness parity
# ----------------------------------------------------------------------
def test_source_stage_waits_for_uplink():
    """`data_ready_at` for a source stage must gate on the uplink
    finishing, not on t_gen (the old code re-set t_gen after
    construction, so the payload was considered present one full uplink
    too early)."""
    from repro.core.simulator import Task
    rng = np.random.default_rng(21)
    app = make_application(rng)
    net = make_network(rng)
    tt = app.task_types[0]
    src = tt.sources()[0]
    ed = int(net.user_ed[0])
    task = Task(id=0, tt=tt, user=0, t_gen=0.0, ed=ed, uplink_done=7.5)
    task._app = app
    # on the entry node itself there is no transfer: ready == uplink end
    assert task.data_ready_at(src, net, ed) == pytest.approx(7.5)
    for v in range(net.n_nodes):
        assert task.data_ready_at(src, net, v) >= 7.5
    # hand-built tasks without an uplink degrade to t_gen
    bare = Task(id=1, tt=tt, user=0, t_gen=3.0, ed=ed)
    bare._app = app
    assert bare.data_ready_at(src, net, ed) == pytest.approx(3.0)


def test_data_ready_vectorized_matches_scalar():
    """data_ready_at_nodes is elementwise identical to data_ready_at,
    for source stages (uplink + payload route) and merge stages
    (max over parent ship-outs)."""
    from repro.core.simulator import Task
    rng = np.random.default_rng(22)
    app = make_application(rng)
    net = make_network(rng)
    tt = app.task_types[2]          # three-branch fusion type
    merge = [m for m in tt.ms_ids if len(tt.parents(m)) > 1][0]
    task = Task(id=0, tt=tt, user=0, t_gen=0.0, ed=int(net.user_ed[0]),
                uplink_done=2.0)
    task._app = app
    for i, p in enumerate(tt.parents(merge)):
        task.done[p] = 5.0 + i
        task.loc[p] = i % net.n_nodes
    for m in (tt.sources()[0], merge):
        rows = task.data_ready_at_nodes(m, net)
        for v in range(net.n_nodes):
            assert rows[v] == task.data_ready_at(m, net, v), (m, v)


class _BlockRng:
    """Stub rng: `gamma` yields `tiny` rate blocks for the first
    `n_tiny` calls, then `big` blocks."""

    def __init__(self, n_tiny, tiny=1e-6, big=4.0):
        self.calls = 0
        self.n_tiny = n_tiny
        self.tiny = tiny
        self.big = big

    def gamma(self, shape, scale, size):
        self.calls += 1
        val = self.tiny if self.calls <= self.n_tiny else self.big
        return np.full(size, val)


def test_service_sampling_never_truncates():
    """The cumulative Gamma service process must run until the workload
    is covered: the old engine gave up after 8 blocks and scheduled the
    finish anyway, silently shortening the service time."""
    from types import SimpleNamespace
    from repro.core.simulator import (MAX_SERVICE_BLOCKS, SLOT_MS,
                                      sample_service_ms)
    ms = SimpleNamespace(name="L*", f_shape=1.0, f_scale=1.0, f_mean=1.0)
    work = 10.0
    n_exp = max(4, int(3 * work / ms.f_mean) + 4)
    # 12 near-zero blocks (the old cap was 8) before service resumes
    rng = _BlockRng(n_tiny=12)
    dur = sample_service_ms(rng, ms, work)
    assert dur > 12 * n_exp * SLOT_MS      # waited through all 12 blocks
    assert rng.calls == 13
    # a degenerate process raises instead of under-scheduling
    with pytest.raises(RuntimeError):
        sample_service_ms(_BlockRng(n_tiny=10 ** 9, big=1e-6), ms, work)


def test_commit_light_duration_covers_workload():
    """End-to-end: a committed light stage's sampled finish time is
    consistent with the workload actually being served (never the old
    8-block cap)."""
    from repro.core.simulator import sample_service_ms
    rng = np.random.default_rng(5)
    app = make_application(rng)
    ms = app.ms(app.light_ids[0])
    for _ in range(200):
        work = ms.a * float(rng.integers(1, 6))
        dur = sample_service_ms(rng, ms, work)
        assert dur > 0.0
