"""Per-architecture smoke tests (deliverable f): reduced variant of each
family runs one forward + one train step on CPU; shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import build_model
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.n_image_tokens:
        batch["frontend"] = jax.random.normal(
            key, (b, cfg.n_image_tokens, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["frontend"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits, _, aux = model.forward(params, batch)
    b, s = batch["tokens"].shape
    assert logits.shape[0] == b and logits.shape[1] == s
    assert logits.shape[2] >= cfg.vocab_size  # padded vocab
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux["moe_aux_loss"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt = adamw_init(params)
    step = make_train_step(model, base_lr=1e-3, warmup=2, total_steps=10)
    batch = _batch(cfg, key)
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(opt2.step) == 1
    # params actually changed
    changed = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    b, s = 2, 12
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = _batch(cfg, key, b, s)
    batch["tokens"] = toks[:, :s]
    full = dict(batch, tokens=toks)
    full_logits, _, _ = model.forward(params, full)
    _, cache, _ = model.forward(params, batch, mode="prefill",
                                caches=model.init_cache(b, s + 1))
    dec, _ = model.decode_step(
        params, cache,
        {"token": toks[:, s:s + 1], "pos": jnp.full((b,), s, jnp.int32)})
    err = float(jnp.max(jnp.abs(dec[:, 0] - full_logits[:, s])))
    assert err < 5e-3, err
