"""Property-based scheduler invariants on the FakeEngine testbed.

Randomized mixed-class traces (seeded, via ``tests/_propcheck.py`` —
no hypothesis dependency) drive the real paged scheduler state machine
under every policy, pinning the contracts the serving engines promise
regardless of discipline:

* **conservation** — every submitted request ends in exactly one of
  done / ``engine.rejected`` / ``engine.unfinished``;
* **monotone clocks** — ``t_submit <= t_admit <= t_done`` (and
  ``t_first`` between admission and completion) for every stamp that
  exists;
* **bounded churn** — no request is preempted more than the policy's
  ``max_preemptions`` (eviction, not starvation-by-recompute);
* **determinism** — byte-identical replay across two runs of the same
  seed (policies carry no hidden nondeterminism — the committed
  goodput baseline depends on this).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 runs green without hypothesis
    from _propcheck import given, settings, st

from repro.serving.engine import Request
from repro.serving.scheduler import make_policy
from repro.serving.testbed import FakeEngine

CLASSES = ["interactive", "standard", "batch"]


def _drive(seed: int, policy: str, decode_steps: int, num_blocks: int):
    """One randomized serving session: staggered submission bursts with
    partial ``run()`` budgets in between, then drain.  Returns the
    engine, every submitted request, and the completed list."""
    rng = np.random.default_rng(seed)
    eng = FakeEngine(max_rows=3, max_len=64, block_size=8,
                     num_blocks=num_blocks, decode_steps=decode_steps,
                     policy=make_policy(policy))
    reqs, done = [], []
    for _ in range(int(rng.integers(2, 5))):
        for _ in range(int(rng.integers(1, 5))):
            plen = int(rng.integers(1, 40))
            r = Request(
                id=len(reqs),
                prompt=[int(x) for x in rng.integers(1, 900, size=plen)],
                max_new_tokens=int(rng.integers(1, 14)),
                qos=CLASSES[int(rng.integers(3))])
            reqs.append(r)
            eng.submit(r)
        done += eng.run(max_steps=int(rng.integers(1, 12)))
    done += eng.run()
    return eng, reqs, done


def _state(eng, reqs, done):
    """Full observable outcome of a session, for replay comparison."""
    return repr((
        [(r.id, r.t_submit, r.t_admit, r.t_first, r.t_done,
          r.n_preempted, r.error, r.out_tokens) for r in reqs],
        sorted(r.id for r in done),
        sorted(r.id for r in eng.rejected),
        sorted(r.id for r in eng.unfinished),
        eng.t, eng.tokens_generated, eng.n_preemptions))


@settings(max_examples=12)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["fifo", "edf", "edf_ec"]),
       decode_steps=st.sampled_from([1, 4]),
       num_blocks=st.sampled_from([6, 9, 14]))
def test_every_request_exactly_one_outcome(seed, policy, decode_steps,
                                           num_blocks):
    eng, reqs, done = _drive(seed, policy, decode_steps, num_blocks)
    done_ids = {r.id for r in done}
    rej_ids = {r.id for r in eng.rejected}
    unf_ids = {r.id for r in eng.unfinished}
    assert done_ids | rej_ids | unf_ids == {r.id for r in reqs}
    assert not (done_ids & rej_ids)
    assert not (done_ids & unf_ids)
    assert not (rej_ids & unf_ids)
    for r in done:
        assert r.done and r.error is None and r.t_done is not None
    for r in eng.rejected:
        assert r.error is not None and r.t_done is not None


@settings(max_examples=12)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["fifo", "edf", "edf_ec"]),
       decode_steps=st.sampled_from([1, 4]),
       num_blocks=st.sampled_from([6, 9, 14]))
def test_timestamps_monotone(seed, policy, decode_steps, num_blocks):
    eng, reqs, done = _drive(seed, policy, decode_steps, num_blocks)
    for r in reqs:
        assert r.t_submit is not None          # submit always stamps
        if r.t_admit is not None:
            assert r.t_submit <= r.t_admit
        if r.t_first is not None:
            # admission and the first emitted token can land on the
            # same engine step (prefill + decode in one iteration)
            assert r.t_admit is not None and r.t_admit <= r.t_first
        if r.t_done is not None:
            base = r.t_admit if r.t_admit is not None else r.t_submit
            assert base <= r.t_done
        if r.t_first is not None and r.t_done is not None:
            assert r.t_first <= r.t_done


@settings(max_examples=12)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["edf", "edf_ec"]),
       decode_steps=st.sampled_from([1, 4]),
       num_blocks=st.sampled_from([6, 9]))
def test_preemptions_bounded(seed, policy, decode_steps, num_blocks):
    eng, reqs, done = _drive(seed, policy, decode_steps, num_blocks)
    cap = eng.policy.max_preemptions
    assert cap is not None                     # EDF policies set one
    for r in reqs:
        assert r.n_preempted <= cap
        if r.n_preempted == cap:               # evicted, never requeued
            assert r.error is not None and "preemption" in r.error


@settings(max_examples=8)
@given(seed=st.integers(0, 10_000),
       policy=st.sampled_from(["fifo", "edf", "edf_ec"]),
       decode_steps=st.sampled_from([1, 4]))
def test_replay_byte_identical(seed, policy, decode_steps):
    a = _state(*_drive(seed, policy, decode_steps, 9))
    b = _state(*_drive(seed, policy, decode_steps, 9))
    assert a == b
