"""End-to-end behaviour tests for the paper's system.

Integration across layers: microservice decomposition of a *real* model
feeds the paper's placement + online controller; the same model serves
real batched requests through the engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.online_controller import ProposalStrategy
from repro.core.network import make_network
from repro.core.simulator import Simulator
from repro.microservice.partition import decompose, to_application
from repro.serving.engine import Request, ServingEngine


def test_paper_pipeline_on_real_model_profiles():
    """Decompose smollm-smoke -> application -> run proposal end-to-end."""
    cfg = get_smoke_config("smollm-360m")
    stages = decompose(cfg, n_core_stages=2)
    rng = np.random.default_rng(0)
    app = to_application(cfg, stages, rng,
                         measured_ms={"tokenize": 0.2, "stage0": 1.5,
                                      "stage1": 1.5, "sample": 0.3,
                                      "detokenize": 0.2},
                         deadline_ms=60.0, rate=0.4)
    net = make_network(rng)
    strat = ProposalStrategy(kappa=4)
    sim = Simulator(app, net, strat, rng=np.random.default_rng(1),
                    horizon_slots=30, drain_slots=200)
    m = sim.run()
    assert m["generated"] > 10
    assert m["completed"] > 0.8
    assert m["on_time"] > 0.5
    # static tier actually placed both core stages somewhere
    placed = {mm for mm, xv in sim.x_cr.items() if xv.sum() > 0}
    assert placed == set(app.core_ids)


def test_serve_and_paper_schedule_agree_on_throughput():
    """The engine really serves requests while the controller schedules —
    the integration the paper's Fig. 2 describes."""
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=4, cache_len=40)
    for i in range(6):
        eng.submit(Request(id=i, prompt=[i + 1, 2, 3], max_new_tokens=5))
    done = eng.run()
    assert len(done) == 6
    # deterministic greedy sampling
    again = ServingEngine(cfg, max_batch=4, cache_len=40)
    for i in range(6):
        again.submit(Request(id=i, prompt=[i + 1, 2, 3], max_new_tokens=5))
    done2 = again.run()
    assert {r.id: r.out_tokens for r in done} == \
        {r.id: r.out_tokens for r in done2}


def test_proposal_beats_unmanaged_tail():
    """With contention-heavy lights, the EC-aware controller keeps the
    on-time rate above a deadline-agnostic round-robin (paper Fig. 3
    ordering, miniature)."""
    from repro.core.baselines import LBRRStrategy
    from repro.core.graph import make_application

    rng = np.random.default_rng(5)
    app = make_application(rng, rate_multiplier=1.5)
    net = make_network(rng)
    m_prop = Simulator(app, net, ProposalStrategy(),
                       rng=np.random.default_rng(7),
                       horizon_slots=40, drain_slots=300).run()
    m_lbrr = Simulator(app, net, LBRRStrategy(),
                       rng=np.random.default_rng(7),
                       horizon_slots=40, drain_slots=300).run()
    assert m_prop["on_time"] > m_lbrr["on_time"]
