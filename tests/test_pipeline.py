"""Pipelined executor: parity with the monolithic engine, chunked
prefill regression, stage slicing, and network-shim accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.network import make_network
from repro.models import build_model
from repro.serving import PipelinedEngine, Request, ServingEngine
from repro.serving.engine import chunk_sizes
from repro.serving.pipeline import PLACEMENT_STRATEGIES, place_stages

PROMPTS = [[5, 6, 7, 2, 9, 3, 8, 1], [9, 10, 4], [11, 3, 5, 7, 2]]


def _outputs(eng):
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=5))
    return {r.id: r.out_tokens for r in eng.run()}


# ----------------------------------------------------------------------
# tentpole acceptance: pipelined == monolithic, greedy, token-identical
# (dense + MoE + SSM + weight-shared hybrid)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["smollm-360m", "mixtral-8x7b",
                                  "falcon-mamba-7b", "zamba2-7b"])
def test_pipelined_matches_monolithic(arch):
    cfg = get_smoke_config(arch)
    mono = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                  prefill_chunk=4))
    pipe_eng = PipelinedEngine(cfg, n_stages=2, max_batch=3, cache_len=32,
                               prefill_chunk=4)
    piped = _outputs(pipe_eng)
    assert piped == mono
    assert len(pipe_eng.stages) == 2
    # each stage owns a disjoint layer range covering the model
    assert [(s.lo, s.hi) for s in pipe_eng.stages] == [(0, 1), (1, 2)]


# ----------------------------------------------------------------------
# satellite: greedy decode identical before/after chunked prefill
# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["smollm-360m", "falcon-mamba-7b"])
def test_chunked_prefill_identical_to_token_by_token(arch):
    cfg = get_smoke_config(arch)
    token_by_token = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                            prefill_chunk=1))
    chunked = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                     prefill_chunk=8))
    assert chunked == token_by_token


@pytest.mark.parametrize("engine_cls", [ServingEngine, PipelinedEngine])
def test_slot_reuse_isolated_from_previous_occupant(engine_cls):
    """A request admitted into a freed slot must match a fresh engine:
    stale KV is position-masked, but SSM recurrent/conv state is not —
    the admitted row must be zeroed."""
    cfg = get_smoke_config("falcon-mamba-7b")
    probe = [7, 3, 9, 2]
    fresh = engine_cls(cfg, max_batch=1, cache_len=32)
    fresh.submit(Request(id=0, prompt=list(probe), max_new_tokens=4))
    want = fresh.run()[0].out_tokens

    reused = engine_cls(cfg, max_batch=1, cache_len=32)
    reused.submit(Request(id=0, prompt=[5, 1, 6, 4, 2, 8], max_new_tokens=4))
    reused.submit(Request(id=1, prompt=list(probe), max_new_tokens=4))
    out = {r.id: r.out_tokens for r in reused.run()}
    assert out[1] == want


def test_engine_has_no_dead_last_token_attr():
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=2, cache_len=32)
    eng.submit(Request(id=0, prompt=[3, 1, 4], max_new_tokens=2))
    eng.run()
    assert not hasattr(eng, "_last_token")


def test_chunk_sizes():
    assert chunk_sizes(0, 16) == []
    assert chunk_sizes(16, 16) == [16]
    assert chunk_sizes(47, 16) == [16, 16, 8, 4, 2, 1]
    for n in range(0, 70):
        sizes = chunk_sizes(n, 16)
        assert sum(sizes) == n
        # bounded program-shape diversity: full chunks + powers of two
        assert all(s == 16 or (s & (s - 1)) == 0 for s in sizes)


# ----------------------------------------------------------------------
# stage slicing: composing run_stages over consecutive ranges
# reproduces the monolithic decode_step
# ----------------------------------------------------------------------
def test_run_stages_composes_to_decode_step():
    cfg = get_smoke_config("smollm-360m")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    caches_full = m.init_cache(2, 16)
    batch = {"token": jnp.array([[7], [3]], jnp.int32),
             "pos": jnp.zeros((2,), jnp.int32)}
    ref, _ = m.decode_step(params, caches_full, batch)

    lo_p = m.stage_params(params, 0, 1, entry=True)
    hi_p = m.stage_params(params, 1, 2, exit_head=True)
    c0 = m.init_cache(2, 16, layers=(0, 1))
    c1 = m.init_cache(2, 16, layers=(1, 2))
    x, _, _ = m.run_stages(lo_p, batch["token"], 0, 1, mode="decode",
                           pos=batch["pos"], caches=c0)
    out, _, _ = m.run_stages(hi_p, x, 1, 2, mode="decode",
                             pos=batch["pos"], caches=c1)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                               rtol=1e-5, atol=1e-5)


def test_stage_params_own_only_their_range():
    cfg = get_smoke_config("mixtral-8x7b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    full = sum(p.size for p in jax.tree.leaves(params["blocks"]))
    sizes = [sum(p.size for p in jax.tree.leaves(
        m.stage_params(params, lo, hi)["blocks"]))
        for lo, hi in ((0, 1), (1, 2))]
    assert sum(sizes) == full


# ----------------------------------------------------------------------
# network shim: placements price the activation hand-offs
# ----------------------------------------------------------------------
def test_transfer_accounting_follows_placement():
    cfg = get_smoke_config("smollm-360m")
    net = make_network(np.random.default_rng(0))
    spread = PipelinedEngine(cfg, n_stages=2, max_batch=2, cache_len=32,
                             net=net, placement={"stage0": 6, "stage1": 7},
                             entry_node=0)
    colo = PipelinedEngine(cfg, n_stages=2, max_batch=2, cache_len=32,
                           net=net, placement={"stage0": 7, "stage1": 7},
                           entry_node=0)
    assert _outputs(spread) == _outputs(colo)  # placement never alters math
    assert spread.transfer_ms > colo.transfer_ms  # inter-stage hop priced
    assert (6, 7) in spread.hops and (6, 7) not in colo.hops
    assert spread.transfer_mb > 0


def test_place_stages_strategies():
    cfg = get_smoke_config("smollm-360m")
    rng = np.random.default_rng(0)
    net = make_network(rng)
    eng = PipelinedEngine(cfg, n_stages=2, max_batch=2, cache_len=32,
                          net=net)
    app = eng.to_application(np.random.default_rng(1),
                             measured_ms={"stage0": 1.0, "stage1": 1.0})
    es = set(int(v) for v in np.flatnonzero(net.is_es))
    for strat in PLACEMENT_STRATEGIES:
        pl = place_stages(app, net, strat, rng=np.random.default_rng(2))
        assert set(pl) == {"stage0", "stage1"}
        assert all(v in es for v in pl.values()), (strat, pl)
    rr = place_stages(app, net, "round_robin")
    assert len(set(rr.values())) == 2
    with pytest.raises(ValueError):
        place_stages(app, net, "nope")


def test_profile_feeds_to_application():
    """profile -> to_application closes the loop: core stage rates are
    calibrated so a_m / f_m equals the measured latency."""
    cfg = get_smoke_config("smollm-360m")
    eng = PipelinedEngine(cfg, n_stages=2, max_batch=2, cache_len=32)
    measured = eng.profile(iters=1)
    assert set(measured) == {"stage0", "stage1"}
    assert all(v > 0 for v in measured.values())
    app = eng.to_application(np.random.default_rng(0),
                             measured_ms=measured)
    for m in app.core_ids:
        ms = app.ms(m)
        if ms.name in measured:
            assert ms.a / ms.f_det == pytest.approx(measured[ms.name],
                                                    rel=1e-6)


def test_pipelined_admission_honors_max_new_tokens_headroom():
    """Same cache-boundary contract as the monolithic engine (the slot
    state machine is shared; both engines must refuse a request whose
    prompt + max_new_tokens exceed the cache — by failing just that
    request, not the engine)."""
    cfg = get_smoke_config("smollm-360m")
    eng = PipelinedEngine(cfg, n_stages=2, max_batch=1, cache_len=16)
    eng.submit(Request(id=0, prompt=list(range(1, 11)), max_new_tokens=6))
    (done,) = eng.run()
    assert len(done.out_tokens) == 6

    eng2 = PipelinedEngine(cfg, n_stages=2, max_batch=1, cache_len=16)
    eng2.submit(Request(id=1, prompt=list(range(1, 17)), max_new_tokens=4))
    eng2.submit(Request(id=2, prompt=[5, 6], max_new_tokens=3))
    done = eng2.run()
    assert [r.id for r in eng2.rejected] == [1]
    assert eng2.rejected[0].error is not None
    assert [(r.id, len(r.out_tokens)) for r in done] == [(2, 3)]
