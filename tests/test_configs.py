"""Config registry: published param counts, applicability rules."""
import pytest

from repro.config import SHAPES, reduce_config
from repro.configs import ARCH_IDS, all_pairs, get_config, get_smoke_config

# published totals (tolerance: embedding/rounding conventions)
EXPECTED_PARAMS_B = {
    "qwen2-72b": (72.7, 0.06),
    "mixtral-8x7b": (46.7, 0.06),
    "command-r-35b": (30.3, 0.20),     # tied-embedding counting varies
    "kimi-k2-1t-a32b": (1042.0, 0.08),
    "falcon-mamba-7b": (7.27, 0.10),
    "gemma3-12b": (11.8, 0.10),
    "seamless-m4t-medium": (0.98, 0.30),
    "llama-3.2-vision-90b": (87.7, 0.10),
    "smollm-360m": (0.36, 0.10),
    "zamba2-7b": (5.7, 0.35),          # shared-attn counting varies
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.num_params() / 1e9
    exp, tol = EXPECTED_PARAMS_B[arch]
    assert abs(n - exp) / exp <= tol, f"{arch}: {n:.2f}B vs {exp}B"


def test_active_params_moe():
    kimi = get_config("kimi-k2-1t-a32b")
    assert 28 <= kimi.num_active_params() / 1e9 <= 36  # "A32B"
    mix = get_config("mixtral-8x7b")
    assert 11 <= mix.num_active_params() / 1e9 <= 15


def test_all_pairs_rules():
    pairs = all_pairs()
    assert len(pairs) == 34  # 10 archs x 4 shapes - 6 long_500k skips
    longs = {a for a, s in pairs if s == "long_500k"}
    assert longs == {"falcon-mamba-7b", "zamba2-7b", "gemma3-12b",
                     "mixtral-8x7b"}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduce_config(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4
    # keeps the family's block kinds
    orig_kinds = set(get_config(arch).block_pattern)
    assert set(cfg.block_pattern) <= orig_kinds


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1
