"""Serving engine + microservice bridge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.microservice.partition import decompose, to_application
from repro.serving.engine import Request, ServingEngine


def test_engine_completes_requests():
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=3, cache_len=48)
    for i in range(5):
        eng.submit(Request(id=i, prompt=[1 + i, 2, 3], max_new_tokens=6))
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)


def test_engine_batched_decode_isolated_slots():
    """Tokens generated for one request must not depend on co-batched
    requests (cache/pos isolation)."""
    cfg = get_smoke_config("smollm-360m")
    eng1 = ServingEngine(cfg, max_batch=1, cache_len=32)
    eng1.submit(Request(id=0, prompt=[5, 6, 7], max_new_tokens=4))
    solo = eng1.run()[0].out_tokens

    eng2 = ServingEngine(cfg, max_batch=3, cache_len=32)
    eng2.submit(Request(id=0, prompt=[5, 6, 7], max_new_tokens=4))
    eng2.submit(Request(id=1, prompt=[9, 10], max_new_tokens=4))
    eng2.submit(Request(id=2, prompt=[11], max_new_tokens=4))
    batched = {r.id: r.out_tokens for r in eng2.run()}
    assert batched[0] == solo


def test_decompose_and_application():
    cfg = get_smoke_config("mixtral-8x7b")
    stages = decompose(cfg, n_core_stages=2)
    names = [s.name for s in stages]
    assert names[0] == "tokenize" and names[-1] == "detokenize"
    assert sum(1 for s in stages if s.kind == "core") == 2
    app = to_application(cfg, stages, np.random.default_rng(0),
                         measured_ms={"stage0": 1.0, "stage1": 1.0})
    tt = app.task_types[0]
    assert tt.validate_inverse_tree()
    assert len(app.core_ids) == 2
    assert len(app.light_ids) == 3
    # calibration: core stage latency == measured
    for m in app.core_ids:
        ms = app.ms(m)
        assert ms.a / ms.f_det == pytest.approx(1.0, rel=1e-6)


def test_encdec_decompose_has_encoder_core():
    cfg = get_smoke_config("seamless-m4t-medium")
    stages = decompose(cfg, n_core_stages=2)
    assert any(s.name == "encoder" for s in stages)


def test_admission_honors_max_new_tokens_headroom():
    """Cache-boundary regression: a prompt of exactly cache_len used to
    pass the admission assert and then finish after ONE decode step
    (pos >= cache_len - 1).  Admission now requires max_new_tokens of
    headroom, so an admitted request always generates in full."""
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=1, cache_len=16)
    # boundary fit: prompt + max_new_tokens == cache_len -> full output
    eng.submit(Request(id=0, prompt=list(range(1, 11)), max_new_tokens=6))
    (done,) = eng.run()
    assert len(done.out_tokens) == 6


def test_oversized_request_rejected_not_fatal():
    """An oversized request must fail alone (Request.error +
    engine.rejected) instead of killing the engine — the old bare
    ``assert`` was stripped under ``python -O`` and fatal to every
    co-batched request."""
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=1, cache_len=16)
    eng.submit(Request(id=1, prompt=list(range(1, 17)), max_new_tokens=4))
    eng.submit(Request(id=2, prompt=[3, 1, 4], max_new_tokens=4))
    done = eng.run()
    assert [r.id for r in eng.rejected] == [1]
    assert "exceeds" in eng.rejected[0].error
    assert not eng.rejected[0].out_tokens
    assert [(r.id, len(r.out_tokens)) for r in done] == [(2, 4)]


def test_request_timestamps_populated():
    """t_submit/t_admit/t_done are step-counter stamps: queueing delay
    and completion latency must be derivable for every served request
    (paged_bench reports them)."""
    cfg = get_smoke_config("smollm-360m")
    eng = ServingEngine(cfg, max_batch=1, cache_len=32)
    for i in range(3):
        eng.submit(Request(id=i, prompt=[1 + i, 2, 3], max_new_tokens=4))
    done = sorted(eng.run(), key=lambda r: r.id)
    assert len(done) == 3
    for r in done:
        assert r.t_admit is not None and r.t_done is not None
        assert r.t_submit <= r.t_admit <= r.t_done
        assert r.t_done - r.t_admit >= r.max_new_tokens - 1
    # max_batch=1 serializes: later requests queue strictly longer
    waits = [r.t_admit - r.t_submit for r in done]
    assert waits[0] < waits[1] < waits[2]
