"""SSM blocks: sequence/step consistency, state carry, shapes."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import ssm


@pytest.mark.parametrize("kind", ["mamba1", "mamba2"])
def test_seq_equals_stepwise(kind):
    cfg = get_smoke_config(
        "falcon-mamba-7b" if kind == "mamba1" else "zamba2-7b")
    init = ssm.mamba1_init if kind == "mamba1" else ssm.mamba2_init
    seq = ssm.mamba1_seq if kind == "mamba1" else ssm.mamba2_seq
    step = ssm.mamba1_step if kind == "mamba1" else ssm.mamba2_step
    params = init(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, t = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (b, t, cfg.d_model)) * 0.3

    y_seq, (h_seq, conv_seq) = seq(params, x, cfg)

    di = cfg.d_inner_eff
    if kind == "mamba1":
        h = jnp.zeros((b, di, cfg.ssm_state))
    else:
        nh = di // cfg.mamba2_headdim
        h = jnp.zeros((b, nh, cfg.mamba2_headdim, cfg.ssm_state))
    conv = jnp.zeros((b, cfg.conv_width - 1, di))
    outs = []
    for i in range(t):
        o, (h, conv) = step(params, x[:, i:i + 1], (h, conv), cfg)
        outs.append(o)
    y_step = jnp.concatenate(outs, axis=1)
    assert float(jnp.max(jnp.abs(y_seq - y_step))) < 2e-4
    assert float(jnp.max(jnp.abs(h_seq - h))) < 2e-4
    assert float(jnp.max(jnp.abs(conv_seq - conv))) < 1e-5


@pytest.mark.parametrize("kind", ["mamba1", "mamba2"])
def test_state_decay_stability(kind):
    """With positive dt and negative A the state stays bounded."""
    cfg = get_smoke_config(
        "falcon-mamba-7b" if kind == "mamba1" else "zamba2-7b")
    init = ssm.mamba1_init if kind == "mamba1" else ssm.mamba2_init
    seq = ssm.mamba1_seq if kind == "mamba1" else ssm.mamba2_seq
    params = init(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jnp.ones((1, 200, cfg.d_model)) * 0.5
    y, (h, _) = seq(params, x, cfg)
    assert jnp.isfinite(y).all()
    assert jnp.isfinite(h).all()
    assert float(jnp.max(jnp.abs(h))) < 1e4
