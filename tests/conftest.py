import os
import sys

# smoke tests and benches must see ONE device — the 512-device flag is set
# only inside launch/dryrun.py (see the brief).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def pytest_configure(config):
    # tier split (TOOLING.md §Test tiers): tier-1 = `make test` =
    # `pytest -m "not tier2"`; tier2 marks the slow parity sweeps that
    # only `make test-full` (and a bare `pytest` run) executes.
    config.addinivalue_line(
        "markers",
        "tier2: slow parity sweep — excluded from tier-1 (`make test`), "
        "run by `make test-full`")
