"""Quantized golden-token harness + engine threading (SERVING.md
§Quantization).

``golden_decode_quant.json`` pins the quantized greedy streams per
(arch, format) with the same recipe as ``golden_decode.json``:
``_outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
prefill_chunk=4, quantization=fmt))``.  The policy is two gates:

1. *Exact pin* — a quantized stream must reproduce its own committed
   golden byte-identically (determinism + cross-engine parity stay
   hard gates; quantization never relaxes them).
2. *Token-match floor* — the fraction of tokens equal to the bf16
   golden must clear ``quantize.golden_token_match_floor(arch, fmt)``
   (quantization error flips argmax only at near-ties; the floor
   catches a broken dequant path at golden-regeneration time).

The bf16 golden itself must stay byte-identical with quantization off
— asserted directly in tests/test_paged.py (dense == golden) and
re-checked here via the qformat-off engine.
"""
import json
import pathlib

import pytest

from repro.configs import get_smoke_config
from repro.models import quantize
from repro.serving import (PagedPipelinedEngine, PagedServingEngine,
                           PipelinedEngine, Request, ServingEngine)

PROMPTS = [[5, 6, 7, 2, 9, 3, 8, 1], [9, 10, 4], [11, 3, 5, 7, 2]]

_HERE = pathlib.Path(__file__).parent
_GOLDEN_BF16 = json.loads((_HERE / "golden_decode.json").read_text())
_GOLDEN_QUANT = json.loads((_HERE / "golden_decode_quant.json").read_text())

QUANT_ARCHS = ["smollm-360m", "mixtral-8x7b", "falcon-mamba-7b",
               "zamba2-7b", "gemma3-12b"]
#: tier split (TOOLING.md §Test tiers): one arch in tier-1, rest tier2
SWEEP_ARCHS = [QUANT_ARCHS[0]] + [
    pytest.param(a, marks=pytest.mark.tier2) for a in QUANT_ARCHS[1:]]


def _outputs(eng, new_tokens=5):
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=new_tokens))
    return {r.id: r.out_tokens for r in eng.run()}


def _match_frac(outs, ref):
    match = tot = 0
    for i, toks in outs.items():
        for a, b in zip(toks, ref[i]):
            tot += 1
            match += int(a == b)
    return match / tot


@pytest.mark.parametrize("fmt", ["int8", "int4"])
@pytest.mark.parametrize("arch", SWEEP_ARCHS)
def test_quant_golden(arch, fmt):
    cfg = get_smoke_config(arch)
    golden = {int(i): toks
              for i, toks in _GOLDEN_QUANT[arch][fmt].items()}
    bf16 = {int(i): toks for i, toks in _GOLDEN_BF16[arch].items()}

    slot = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                  prefill_chunk=4, quantization=fmt))
    assert slot == golden          # gate 1: exact pin to own golden
    frac = _match_frac(slot, bf16)
    floor = quantize.golden_token_match_floor(arch, fmt)
    assert frac >= floor, (frac, floor)   # gate 2: tolerance vs bf16

    # cross-engine parity is unchanged by the format: paged == slot
    paged = _outputs(PagedServingEngine(cfg, max_rows=2, max_len=32,
                                        block_size=8, prefill_chunk=4,
                                        quantization=fmt))
    assert paged == slot


def test_bf16_stream_unchanged_with_quantization_off():
    cfg = get_smoke_config("smollm-360m")
    import jax
    eng = ServingEngine(cfg, max_batch=3, cache_len=32, prefill_chunk=4,
                        quantization=None)
    assert eng.quantization is None
    assert not any(quantize.is_quantized(leaf) for leaf in jax.tree.leaves(
        eng.params, is_leaf=quantize.is_quantized))
    outs = _outputs(eng)
    assert outs == {int(i): t
                    for i, t in _GOLDEN_BF16["smollm-360m"].items()}
    # "bf16" normalizes to the off state (same jit programs, same HLO)
    assert ServingEngine(cfg, max_batch=3, cache_len=32, prefill_chunk=4,
                         quantization="bf16").quantization is None


def test_all_engines_agree_quantized():
    """The format must be invisible to the engine layer: all four
    engines produce the same int8 stream (the quant analogue of the
    dense cross-engine parity sweeps)."""
    cfg = get_smoke_config("smollm-360m")
    ref = _outputs(ServingEngine(cfg, max_batch=3, cache_len=32,
                                 prefill_chunk=4, quantization="int8"))
    assert ref == {int(i): t for i, t in
                   _GOLDEN_QUANT["smollm-360m"]["int8"].items()}
    assert _outputs(PipelinedEngine(
        cfg, n_stages=2, max_batch=3, cache_len=32, prefill_chunk=4,
        quantization="int8")) == ref
    assert _outputs(PagedServingEngine(
        cfg, max_rows=3, max_len=32, block_size=8, prefill_chunk=4,
        quantization="int8")) == ref
    assert _outputs(PagedPipelinedEngine(
        cfg, n_stages=2, max_rows=3, max_len=32, block_size=8,
        prefill_chunk=4, quantization="int8")) == ref


def test_pipelined_stages_carry_packed_leaves():
    """Stage slicing must preserve packed leaves: each stage's params
    hold quant dicts for its block slice, and the quantized weight
    bytes are genuinely smaller than the bf16 tree."""
    cfg = get_smoke_config("smollm-360m")
    eng = PipelinedEngine(cfg, n_stages=2, max_batch=2, cache_len=16,
                          prefill_chunk=4, quantization="int8")
    import jax
    n_packed = 0
    for st in eng.stages:
        blocks = st.params.get("blocks", {})
        n_packed += sum(1 for leaf in jax.tree.leaves(
            blocks, is_leaf=quantize.is_quantized)
            if quantize.is_quantized(leaf))
    assert n_packed > 0
    dense = PipelinedEngine(cfg, n_stages=2, max_batch=2, cache_len=16,
                            prefill_chunk=4)
    def nbytes(t):
        return sum(x.nbytes for x in jax.tree.leaves(t))
    assert nbytes(eng.params) < nbytes(dense.params)
