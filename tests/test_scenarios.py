"""Scenario registry coverage: every registered scenario builds and
runs for every strategy with sane metrics; the dynamics (MMPP, diurnal,
churn, tiered topology) behave as specified; and the failure-churn
scenario demonstrates the kappa-diversity constraint's purpose."""
import numpy as np
import pytest

from repro.core.experiment import STRATEGIES, spawn_rng
from repro.core.network import (TIER_CLOUD, TIER_DEVICE, TIER_ED, TIER_ES,
                                make_tiered_network)
from repro.experiments.runner import make_grid, run_grid
from repro.experiments.scenarios import (DiurnalModulation, MMPPModulation,
                                         get_scenario, list_scenarios)

# every-strategy grid coverage runs the classic six plus the smallest
# scale_load populations (the larger ones are exercised by
# tests/test_vectorized_replay.py and benchmarks/scale_load.py)
SCENARIOS = ("baseline", "bursty_mmpp", "diurnal", "failure_churn",
             "skewed_mix", "tiered", "scale_load_10",
             "scale_load_tiered_10")
STRATS = tuple(STRATEGIES)


def test_registry_contents():
    from repro.experiments.scenarios import SCALE_LOAD_USERS
    assert {"baseline", "bursty_mmpp", "diurnal",
            "failure_churn", "tiered"} <= set(list_scenarios())
    assert 200 in SCALE_LOAD_USERS and max(SCALE_LOAD_USERS) >= 500
    for n in SCALE_LOAD_USERS:
        assert f"scale_load_{n}" in list_scenarios()
        assert f"scale_load_tiered_{n}" in list_scenarios()
    with pytest.raises(KeyError):
        get_scenario("no_such_scenario")
    for name, desc in list_scenarios().items():
        assert desc, name


def test_scale_load_topology_scales_with_population():
    """scale_load_N grows users AND nodes: the 200-user metro has
    proportionally more EDs/ESs; the tiered variant gains devices."""
    small = get_scenario("scale_load_10").build_network(spawn_rng(0))
    big = get_scenario("scale_load_200").build_network(spawn_rng(0))
    assert small.n_users == 10 and big.n_users == 200
    assert big.n_nodes > small.n_nodes
    assert big.is_es.sum() > small.is_es.sum()
    tiered = get_scenario("scale_load_tiered_200").build_network(
        spawn_rng(0))
    assert tiered.n_users == 200
    assert (tiered.tier == TIER_DEVICE).sum() >= 4
    assert (tiered.tier == TIER_CLOUD).sum() >= 1


@pytest.fixture(scope="module")
def grid_rows():
    """One short trial per (scenario, strategy), via the parallel
    runner itself (doubles as an integration test of the fan-out)."""
    specs = make_grid(seeds=(0,), strategies=STRATS, scenarios=SCENARIOS,
                      horizon_slots=8)
    return {(r["scenario"], r["strategy"]): r for r in run_grid(specs)}


@pytest.mark.parametrize("scenario", SCENARIOS)
@pytest.mark.parametrize("strategy", STRATS)
def test_every_scenario_runs_every_strategy(grid_rows, scenario, strategy):
    r = grid_rows[(scenario, strategy)]
    assert r["generated"] > 0
    assert 0.0 <= r["on_time"] <= r["completed"] <= 1.0
    assert r["total_cost"] > 0.0
    assert r["scenario"] == scenario and r["strategy"] == strategy


def test_mmpp_modulation_switches_states():
    mod = MMPPModulation(spawn_rng(0))
    mults = {mod(t) for t in range(400)}
    assert mults == set(mod.mults)          # both states visited
    assert min(mults) < 1.0 < max(mults)


def test_diurnal_modulation_is_sinusoidal():
    mod = DiurnalModulation(spawn_rng(1))
    vals = np.array([mod(t) for t in range(96)])
    assert (vals >= 0.0).all()
    assert vals.min() < 0.6 and vals.max() > 1.4   # amplitude realized
    # one full period apart -> same value
    assert mod(0) == pytest.approx(mod(48), abs=1e-9)


def test_churn_schedule_covers_every_es():
    from repro.core.network import make_network
    scen = get_scenario("failure_churn")
    net = make_network(np.random.default_rng(2))
    events = scen.churn_schedule(net, spawn_rng(3), horizon_slots=60)
    failed = {e.node for e in events if e.action == "fail"}
    recovered = {e.node for e in events if e.action == "recover"}
    assert failed == set(np.flatnonzero(net.is_es))   # every ES hit
    assert recovered == failed                        # and comes back
    for e in events:
        assert 0 < e.slot


def test_tiered_network_topology():
    net = make_tiered_network(np.random.default_rng(4))
    for t in (TIER_DEVICE, TIER_ED, TIER_ES, TIER_CLOUD):
        assert len(net.nodes_in_tier(t)) > 0
    assert np.isfinite(net.net_ms).all()              # fully routable
    dev = net.nodes_in_tier(TIER_DEVICE)
    cloud = net.nodes_in_tier(TIER_CLOUD)
    assert net.R[cloud].sum(axis=1).min() > net.R[dev].sum(axis=1).max()
    assert (net.tier[net.user_ed] == TIER_DEVICE).all()  # users enter low


def test_churn_kappa_diversity_outperforms_single_site():
    """The headline C6 claim: under rolling ES outages the
    kappa-constrained proposal completes more tasks than a kappa=1
    ablation whose backbone may concentrate on one (doomed) server."""
    specs = make_grid(seeds=range(3), strategies=("proposal",),
                      scenarios=("failure_churn",), horizon_slots=40,
                      kappas=(1, 12))
    rows = run_grid(specs)
    comp = {k: np.mean([r["completed"] for r in rows if r["kappa"] == k])
            for k in (1, 12)}
    assert comp[12] > comp[1]
