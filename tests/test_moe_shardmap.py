"""shard_map MoE variants (M2 slice-dispatch, M3 capacity-sharded) vs the
single-host dispatch oracle — subprocess with 8 forced devices."""
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_smoke_config
    from repro.models.moe import moe_init, moe_apply
    from repro.sharding.specs import use_mesh_rules

    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    base = get_smoke_config("mixtral-8x7b")
    for ne, k, label in [(4, 2, "M2 slice-dispatch"),
                         (3, 2, "M3 cap-sharded")]:
        cfg = dataclasses.replace(base, n_experts=ne, experts_per_token=k,
                                  capacity_factor=32.0)
        params = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        os.environ.pop("REPRO_MOE_SHARDMAP", None)
        y_ref, _ = moe_apply(params, x, cfg)
        os.environ["REPRO_MOE_SHARDMAP"] = "1"
        with mesh, use_mesh_rules(mesh):
            y, aux = jax.jit(lambda p, xx: moe_apply(p, xx, cfg))(params, x)
        err = float(jnp.max(jnp.abs(y - y_ref)))
        assert err < 1e-3, (label, err)
        print(label, "OK", err)
""")


def test_moe_shardmap_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], cwd=".",
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert r.stdout.count("OK") == 2
