"""Seeded fallback for `hypothesis` property tests.

Tier-1 must collect and run green whether or not `hypothesis` is
installed.  This module provides drop-in replacements for the small
subset of the hypothesis API the suite uses::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st

`given(**strategies)` turns the test into a loop over
``settings(max_examples=...)`` deterministic pseudo-random examples.
The example stream is seeded from a stable hash of the test's qualified
name, so a failure reproduces identically on every run and machine
(no PYTHONHASHSEED dependence).  On failure the falsifying example is
attached to the raised error, mimicking hypothesis' report.

Failure reporting is robust to hostile exceptions: an exception whose
``args[0]`` is not a string (``OSError(2, "...")`` renders from
``errno``/``strerror``, ignoring args mutation) or that is annotated
by several nested ``given`` layers used to silently *lose* the
per-case reproduction info.  Every annotation is therefore (a)
appended to ``e._propcheck_notes``, (b) printed to stderr (pytest
shows captured stderr for failing tests), and (c) best-effort
prepended to string ``args`` — so the seed + case index survive no
matter how the exception renders (tests/test_propcheck.py).
"""
from __future__ import annotations

import functools
import inspect
import sys
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A draw rule: rng -> value (hypothesis-strategy stand-in)."""

    def __init__(self, draw, label: str):
        self._draw = draw
        self.label = label

    def example_from(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):  # pragma: no cover - debug aid
        return self.label


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.integers(2)),
                              "booleans()")

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        elements = list(elements)
        return SearchStrategy(
            lambda rng: elements[int(rng.integers(len(elements)))],
            f"sampled_from({elements!r})")


st = strategies


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Accepts (and mostly ignores) hypothesis settings kwargs."""
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def attach_note(e: BaseException, note: str):
    """Attach a reproduction note so it survives any exception type.

    Mutating ``e.args`` alone silently loses the note for exceptions
    that do not render their args (``OSError`` prints from
    ``errno``/``strerror``) and garbles multi-arg constructors, so the
    note also lands on ``e._propcheck_notes`` (machine-readable, one
    entry per nested ``given`` layer, innermost first) and on stderr
    (pytest surfaces captured stderr for failing tests).
    """
    notes = getattr(e, "_propcheck_notes", None)
    if notes is None:
        notes = []
        try:
            e._propcheck_notes = notes
        except Exception:  # __slots__-only exception: stderr still has it
            pass
    notes.append(note)
    print(f"_propcheck: {note}", file=sys.stderr)
    try:
        if e.args and isinstance(e.args[0], str):
            e.args = (f"{note} -- {e.args[0]}",) + e.args[1:]
        else:
            e.args = (note,) + tuple(e.args)
    except Exception:  # exceptions may refuse args mutation entirely
        pass


def given(**strats):
    def deco(fn):
        seed = zlib.crc32(fn.__qualname__.encode("utf-8"))

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_propcheck_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(seed)
            for i in range(n):
                drawn = {k: s.example_from(rng) for k, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    attach_note(
                        e, f"falsifying example #{i}: {drawn!r} "
                           f"[{fn.__qualname__}: seed={seed}, "
                           f"case {i + 1}/{n}]")
                    raise
        wrapper._propcheck_max_examples = getattr(
            fn, "_propcheck_max_examples", DEFAULT_MAX_EXAMPLES)
        # hide the drawn parameters from pytest's fixture resolution
        # (functools.wraps exposes the original signature via __wrapped__)
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return deco
