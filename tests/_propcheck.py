"""Seeded fallback for `hypothesis` property tests.

Tier-1 must collect and run green whether or not `hypothesis` is
installed.  This module provides drop-in replacements for the small
subset of the hypothesis API the suite uses::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _propcheck import given, settings, strategies as st

`given(**strategies)` turns the test into a loop over
``settings(max_examples=...)`` deterministic pseudo-random examples.
The example stream is seeded from a stable hash of the test's qualified
name, so a failure reproduces identically on every run and machine
(no PYTHONHASHSEED dependence).  On failure the falsifying example is
attached to the raised error, mimicking hypothesis' report.
"""
from __future__ import annotations

import functools
import inspect
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A draw rule: rng -> value (hypothesis-strategy stand-in)."""

    def __init__(self, draw, label: str):
        self._draw = draw
        self.label = label

    def example_from(self, rng: np.random.Generator):
        return self._draw(rng)

    def __repr__(self):  # pragma: no cover - debug aid
        return self.label


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` (subset)."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            f"integers({min_value}, {max_value})")

    @staticmethod
    def floats(min_value: float, max_value: float) -> SearchStrategy:
        return SearchStrategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            f"floats({min_value}, {max_value})")

    @staticmethod
    def booleans() -> SearchStrategy:
        return SearchStrategy(lambda rng: bool(rng.integers(2)),
                              "booleans()")

    @staticmethod
    def sampled_from(elements) -> SearchStrategy:
        elements = list(elements)
        return SearchStrategy(
            lambda rng: elements[int(rng.integers(len(elements)))],
            f"sampled_from({elements!r})")


st = strategies


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    """Accepts (and mostly ignores) hypothesis settings kwargs."""
    def deco(fn):
        fn._propcheck_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_propcheck_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode("utf-8")))
            for i in range(n):
                drawn = {k: s.example_from(rng) for k, s in strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    e.args = (f"falsifying example #{i}: {drawn!r} -- "
                              f"{e.args[0] if e.args else ''}",) + e.args[1:]
                    raise
        wrapper._propcheck_max_examples = getattr(
            fn, "_propcheck_max_examples", DEFAULT_MAX_EXAMPLES)
        # hide the drawn parameters from pytest's fixture resolution
        # (functools.wraps exposes the original signature via __wrapped__)
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        return wrapper
    return deco
