"""MoE dispatch properties: equivalence with dense compute, capacity
semantics, gate normalization."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # fall back to the seeded shim (see _propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models.moe import moe_apply, moe_init


def _cfg(ne=4, k=2, cap=8.0):
    base = get_smoke_config("mixtral-8x7b")
    return dataclasses.replace(base, n_experts=ne, experts_per_token=k,
                               capacity_factor=cap)


def test_moe_matches_dense_at_high_capacity():
    """With capacity >> tokens no token drops: sort-based dispatch must
    equal the dense (all-experts) weighted computation."""
    cfg = _cfg(cap=64.0)
    key = jax.random.PRNGKey(0)
    params = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, cfg.d_model))
    y, aux = moe_apply(params, x, cfg)
    assert float(aux["moe_drop_frac"]) == 0.0

    # dense reference
    logits = x @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / gates.sum(-1, keepdims=True)
    y_ref = jnp.zeros_like(x)
    for e in range(cfg.n_experts):
        g = jax.nn.silu(x @ params["we_gate"][e]) * (x @ params["we_up"][e])
        oe = g @ params["we_down"][e]
        w = jnp.sum(jnp.where(idx == e, gates, 0.0), axis=-1)
        y_ref = y_ref + w[:, None] * oe
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4


@given(seed=st.integers(0, 100), cap=st.floats(0.3, 1.0))
@settings(max_examples=10, deadline=None)
def test_moe_capacity_drops_bounded(seed, cap):
    cfg = _cfg(cap=cap)
    params = moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (64, cfg.d_model))
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all()
    assert 0.0 <= float(aux["moe_drop_frac"]) < 1.0


def test_moe_aux_loss_positive_and_balanced_optimum():
    cfg = _cfg()
    params = moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (128, cfg.d_model))
    _, aux = moe_apply(params, x, cfg)
    # Switch aux loss >= router_aux_weight at perfect balance
    assert float(aux["moe_aux_loss"]) >= cfg.router_aux_weight * 0.5
