"""Prefix sharing: refcount/COW ledger semantics + engine behavior.

Unit-level lockdown of the shared-ownership model (SERVING.md §Prefix
sharing): hand-traced refcount lifecycles (hit-then-release,
hit-then-preempt), copy-on-write isolation, the preemption regression
(a victim's shared blocks must NOT return to the free list while a
survivor references them), architecture gating, and the engine-side
prefill skip + effective-capacity coupling.
"""
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.kvcache import PagedCache
from repro.serving.engine import Request
from repro.serving.scheduler import CapacityView, make_policy
from repro.serving.testbed import FakeEngine, fake_stream

BS = 8
PRE = [5, 6, 7, 2, 9, 3, 8, 1]          # exactly one full block


def _cache(num_blocks=8, max_rows=3, **kw):
    cfg = get_smoke_config("smollm-360m")
    return PagedCache(cfg, max_rows=max_rows, max_len=32, block_size=BS,
                      num_blocks=num_blocks, share_prefixes=True, **kw)


# ----------------------------------------------------------------------
# ledger: match, refcounts, COW
# ----------------------------------------------------------------------
def test_admit_maps_shared_prefix_with_refcount_bump():
    pc = _cache()
    t0, t1 = PRE + [4, 2], PRE + [9, 9, 1]
    assert pc.admit(0, len(t0) + 1, tokens=t0)
    assert pc.hit_tokens(0) == 0            # first arrival: cold
    assert pc.probe_hit(t1) == 1            # index now holds PRE's block
    assert pc.admit(1, len(t1) + 1, tokens=t1)
    assert pc.hit_tokens(1) == BS           # one full block skipped
    shared = int(pc.tables[0, 0])
    assert int(pc.tables[1, 0]) == shared
    assert pc._ref[shared] == 2
    assert pc.blocks_saved == 1 and pc.n_prefix_hits == 1
    assert pc.prefix_tokens_hit == BS
    pc.check()


def test_partial_block_prefix_never_matches():
    """Only *full* blocks are content-addressed: a 7-token common
    prefix (one short of the block) shares nothing."""
    pc = _cache()
    t0, t1 = PRE[:7] + [1, 1], PRE[:7] + [2, 2]
    assert pc.admit(0, len(t0) + 1, tokens=t0)
    assert pc.probe_hit(t1) == 0
    assert pc.admit(1, len(t1) + 1, tokens=t1)
    assert pc.hit_tokens(1) == 0
    assert int(pc.tables[0, 0]) != int(pc.tables[1, 0])
    pc.check()


def test_cow_write_isolates_the_writer():
    """A write into a refcount>1 block moves the writer to a fresh
    block (queued as a device pool copy) and leaves the other owner's
    mapping untouched."""
    pc = _cache()
    t0, t1 = PRE + [4, 2], PRE + [9, 9, 1]
    pc.admit(0, len(t0) + 1, tokens=t0)
    pc.admit(1, len(t1) + 1, tokens=t1)
    shared = int(pc.tables[1, 0])
    assert pc.ensure(1, 3)                  # write inside the shared block
    fresh = int(pc.tables[1, 0])
    assert fresh != shared
    assert int(pc.tables[0, 0]) == shared   # row 0 untouched
    assert pc._ref[shared] == 1 and pc._ref[fresh] == 1
    assert pc.take_pending_copies() == [(shared, fresh)]
    assert pc.take_pending_copies() == []   # drained exactly once
    assert pc.n_cow_copies == 1
    pc.check()


def test_cow_pool_exhaustion_returns_false_and_keeps_sharing():
    """COW with an empty free list reports failure (the engine's grow
    loop preempts) without corrupting the shared mapping."""
    pc = _cache(num_blocks=3)
    t0, t1 = PRE + [4, 2], PRE + [9, 9, 1]
    pc.admit(0, len(t0) + 1, tokens=t0)     # 2 blocks
    pc.admit(1, len(t1) + 1, tokens=t1)     # +1 fresh, pool now empty
    assert pc.free_blocks == 0
    shared = int(pc.tables[1, 0])
    assert not pc.ensure(1, 3)              # COW needs a block: none
    assert int(pc.tables[1, 0]) == shared   # mapping unchanged
    assert pc._ref[shared] == 2
    assert pc.pending_copies == []
    pc.check()


def test_exclusive_indexed_block_deindexes_on_write():
    """A write into a block the row owns exclusively but that is still
    indexed must drop the index entry — the content is about to
    diverge from the indexed token prefix."""
    pc = _cache()
    t0 = PRE + [4, 2]
    pc.admit(0, len(t0) + 1, tokens=t0)
    blk = int(pc.tables[0, 0])
    assert blk in pc._block_key
    assert pc.ensure(0, 3)                  # write inside own block
    assert blk not in pc._block_key
    assert pc.probe_hit(PRE + [1]) == 0     # no stale match possible
    pc.check()


# ----------------------------------------------------------------------
# hand-traced refcount lifecycles
# ----------------------------------------------------------------------
def test_lifecycle_hit_then_release():
    """Owner releases first, then the sharer: the block survives the
    first release (ref 2 -> 1), leaves the index and returns to the
    free list only on the last (ref 1 -> 0)."""
    pc = _cache()
    t0, t1 = PRE + [4, 2], PRE + [9, 9, 1]
    pc.admit(0, len(t0) + 1, tokens=t0)
    pc.admit(1, len(t1) + 1, tokens=t1)
    shared = int(pc.tables[0, 0])
    pc.release(0)                           # original owner done
    pc.check()
    assert pc._ref[shared] == 1
    assert shared not in pc._free["attn"]
    assert shared in pc._block_key          # still matchable
    assert pc.probe_hit(PRE + [7]) == 1
    pc.release(1)                           # last owner done
    pc.check()
    assert pc._ref[shared] == 0
    assert shared in pc._free["attn"]
    assert shared not in pc._block_key
    assert pc.used_blocks == 0


def test_lifecycle_hit_then_preempt():
    """Preempting the *sharer* (release via the same refcount path)
    keeps the block resident and indexed for its re-admission, which
    matches again without allocating."""
    pc = _cache()
    t0, t1 = PRE + [4, 2], PRE + [9, 9, 1]
    pc.admit(0, len(t0) + 1, tokens=t0)
    pc.admit(1, len(t1) + 1, tokens=t1)
    shared = int(pc.tables[0, 0])
    free0 = pc.free_blocks
    pc.release(1)                           # preemption frees row 1
    pc.check()
    assert pc._ref[shared] == 1             # row 0 still owns it
    assert shared not in pc._free["attn"]
    assert pc.probe_hit(t1) == 1            # resume will re-match
    assert pc.admit(1, len(t1) + 1, tokens=t1)
    assert int(pc.tables[1, 0]) == shared
    assert pc._ref[shared] == 2
    assert pc.free_blocks == free0          # round-trip leaked nothing
    pc.check()


# ----------------------------------------------------------------------
# regression: preemption must not free still-referenced blocks
# ----------------------------------------------------------------------
def test_preempt_victim_with_shared_blocks_keeps_them_resident():
    """THE regression this PR's refcounted ``release`` exists for: the
    pre-sharing ledger returned every held block to the free list on
    preemption — with sharing, that hands a surviving request's prefix
    block to the next allocation.  Drive the real ``_PagedEngine``
    preemption path and require the survivor's mapping intact."""
    eng = FakeEngine(max_rows=2, max_len=32, block_size=BS, num_blocks=6,
                     prefill_chunk=4, prefix_sharing=True)
    r0 = Request(id=0, prompt=PRE + [4, 2], max_new_tokens=8)
    r1 = Request(id=1, prompt=PRE + [9, 9, 1], max_new_tokens=8)
    eng.submit(r0)
    eng.submit(r1)
    eng.step()                              # both admitted, prefix shared
    row0 = eng.rows.index(r0)
    row1 = eng.rows.index(r1)
    shared = int(eng.pc.tables[row0, 0])
    assert int(eng.pc.tables[row1, 0]) == shared
    assert eng.pc._ref[shared] == 2
    eng._preempt(row1)                      # victim holds shared blocks
    assert eng.n_preemptions == 1
    assert eng.pc._ref[shared] == 1
    assert shared not in eng.pc._free["attn"], \
        "preemption freed a block the survivor still references"
    assert int(eng.pc.tables[row0, 0]) == shared
    eng.pc.check()
    # ... and the drained session still ends whole + oracle-exact
    done = {r.id: r.out_tokens for r in eng.run()}
    assert done[0] == fake_stream(r0.prompt, 8)
    assert done[1] == fake_stream(r1.prompt, 8)
    eng.pc.check()
    assert eng.pc.used_blocks == 0


# ----------------------------------------------------------------------
# engine: divergence isolation, prefill skip, capacity coupling
# ----------------------------------------------------------------------
def test_divergent_streams_never_cross_contaminate():
    """Two requests sharing a prefix then diverging: each stream equals
    its own oracle continuation — neither observes the other's
    writes (the testbed recurrence is position+token-exact, so any
    table/COW mixup changes tokens)."""
    for k in (1, 8):
        eng = FakeEngine(max_rows=2, max_len=64, block_size=BS,
                         num_blocks=10, prefill_chunk=4, decode_steps=k,
                         prefix_sharing=True)
        p0, p1 = PRE + [4, 2], PRE + [9, 9, 1]
        eng.submit(Request(id=0, prompt=p0, max_new_tokens=10))
        eng.submit(Request(id=1, prompt=p1, max_new_tokens=10))
        done = {r.id: r.out_tokens for r in eng.run()}
        assert eng.pc.n_prefix_hits == 1
        assert done[0] == fake_stream(p0, 10)
        assert done[1] == fake_stream(p1, 10)
        eng.pc.check()
        assert eng.pc.used_blocks == 0


def test_cache_hit_admission_prefills_only_the_tail():
    """The skipped span never costs a prefill dispatch:
    ``engine.prefill_tokens`` (the t_first/admission budget) drops by
    exactly the matched span."""
    def run(sharing):
        eng = FakeEngine(max_rows=2, max_len=32, block_size=BS,
                         num_blocks=8, prefill_chunk=4,
                         prefix_sharing=sharing)
        for i, p in enumerate((PRE + [4, 2], PRE + [9, 9, 1])):
            eng.submit(Request(id=i, prompt=list(p), max_new_tokens=4))
        done = {r.id: r.out_tokens for r in eng.run()}
        return eng, done

    on, out_on = run(True)
    off, out_off = run(False)
    assert out_on == out_off
    assert on.pc.prefix_tokens_hit == BS
    assert on.prefill_tokens == off.prefill_tokens - BS


def test_probe_hit_shrinks_ec_admission_demand():
    """The effective-capacity admission test models a prefix hit as
    reduced service demand: with the shared span discounted the
    deficit vanishes and the request ADMITs instead of DEFERring."""
    policy = make_policy("edf_ec")
    req = Request(id=0, prompt=PRE + [4, 2], max_new_tokens=4,
                  qos="interactive")
    req.t_submit = 0
    toks = (req.prompt + req.out_tokens)[:-1]
    need = -(-len(req.prompt) // BS)        # 2 blocks demanded
    base = dict(free_tokens=BS, total_tokens=8 * BS, granule=BS)
    verdict_cold, _ = policy.admission_test(
        req, 1, CapacityView(**base))
    verdict_hit, _ = policy.admission_test(
        req, 1, CapacityView(**base, shared_blocks=lambda t: 1))
    assert need == 2
    assert verdict_cold == "defer"          # 2 needed, 1 free
    assert verdict_hit == "admit"           # hit discounts the stem
    # the engine wires the real probe into its view
    eng = FakeEngine(num_blocks=8, prefix_sharing=True)
    view = eng._capacity_view()
    assert view.shared_blocks == eng.pc.probe_hit  # the real probe
    assert view.shared_blocks(toks) == 0    # cold index


# ----------------------------------------------------------------------
# gating + disabled path
# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch,supported", [
    ("smollm-360m", True), ("qwen2-72b", True),
    ("mixtral-8x7b", False),      # SWA ring is per-request state
    ("gemma3-12b", False),        # SWA
    ("falcon-mamba-7b", False),   # SSM state
    ("zamba2-7b", False),         # SSM hybrid
])
def test_sharing_gated_to_pure_attention_archs(arch, supported):
    pc = PagedCache(get_smoke_config(arch), max_rows=2, max_len=32,
                    block_size=BS, share_prefixes=True)
    assert pc.sharing_supported == supported
    assert pc.share_prefixes == supported


def test_sharing_off_is_the_exclusive_ledger():
    """``share_prefixes=False`` (or an unsupported arch): admission
    with tokens never matches, refcounts stay 0/1, and behavior is the
    historical exclusive-ownership ledger bit-for-bit."""
    cfg = get_smoke_config("smollm-360m")
    pc = PagedCache(cfg, max_rows=3, max_len=32, block_size=BS,
                    num_blocks=8, share_prefixes=False)
    t0, t1 = PRE + [4, 2], PRE + [9, 9, 1]
    assert pc.admit(0, len(t0) + 1, tokens=t0)
    assert pc.probe_hit(t1) == 0
    assert pc.admit(1, len(t1) + 1, tokens=t1)
    assert pc.hit_tokens(1) == 0
    assert int(pc.tables[0, 0]) != int(pc.tables[1, 0])
    assert pc.n_prefix_hits == pc.blocks_saved == 0
    assert not pc._prefix_index
    pc.check()
