"""Scheduler policy unit tests on the FakeEngine testbed.

Every decision point of ``serving/scheduler.py`` pinned without JAX
dispatch: EDF ordering, effective-capacity admission accept/reject
boundaries, deadline-aware victim selection, slack-aging starvation
avoidance (bounded promotion), hand-computed virtual-queue drift, and
the rejection/resume bookkeeping regressions."""
import pytest

from repro.core.effective_capacity import latency_budget
from repro.serving.engine import Request
from repro.serving.scheduler import (
    ADMIT, DEFER, REJECT, CapacityView, EDFCapacityPolicy, EDFPolicy,
    FIFOPolicy, SchedulerPolicy, get_qos, goodput, make_policy,
    per_class_stats, slo_met)
from repro.serving.testbed import FakeEngine, fake_stream


def _req(i, qos="standard", t_submit=0, **kw):
    r = Request(id=i, prompt=kw.pop("prompt", [1, 2, 3]), qos=qos, **kw)
    r.t_submit = t_submit
    return r


# ----------------------------------------------------------------------
# policy registry / FIFO equivalence
# ----------------------------------------------------------------------
def test_make_policy_registry():
    assert isinstance(make_policy(None), FIFOPolicy)
    assert make_policy("edf").name == "edf"
    assert make_policy("edf_ec").name == "edf_ec"
    p = EDFPolicy()
    assert make_policy(p) is p
    with pytest.raises(ValueError):
        make_policy("lottery")


def test_fifo_is_the_historical_discipline():
    """Queue-head admission, newest-admitted victim, no admission
    test, unlimited preemptions — the pre-policy engine behaviour."""
    pol = SchedulerPolicy()
    q = [_req(0, t_submit=5), _req(1, t_submit=0)]
    assert pol.next_admission(q, 10) is q[0]       # head, not earliest
    cands = [(3, q[0]), (1, q[1])]                 # admission order
    assert pol.select_victim(cands, 10, needy=3) == 1   # newest = LIFO
    assert pol.admission_test(q[0], 10, None) == (ADMIT, None)
    assert pol.max_preemptions is None


# ----------------------------------------------------------------------
# EDF ordering
# ----------------------------------------------------------------------
def test_edf_orders_by_class_deadline():
    pol = EDFPolicy()
    q = [_req(0, "batch"), _req(1, "standard"), _req(2, "interactive")]
    assert pol.next_admission(q, 0).id == 2   # ttft 16 < 48 < 512
    q.pop(2)
    assert pol.next_admission(q, 0).id == 1


def test_edf_resume_deadline_is_next_token():
    """A preempted mid-stream request's deadline is its *next token*
    TPOT due-date — it can outrank a fresher arrival."""
    pol = EDFPolicy()
    resume = _req(0, "standard", t_submit=0, out_tokens=[9, 9, 9])
    resume.t_admit, resume.t_first = 1, 2
    fresh = _req(1, "interactive", t_submit=10)
    # dl(resume) = 2 + 4.0 * 4 = 18 < dl(fresh) = 10 + 16 = 26
    assert pol.deadline(resume) == 18
    assert pol.deadline(fresh) == 26
    assert pol.next_admission([fresh, resume], 12).id == 0


def test_edf_tiebreak_deterministic():
    pol = EDFPolicy()
    q = [_req(7, "standard"), _req(3, "standard")]
    assert pol.next_admission(q, 0).id == 3   # equal key -> lowest id


# ----------------------------------------------------------------------
# effective-capacity admission boundaries
# ----------------------------------------------------------------------
def _view(free_blocks, granule=8, total=16):
    return CapacityView(free_tokens=free_blocks * granule,
                        total_tokens=total * granule, granule=granule)


def test_ec_admits_when_it_fits_now():
    pol = EDFCapacityPolicy(service_shape=2.0, service_scale=0.5)
    req = _req(0, "interactive", prompt=[1] * 20)
    assert pol.admission_test(req, 0, _view(3))[0] == ADMIT  # 20tok=3blk


def test_ec_rejects_exhausted_ttft_slack():
    pol = EDFCapacityPolicy(service_shape=2.0, service_scale=0.5)
    req = _req(0, "interactive", t_submit=0)
    verdict, msg = pol.admission_test(req, 17, _view(0))  # ttft 16 < 17
    assert verdict == REJECT and "interactive" in msg


def test_ec_reject_defer_boundary_matches_latency_budget():
    """The verdict flips exactly where eq. 21's Chernoff inversion says
    the pool cannot free the deficit within remaining TTFT slack."""
    shape, scale = 2.0, 0.5
    pol = EDFCapacityPolicy(service_shape=shape, service_scale=scale)
    cls = get_qos("standard")
    deficit = 4
    view = _view(0)
    need_tok = deficit * view.granule  # 4-block deficit, 0 free
    d = latency_budget(shape, scale, cls.eps, float(deficit))
    # submit so that remaining slack straddles d
    tight = _req(0, "standard", t_submit=0, prompt=[1] * need_tok,
                 max_new_tokens=0)
    t_fail = int(cls.ttft - d) + 1      # slack = ttft - t < d
    t_ok = int(cls.ttft - d) - 1        # slack > d
    assert pol.admission_test(tight, t_fail, view)[0] == REJECT
    assert pol.admission_test(tight, t_ok, view)[0] == DEFER


def test_ec_resumed_requests_always_pass():
    pol = EDFCapacityPolicy(service_shape=2.0, service_scale=0.5)
    req = _req(0, "interactive", t_submit=0, out_tokens=[4])
    req.t_admit = 1   # admitted once: contract honoured at admission
    assert pol.admission_test(req, 999, _view(0))[0] == ADMIT


def test_ec_defers_until_service_model_warm():
    """Online estimator: before MIN_SAMPLES observations the test
    must defer (plain EDF head-of-line wait), never reject on a cold
    model."""
    pol = EDFCapacityPolicy()
    req = _req(0, "standard", t_submit=0, prompt=[1] * 64)
    assert pol.admission_test(req, 1, _view(1))[0] == DEFER
    # warm it: one block freed per step across enough sample windows
    # for the EWMA to converge near the true 1 block/step rate
    horizon = pol.SAMPLE_WINDOW * (pol.MIN_SAMPLES + 8) + 2
    for t in range(1, horizon):
        pol.on_step(t, [], [])
        pol.on_free(1, t)
    shape, scale = pol.service_stats()
    assert shape is not None and shape * scale == pytest.approx(
        1.0, rel=0.2)  # per-step mean rate recovered
    assert pol.admission_test(req, 1, _view(1))[0] in (DEFER, REJECT)


# ----------------------------------------------------------------------
# victim selection
# ----------------------------------------------------------------------
def test_victim_is_most_slack_never_protected():
    pol = EDFPolicy(ttft_protect=4)
    t = 14
    # fresh interactive, deadline 2+16=18, slack 4 <= protect: immune
    prot = _req(0, "interactive", t_submit=2)
    # generating standard: dl = 4 + 4*(2+1) = 16, slack 2
    std = _req(1, "standard", t_submit=0, out_tokens=[5, 5])
    std.t_admit, std.t_first = 2, 4
    # generating batch: dl = 4 + 16*(1+1) = 36, slack 22 (most)
    bat = _req(2, "batch", t_submit=0, out_tokens=[5])
    bat.t_admit, bat.t_first = 2, 4
    cands = [(0, prot), (1, std), (2, bat)]
    assert pol.select_victim(cands, t, needy=0) == 2
    # without batch, standard is the only eligible
    assert pol.select_victim([(0, prot), (1, std)], t, needy=1) == 1
    # all protected -> None (engine falls back to self-preemption)
    assert pol.select_victim([(0, prot)], t, needy=0) is None


def test_victim_no_protection_for_already_missed():
    pol = EDFPolicy(ttft_protect=4)
    missed = _req(0, "interactive", t_submit=0)   # dl 16 < t: missed
    assert pol.select_victim([(0, missed)], 30, needy=0) == 0


def test_victim_tie_breaks_to_newest():
    pol = EDFPolicy()
    a, b = _req(0, "batch"), _req(1, "batch")
    for r in (a, b):
        r.t_admit, r.t_first = 1, 2
        r.out_tokens = [7]
    assert pol.select_victim([(0, a), (1, b)], 5, needy=0) == 1


# ----------------------------------------------------------------------
# slack aging: bounded starvation
# ----------------------------------------------------------------------
def test_slack_aging_promotes_starving_batch():
    """A batch request facing an endless stream of fresh interactive
    arrivals must be promoted within a bounded number of steps: key
    closure rate is (1 + age_rate) per step, so promotion lands by
    (ttft_batch - ttft_int) / (1 + age_rate) ~ 331 steps — well inside
    its own 512-step TTFT budget."""
    pol = EDFPolicy(age_rate=0.5)
    starving = _req(0, "batch", t_submit=0)
    promoted_at = None
    for t in range(1, 513):
        fresh = _req(100 + t, "interactive", t_submit=t)
        q = [fresh, starving]
        pol.on_step(t, q, [])
        if pol.next_admission(q, t).id == 0:
            promoted_at = t
            break
    assert promoted_at is not None and promoted_at <= 340
    assert promoted_at > 100  # and not trivially early


# ----------------------------------------------------------------------
# virtual-queue drift: hand-computed trace
# ----------------------------------------------------------------------
def test_virtual_queue_drift_matches_hand_trace():
    """Eq. (18) with zeta=1, interactive ttft=16, driven by the class's
    longest queued fresh wait:

        t=20 wait 20: H = max(1 + 20 - 16, 1) = 5
        t=21 wait 21: H = max(5 + 21 - 16, 1) = 10
        t=22 drained: H = max(10 + 0 - 16, 1) = 1
    """
    pol = EDFPolicy()
    r = _req(0, "interactive", t_submit=0)
    assert pol.vq.get("interactive") == 1.0          # floor before drift
    pol.on_step(20, [r], [])
    assert pol.vq.get("interactive") == 5.0
    pol.on_step(21, [r], [])
    assert pol.vq.get("interactive") == 10.0
    pol.on_step(22, [], [])                          # class drained
    assert pol.vq.get("interactive") == 1.0
    # admitted requests stop driving drift (t_admit set -> not queued-fresh)
    r.t_admit = 22
    pol.on_step(40, [r], [])
    assert pol.vq.get("interactive") == 1.0


def test_virtual_queue_uses_longest_wait_per_class():
    pol = EDFPolicy()
    old, young = _req(0, "interactive", t_submit=0), _req(
        1, "interactive", t_submit=15)
    pol.on_step(20, [young, old], [])
    assert pol.vq.get("interactive") == 5.0  # wait 20, not 5


def test_virtual_queue_boosts_admission_key():
    """Deadline debt pulls the whole class forward: with H_int inflated,
    a fresh interactive overtakes an otherwise-earlier standard."""
    pol = EDFPolicy(age_rate=0.0)
    std = _req(0, "standard", t_submit=0)       # dl 48
    itv = _req(1, "interactive", t_submit=40)   # dl 56: later
    assert pol.next_admission([std, itv], 40).id == 0
    pol.vq.update("interactive", 20.0, 16.0)    # H: 1 -> 5
    # key(itv) = 56 - 4.0 * (5 - 1) = 40 < 48
    assert pol.next_admission([std, itv], 40).id == 1


# ----------------------------------------------------------------------
# SLO accounting helpers
# ----------------------------------------------------------------------
def test_slo_met_boundaries():
    r = _req(0, "interactive", t_submit=0, out_tokens=[1] * 4,
             max_new_tokens=4)
    r.t_admit, r.t_first = 1, 16
    r.t_done = 16 + 6      # tpot 2.0 * (4-1) = 6: exactly on time
    assert slo_met(r)
    r.t_done = 23          # one step late on TPOT
    assert not slo_met(r)
    r.t_done, r.t_first = 23 - 6 + 6, 17   # TTFT one step late
    r.t_done = r.t_first + 6
    assert not slo_met(r)


def test_goodput_counts_rejected_and_unfinished_as_misses():
    ok = _req(0, "batch", t_submit=0, out_tokens=[1], max_new_tokens=1)
    ok.t_admit = ok.t_first = ok.t_done = 1
    rej = _req(1, "batch", t_submit=0)
    rej.error, rej.t_done = "rejected", 1
    hung = _req(2, "batch", t_submit=0)
    assert goodput([ok, rej, hung]) == pytest.approx(1 / 3)
    stats = per_class_stats([ok, rej, hung])
    assert stats["batch"]["n"] == 3
    assert stats["batch"]["rejected"] == 1
    assert stats["batch"]["goodput"] == pytest.approx(1 / 3)


# ----------------------------------------------------------------------
# regressions: rejection stamping + resume without restamping
# ----------------------------------------------------------------------
def test_admission_reject_stamps_t_done_and_class_error():
    """Requests rejected by the admission test before first admission
    get the full ``_reject`` treatment: ``t_done`` stamped, landed in
    ``engine.rejected``, class-specific error message."""
    # slow pool: latency_budget(1.0, 0.25, .05, 4 blocks) ~ 27 steps
    pol = EDFCapacityPolicy(service_shape=1.0, service_scale=0.25)
    eng = FakeEngine(max_rows=2, max_len=64, block_size=8, num_blocks=8,
                     policy=pol)
    eng.submit(Request(id=0, prompt=[2] * 32, max_new_tokens=20,
                       qos="batch"))         # hogs 4+ blocks for a while
    eng.run(max_steps=2)                     # batch admitted + running
    # needs 8 blocks now, <=4 free: the Gamma model says freeing the
    # deficit blows the 16-step interactive TTFT -> reject up front
    eng.submit(Request(id=1, prompt=[3] * 60, max_new_tokens=4,
                       qos="interactive"))
    eng.run()
    assert [r.id for r in eng.rejected] == [1]
    rej = eng.rejected[0]
    assert rej.t_done is not None and rej.t_admit is None
    assert "interactive" in rej.error and "effective-capacity" in rej.error
    assert rej.t_submit <= rej.t_done


def test_unfinished_resume_without_restamping():
    """``run()`` exhausting its step budget leaves requests in
    ``engine.unfinished``; a further ``run()`` must resume them to
    completion with their original ``t_submit`` (no duplicate
    restamping) and byte-identical streams."""
    eng = FakeEngine(max_rows=2, max_len=64)
    reqs = [Request(id=i, prompt=[4 + i, 5], max_new_tokens=12)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=3)
    assert eng.unfinished                    # budget too small to drain
    stamps = {r.id: r.t_submit for r in reqs}
    eng.run()                                # resume
    assert not eng.unfinished
    for r in reqs:
        assert r.t_submit == stamps[r.id]    # original stamp survives
        assert r.out_tokens == fake_stream(r.prompt, 12)
        assert r.t_submit <= r.t_admit <= r.t_done


def test_resubmit_keeps_original_t_submit():
    eng = FakeEngine(max_rows=1)
    r = Request(id=0, prompt=[5], max_new_tokens=2)
    eng.submit(r)
    eng.run()
    assert r.t_submit == 0
    eng.queue.append(r)  # hypothetical re-enqueue path
    eng.submit(Request(id=1, prompt=[6], max_new_tokens=2))
    assert r.t_submit == 0  # no restamp on later submits
