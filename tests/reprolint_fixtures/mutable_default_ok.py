"""Clean: None/tuple defaults, default_factory fields, non-dataclass
class registries."""
from dataclasses import dataclass, field
from typing import List, Optional


def admit(req, queue=None):
    queue = [] if queue is None else queue
    queue.append(req)
    return queue


def windowed(sizes=(1, 2, 4)):        # tuples are immutable
    return sizes


@dataclass
class Req:
    out_tokens: List[int] = field(default_factory=list)
    note: Optional[str] = None


class Plain:
    registry = {}   # not a dataclass: a class-level registry is fine
