"""Violating: cache-carrying jits that do not donate."""
import functools

import jax


@functools.partial(jax.jit, static_argnames=("k",))  # EXPECT: jit-donation
def decode(params, caches, batch, *, k):
    return caches


def _reset(caches, slot):
    return caches


reset = jax.jit(_reset)  # EXPECT: jit-donation

cow = jax.jit(lambda caches, src: caches)  # EXPECT: jit-donation

opt = jax.jit(lambda opt_state, grads: opt_state)  # EXPECT: jit-donation
