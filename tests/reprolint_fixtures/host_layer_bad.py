"""Violating: JAX imports in a host-layer (scheduler-shaped) module."""
import jax                       # EXPECT: host-layer-jax
import jax.numpy as jnp          # EXPECT: host-layer-jax
from jax import lax              # EXPECT: host-layer-jax


def nested():
    from jax.experimental import shard_map  # EXPECT: host-layer-jax
    return shard_map


def decide(queue):
    return jnp.argmin(jax.numpy.asarray(queue)), lax
