"""Fixture: malformed suppressions the framework must reject."""


def admit(req, queue=[]):  # reprolint: disable=mutable-default
    return queue


def route(table={}):  # reprolint: disable=no-such-rule -- typo'd name
    return table


x = 1  # reprolint: disable=host-sync -- nothing here to suppress
