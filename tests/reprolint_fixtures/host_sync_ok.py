"""Clean: static casts in traced code; host casts outside hot paths."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def traced(x):
    k = int(x.shape[0])       # shape metadata: resolved at trace time
    m = float(1.5)            # literal
    j = int(len([1, 2]))      # len() is static
    return x * k * m * j


def body(carry, x):
    return carry + jnp.sum(x), x   # pure device math in the scan body


def outer(xs):
    return jax.lax.scan(body, 0.0, xs)


def host_helper(a):
    # plain host code: casts and np.asarray are not syncs here
    return int(a.max()) + float(a.min()), np.asarray(a)


class Engine:
    def _prefill_row(self, toks):
        # admission path, not the macro-step hot loop
        return np.asarray(toks)
