"""Violating: shared-mutable defaults (arguments and dataclass
fields)."""
from dataclasses import dataclass
from typing import Dict, List


def admit(req, queue=[]):             # EXPECT: mutable-default
    queue.append(req)
    return queue


def route(table={},                   # EXPECT: mutable-default
          *, hops=set()):             # EXPECT: mutable-default
    return table, hops


@dataclass
class Req:
    out_tokens: List[int] = []        # EXPECT: mutable-default
    meta: Dict[str, int] = dict()     # EXPECT: mutable-default
