"""Clean: explicit injected streams, crc32 folding, shadowed names."""
import zlib

import numpy as np


def draw(n, seed):
    rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
    folded = zlib.crc32("scenario".encode())
    return rng.normal(size=n), folded


def with_generator(rng: np.random.Generator):
    return rng.integers(0, 10)


def local_hash(hash):
    # parameter shadows the builtin: not a seeding hazard
    return hash("x")


class Key:
    def __hash__(self):
        return 7
