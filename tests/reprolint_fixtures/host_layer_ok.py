"""Clean: a pure-numpy scheduler module."""
from typing import List, Optional

import numpy as np


def next_admission(queue: List, now: int) -> Optional[int]:
    if not queue:
        return None
    slacks = np.asarray([q.deadline - now for q in queue])
    return int(np.argmin(slacks))
