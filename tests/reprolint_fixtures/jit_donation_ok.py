"""Clean: donation declared, or no cache-carrying parameters."""
import functools

import jax


@functools.partial(jax.jit, donate_argnums=(1,), static_argnames=("k",))
def decode(params, caches, batch, *, k):
    return caches


def _reset(caches, slot):
    return caches


reset = jax.jit(_reset, donate_argnums=(0,))
named = jax.jit(_reset, donate_argnames=("caches",))
plain = jax.jit(lambda x, y: x + y)   # no cache-named parameters
wrapped = jax.jit(some_imported_fn)   # noqa: F821 - not resolvable, skipped
