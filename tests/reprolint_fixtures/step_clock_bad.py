"""Violating: wall-clock reads inside engine step logic."""
import time
from time import perf_counter


class Engine:
    def step(self):
        t0 = time.time()             # EXPECT: step-clock
        t1 = time.perf_counter()     # EXPECT: step-clock
        t2 = perf_counter()          # EXPECT: step-clock
        t3 = time.monotonic()        # EXPECT: step-clock
        return t0, t1, t2, t3
