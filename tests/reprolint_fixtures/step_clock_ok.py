"""Clean: the step counter is the engine clock."""


class Engine:
    def __init__(self):
        self.t = 0

    def step(self):
        self.t += 1            # one step() == one decode iteration
        return self.t

    def stamp(self, req):
        req.t_admit = self.t   # stamps are step-counter units
