"""Clean: packing via the public API, caches donated, weights static."""
import jax

from repro.models import quantize as qz


def build_engine_params(params, fmt):
    # the one sanctioned entry point: the format decision stays in
    # models/quantize.py
    return qz.quantize_params(params, fmt)


def rebuild(params, new_scale):
    # packed leaves are immutable: rebuild the tree instead of patching
    return {**params, "wq": {"q": params["wq"]["q"], "s": new_scale}}


def queries(state, q):
    # unrelated "q"-keyed stores on non-weight names are fine
    state["q"] = q
    return state


def decode(params, caches, x):
    return caches, x


def build_jits():
    # caches are linear state and donate; weights ride along static
    return jax.jit(decode, donate_argnums=(1,))
