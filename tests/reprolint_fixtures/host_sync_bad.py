"""Violating: host syncs inside traced code and the macro-step path."""
import jax
import numpy as np


@jax.jit
def traced(x):
    v = x.item()           # EXPECT: host-sync
    y = np.asarray(x)      # EXPECT: host-sync
    n = int(x)             # EXPECT: host-sync
    jax.device_get(x)      # EXPECT: host-sync
    return v, y, n


def scan_caller(xs):
    def body(carry, x):
        carry = carry + float(x)  # EXPECT: host-sync
        return carry, x
    return jax.lax.scan(body, 0.0, xs)


class Engine:
    def _forward_steps(self, tokens):
        toks = self._jits["decode"](tokens)
        toks.block_until_ready()   # EXPECT: host-sync
        extra = toks.tolist()      # EXPECT: host-sync
        return np.asarray(toks), extra  # EXPECT: host-sync
