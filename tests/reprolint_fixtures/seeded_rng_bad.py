"""Violating: global-stream RNG draws and salted hash() seeding."""
import random

import numpy as np


def draw(n):
    a = np.random.rand(n)         # EXPECT: seeded-rng
    np.random.seed(0)             # EXPECT: seeded-rng
    b = random.random()           # EXPECT: seeded-rng
    random.shuffle([1, 2, 3])     # EXPECT: seeded-rng
    s = hash("scenario-name")     # EXPECT: seeded-rng
    return a, b, s
