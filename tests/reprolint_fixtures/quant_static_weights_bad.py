"""Violating: packing outside quantize.py, mutating packed leaves,
donating weights into jit."""
import jax

from repro.models import quantize as qz


def repack_locally(w):
    packed = qz.quantize_int8(w)            # EXPECT: quant-static-weights
    nibbles = qz.pack_int4(w)               # EXPECT: quant-static-weights
    return packed, nibbles


def patch_scales(params, new_scale):
    params["wq"]["s"] = new_scale           # EXPECT: quant-static-weights
    params["wq"]["q"] += 1                  # EXPECT: quant-static-weights
    return params


def decode(params, caches, x):
    return caches, x


def build_jits():
    bad = jax.jit(decode, donate_argnums=(0, 1))   # EXPECT: quant-static-weights
    ok = jax.jit(decode, donate_argnums=(1,))
    also_bad = jax.jit(decode,              # EXPECT: quant-static-weights
                       donate_argnames=("params",))
    return bad, ok, also_bad
