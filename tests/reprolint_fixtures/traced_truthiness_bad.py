"""Violating: Python control flow on traced jnp values."""
import jax
import jax.numpy as jnp


@jax.jit
def f(x):
    if jnp.any(x > 0):            # EXPECT: traced-truthiness
        return x
    m = jnp.max(x)
    while m > 0:                  # EXPECT: traced-truthiness
        m = m - 1
    return m


def outer(xs):
    def body(carry, x):
        s = jnp.sum(x)
        if s > 0:                 # EXPECT: traced-truthiness
            carry = carry + 1
        return carry, x
    return jax.lax.scan(body, 0, xs)
