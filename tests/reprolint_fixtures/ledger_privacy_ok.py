"""Clean: the public ledger API, and private fields that are not
PagedCache's."""
from repro.models.kvcache import PagedCache


def use(cfg):
    pc = PagedCache(cfg, max_rows=1, max_len=8, block_size=4)
    if pc.can_admit(8):
        pc.admit(0, 8)
    pc.ensure(0, 7)
    pc.release(0)
    pc.check()
    return pc.free_blocks, pc.num_blocks


class Engine:
    def ok(self):
        self._jits = {}              # the engine's own private state
        self._admit_order.append(1)  # not the ledger's
        return self.pc.probe_hit     # public ledger API
