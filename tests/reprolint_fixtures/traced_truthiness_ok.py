"""Clean: static branching in traced code; device branching done
right; host code untouched."""
import jax
import jax.numpy as jnp


@jax.jit
def f(x, *, use_fast=True, paged=None):
    if paged is None:              # identity test: static config
        x = x + 1
    if use_fast:                   # plain flag parameter
        x = x * 2
    if x.shape[0] > 4:             # shape metadata: trace-time static
        x = x[:4]
    return jnp.where(x > 0, x, 0)  # data-dependent branch, on device


def host(x):
    if jnp.any(x > 0):             # not traced: host-side code may branch
        return 1
    return 0
