"""Violating: private PagedCache state poked from outside the ledger."""
from repro.models.kvcache import PagedCache


def poke(cfg):
    pc = PagedCache(cfg, max_rows=1, max_len=8, block_size=4)
    pc._free["attn"].append(3)       # EXPECT: ledger-privacy
    n = len(pc._held["attn"][0])     # EXPECT: ledger-privacy
    return n


class Engine:
    def grow(self):
        return self.pc._ref[0]       # EXPECT: ledger-privacy


def tracked(cfg):
    store = PagedCache(cfg)
    store._version += 1              # EXPECT: ledger-privacy
    return store
