"""Fixture: correctly-suppressed violations (reasons given)."""


def admit(req, queue=[]):  # reprolint: disable=mutable-default -- fixture
    return queue


# reprolint: disable-next=mutable-default -- fixture: disable-next form,
# with the reason wrapping onto a continuation comment line
def route(table={}):
    return table
