"""Property-based paged-cache ledger invariants under prefix sharing.

Randomized (seeded, via ``tests/_propcheck.py`` — no hypothesis
dependency) interleavings of admit / grow / COW-write / release /
preempt over *overlapping-prefix* prompt families pin the refcounted
ownership contract the engines rely on (SERVING.md §Prefix sharing):

* **partition** — after every operation, every attn-pool block is
  exactly one of {free, scratch, referenced}; a block is on the free
  list iff its refcount is zero;
* **accounting** — each block's refcount equals both its multiplicity
  across the per-row held lists and its occupancy across the block
  tables (``PagedCache.check`` asserts all of this internally);
* **no double-free** — releasing an already-drained row never returns
  a still-referenced (or already-free) block to the free list;
* **drain** — releasing every row returns the pool to its initial
  free-list size with an empty prefix index, regardless of how many
  admissions shared blocks along the way.

The same interleavings are replayed through :class:`FakeEngine` (the
real ``_PagedEngine`` state machine) to pin the stream-level contract:
prefix sharing changes which blocks are allocated, never which tokens
come out (every stream equals the ``fake_stream`` oracle).
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 runs green without hypothesis
    from _propcheck import given, settings, st

from repro.configs import get_smoke_config
from repro.models.kvcache import PagedCache
from repro.serving.engine import Request
from repro.serving.testbed import FakeEngine, fake_stream

BS = 8
# overlapping-prefix prompt families: two distinct shared stems (one and
# two full blocks) plus divergent tails, so random admissions hit each
# other's indexed blocks at varying depths
_STEM1 = [5, 6, 7, 2, 9, 3, 8, 1]
_STEM2 = _STEM1 + [4, 4, 2, 2, 6, 6, 1, 1]
_TAILS = [[], [3], [9, 9], [12, 1, 7], [2, 8, 5, 5]]


def _prompt(rng) -> list:
    stem = (_STEM1, _STEM2, [])[int(rng.integers(3))]
    tail = _TAILS[int(rng.integers(len(_TAILS)))]
    if not stem and not tail:
        tail = [int(rng.integers(1, 900))]
    return list(stem) + list(tail)


def _drive_ledger(seed: int, num_blocks: int, n_ops: int):
    """One randomized ledger session.  Draws ops against a sharing-
    enabled cache, running ``check()`` after every mutation; returns
    the cache for the drain assertion."""
    rng = np.random.default_rng(seed)
    cfg = get_smoke_config("smollm-360m")
    pc = PagedCache(cfg, max_rows=4, max_len=64, block_size=BS,
                    num_blocks=num_blocks, share_prefixes=True)
    assert pc.share_prefixes
    pos = [0] * pc.max_rows   # simulated decode position per live row
    live = [False] * pc.max_rows
    for _ in range(n_ops):
        op = int(rng.integers(4))
        row = int(rng.integers(pc.max_rows))
        if op == 0 and not live[row]:          # admit
            toks = _prompt(rng)
            if pc.admit(row, len(toks) + 1, tokens=toks):
                live[row] = True
                pos[row] = len(toks)
        elif op == 1 and live[row]:            # grow one decode step
            if pos[row] < pc.max_len - 1 and pc.ensure(row, pos[row]):
                pos[row] += 1
        elif op == 2 and live[row]:            # write INSIDE the held
            # span — lands on a shared block often, forcing COW (real
            # engines never do this; the ledger must survive it anyway)
            pc.ensure(row, int(rng.integers(0, max(1, pos[row]))))
        elif op == 3 and live[row]:            # release / preempt
            pc.release(row)
            live[row] = False
            pos[row] = 0
        pc.check()
    return pc, live


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       num_blocks=st.integers(6, 16),
       n_ops=st.integers(10, 60))
def test_ledger_random_interleavings_hold_invariants(seed, num_blocks,
                                                     n_ops):
    pc, live = _drive_ledger(seed, num_blocks, n_ops)
    # partition + refcount accounting held after every op (check()
    # in the loop); now drain and require the pool whole again
    for row in range(pc.max_rows):
        if live[row]:
            pc.release(row)
        pc.check()
    assert pc.used_blocks == 0
    assert pc.free_blocks == num_blocks
    assert not pc._prefix_index and not pc._block_key
    assert not pc.pending_copies or pc.take_pending_copies()
    # double-free guard: a drained row's second release is a no-op,
    # but a forged still-referenced block must trip the RuntimeError
    pc.release(0)
    assert pc.admit(0, BS, tokens=_STEM1)
    blk = pc._held["attn"][0][0]
    pc.release(0)
    pc._held["attn"][0].append(blk)
    try:
        pc.release(0)
    except RuntimeError:
        pc._held["attn"][0].clear()
    else:  # pragma: no cover - the guard must fire
        raise AssertionError("double free not caught")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       num_blocks=st.integers(5, 10),
       decode_steps=st.sampled_from([1, 4, 8]))
def test_engine_random_shared_traces_match_oracle(seed, num_blocks,
                                                  decode_steps):
    """The real scheduler over random overlapping-prefix traces:
    streams equal the recurrence oracle token-for-token (sharing and
    the COW/preemption churn it adds are invisible), and the drained
    ledger returns every block."""
    rng = np.random.default_rng(seed)
    eng = FakeEngine(max_rows=3, max_len=64, block_size=BS,
                     num_blocks=num_blocks, decode_steps=decode_steps,
                     prefix_sharing=True)
    reqs = []
    for _ in range(int(rng.integers(4, 10))):
        r = Request(id=len(reqs), prompt=_prompt(rng),
                    max_new_tokens=int(rng.integers(1, 12)))
        reqs.append(r)
        eng.submit(r)
    done = eng.run()
    eng.pc.check()
    assert len(done) == len(reqs)
    for r in done:
        assert r.out_tokens == fake_stream(r.prompt, r.max_new_tokens), \
            f"request {r.id} diverged from the oracle"
    assert eng.pc.used_blocks == 0
    assert eng.pc.free_blocks == num_blocks
