"""Tests for tools/reprolint: per-rule fixtures, the suppression
framework, the CLI contract, and a self-lint of the repo.

Fixture protocol (tests/reprolint_fixtures/): every rule has a
``<rule>_bad.py`` whose violating lines carry a trailing
``# EXPECT: <rule>`` marker, and a ``<rule>_ok.py`` of near-miss
patterns that must stay silent.  The harness runs the single rule
directly over a FileContext, so path-scoped rules (host-layer-jax,
step-clock, ledger-privacy) are exercised without faking paths.
"""
import json
import os
import re
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from tools.reprolint import framework, lint_paths  # noqa: E402
from tools.reprolint.context import FileContext  # noqa: E402
from tools.reprolint.framework import lint_file  # noqa: E402

FIXTURES = os.path.join(ROOT, "tests", "reprolint_fixtures")
EXPECT_RE = re.compile(r"#\s*EXPECT:\s*([\w,\s-]+?)\s*$")

RULES = framework.all_rules()

FIXTURE_RULES = [
    ("jit_donation", "jit-donation"),
    ("host_sync", "host-sync"),
    ("seeded_rng", "seeded-rng"),
    ("host_layer", "host-layer-jax"),
    ("step_clock", "step-clock"),
    ("ledger_privacy", "ledger-privacy"),
    ("traced_truthiness", "traced-truthiness"),
    ("mutable_default", "mutable-default"),
    ("quant_static_weights", "quant-static-weights"),
]


def _context(path):
    with open(path, "r", encoding="utf-8") as fh:
        source = fh.read()
    rel = os.path.relpath(path, ROOT).replace(os.sep, "/")
    return FileContext(path, rel, source)


def _run_rule(rule_name, path):
    ctx = _context(path)
    return {(f.line, f.rule) for f in RULES[rule_name]().check(ctx)}


def _expected(path):
    want = set()
    with open(path, "r", encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            m = EXPECT_RE.search(line)
            if m:
                for rule in m.group(1).split(","):
                    want.add((lineno, rule.strip()))
    return want


@pytest.mark.parametrize("stem,rule", FIXTURE_RULES)
def test_rule_fires_on_bad_fixture(stem, rule):
    path = os.path.join(FIXTURES, f"{stem}_bad.py")
    want = _expected(path)
    assert want, f"{stem}_bad.py has no EXPECT markers"
    got = _run_rule(rule, path)
    assert got == want, (
        f"{rule} on {stem}_bad.py: expected {sorted(want)}, got {sorted(got)}"
    )


@pytest.mark.parametrize("stem,rule", FIXTURE_RULES)
def test_rule_silent_on_ok_fixture(stem, rule):
    path = os.path.join(FIXTURES, f"{stem}_ok.py")
    got = _run_rule(rule, path)
    assert got == set(), (
        f"{rule} over-fired on {stem}_ok.py: {sorted(got)}"
    )


def test_every_rule_has_fixtures():
    covered = {rule for _, rule in FIXTURE_RULES}
    assert covered == set(RULES), (
        f"rules without fixtures: {sorted(set(RULES) - covered)}"
    )


# ---------------------------------------------------------------------------
# Suppression framework
# ---------------------------------------------------------------------------

def test_reasoned_suppressions_apply():
    path = os.path.join(FIXTURES, "suppression_ok.py")
    findings = lint_file(path, ROOT)
    assert findings, "fixture should produce (suppressed) findings"
    assert all(f.suppressed for f in findings)
    assert all(f.rule == "mutable-default" for f in findings)
    assert all(f.suppress_reason for f in findings)
    # one same-line disable, one disable-next spanning a comment block
    assert len(findings) == 2


def test_malformed_suppressions_are_reported():
    path = os.path.join(FIXTURES, "suppression_bad.py")
    findings = lint_file(path, ROOT)
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)

    # line 4: reason missing -> the violation is suppressed, but the
    # directive itself is flagged
    bad = by_rule.get("bad-suppression", [])
    assert any(f.line == 4 and "reason" in f.message for f in bad)
    # line 8: unknown rule name in the directive
    assert any(f.line == 8 and "no-such-rule" in f.message for f in bad)
    # line 8's actual violation is NOT suppressed (wrong rule named)
    mut = [f for f in by_rule.get("mutable-default", []) if not f.suppressed]
    assert any(f.line == 8 for f in mut)
    # line 12: directive that suppresses nothing
    unused = by_rule.get("unused-suppression", [])
    assert any(f.line == 12 for f in unused)


# ---------------------------------------------------------------------------
# Self-lint: the repo must be clean under its own linter
# ---------------------------------------------------------------------------

def test_repo_self_lint_is_clean():
    findings = lint_paths(["src", "benchmarks", "tests"], ROOT)
    unsuppressed = [f for f in findings if not f.suppressed]
    assert unsuppressed == [], "\n".join(f.render() for f in unsuppressed)
    # every deliberate suppression must carry a reason
    assert all(f.suppress_reason for f in findings if f.suppressed)


def test_fixtures_excluded_from_repo_lint():
    findings = lint_paths(["tests"], ROOT)
    assert not any("reprolint_fixtures" in f.path for f in findings)


# ---------------------------------------------------------------------------
# CLI contract: exit codes and --json schema
# ---------------------------------------------------------------------------

def _cli(args, cwd=ROOT):
    return subprocess.run(
        [sys.executable, "-m", "tools.reprolint", *args],
        cwd=cwd, capture_output=True, text=True,
    )


def test_cli_exit_one_on_findings(tmp_path):
    victim = tmp_path / "victim.py"
    victim.write_text("def f(x=[]):\n    return x\n")
    proc = _cli(["--root", str(tmp_path), "victim.py"])
    assert proc.returncode == 1
    assert "mutable-default" in proc.stdout


def test_cli_exit_zero_and_json_on_clean(tmp_path):
    clean = tmp_path / "clean.py"
    clean.write_text("def f(x=None):\n    return x\n")
    proc = _cli(["--json", "--root", str(tmp_path), "clean.py"])
    assert proc.returncode == 0
    payload = json.loads(proc.stdout)
    assert payload["version"] == 1
    assert payload["clean"] is True
    assert payload["files"] == 1
    assert payload["findings"] == []


def test_cli_json_findings_schema(tmp_path):
    victim = tmp_path / "victim.py"
    victim.write_text("import numpy as np\nv = np.random.rand(3)\n")
    proc = _cli(["--json", "--root", str(tmp_path), "victim.py"])
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    assert payload["clean"] is False
    assert payload["counts"].get("seeded-rng") == 1
    (finding,) = payload["findings"]
    assert finding["rule"] == "seeded-rng"
    assert finding["line"] == 2
    assert finding["path"].endswith("victim.py")
    assert finding["suppressed"] is False


def test_cli_exit_two_on_missing_path():
    proc = _cli(["no/such/dir"])
    assert proc.returncode == 2


def test_cli_list_rules_covers_catalogue():
    proc = _cli(["--list-rules"])
    assert proc.returncode == 0
    for name in RULES:
        assert name in proc.stdout
