"""Task-DAG model + latency recursion properties."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # fall back to the seeded shim (see _propcheck.py)
    from _propcheck import given, settings, strategies as st

from repro.core.graph import make_application
from repro.core.network import make_network
from repro.core.qos import MeanLatencyModel, qos_scores


@given(seed=st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_dags_are_inverse_trees(seed):
    app = make_application(np.random.default_rng(seed))
    for tt in app.task_types:
        assert tt.validate_inverse_tree()
        assert tt.sink() in tt.ms_ids
        for m in tt.ms_ids:
            # unique path to sink
            desc = tt.descendants(m)
            assert len(desc) == len(set(desc))
            if m != tt.sink():
                assert desc[-1] == tt.sink()


def test_application_scale_matches_paper():
    app = make_application(np.random.default_rng(0))
    assert len(app.core_ids) == 6
    assert len(app.light_ids) == 9
    assert len(app.task_types) == 4
    # every core + light MS is used by at least one task type
    used = set()
    for tt in app.task_types:
        used |= set(tt.ms_ids)
    assert used == set(range(15))


@given(seed=st.integers(0, 200))
@settings(max_examples=10, deadline=None)
def test_network_connectivity(seed):
    net = make_network(np.random.default_rng(seed))
    # all-pairs routing exists and is symmetric-ish
    assert np.isfinite(net.net_ms).all()
    for i in range(net.n_nodes):
        for j in range(net.n_nodes):
            d = net.path_ms(i, j, 1.0)
            assert d >= 0
            if i != j:
                assert d > 0


@given(seed=st.integers(0, 200))
@settings(max_examples=8, deadline=None)
def test_dpr_monotone_along_dag(seed):
    """Preceding latency of a child (plus its parent's processing) is at
    least the *best-placed* parent's preceding latency."""
    rng = np.random.default_rng(seed)
    app = make_application(rng)
    net = make_network(rng)
    model = MeanLatencyModel(app, net)
    tt = app.task_types[0]
    u, v = 0, 0
    for s, d in tt.edges:
        best_parent = min(model.d_pr(u, tt, vp, s)
                          for vp in range(net.n_nodes))
        assert (model.d_pr(u, tt, v, d) + 1e-9
                >= best_parent + model.mean_proc(s))


def test_qos_scores_shapes_and_signs():
    rng = np.random.default_rng(1)
    app = make_application(rng)
    net = make_network(rng)
    z, q = qos_scores(app, net)
    total_conc = 0.0
    for m in app.core_ids:
        assert z[m].shape == (net.n_nodes,)
        assert (z[m] >= 0).all() and (q[m] >= 0).all()
        total_conc += z[m].sum()
    # z~ apportions (rate x service) mass — strictly positive overall
    assert total_conc > 0
