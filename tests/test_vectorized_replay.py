"""Vectorized-engine determinism locks (PR 3).

1. The vectorized `Simulator` reproduces the fixed-semantics scalar
   reference (`repro.core.simulator_scalar`) metric-for-metric at fixed
   seeds — the scalar engine is the oracle, any drift is a bug.
2. Replay determinism survives the vectorization: the same grid yields
   byte-identical results JSON regardless of worker count, over the
   full scenario registry including the scale_load family.
"""
import pytest

from repro.core.simulator_scalar import run_one_scalar
from repro.experiments import results
from repro.experiments.results import metrics_equal
from repro.experiments.runner import TrialSpec, run_grid, run_one
from repro.experiments.scenarios import SCALE_LOAD_USERS, list_scenarios

ALL_SCENARIOS = tuple(list_scenarios())


def _assert_same(a, b):
    # metrics_equal, not `==`: empty trials carry NaN latency metrics
    # in both engines, and nan != nan would flag them as divergent
    assert metrics_equal(a, b), {k: (a[k], b[k]) for k in a
                                 if not metrics_equal({k: a[k]},
                                                      {k: b.get(k)})}


@pytest.mark.parametrize("strategy", ["proposal", "prop_avg", "lbrr", "ga"])
def test_vectorized_matches_scalar_reference(strategy):
    """Every strategy, trial-for-trial identical metrics dicts."""
    spec = TrialSpec(seed=5, strategy=strategy, scenario="baseline",
                     horizon_slots=10, drain_slots=200)
    _assert_same(run_one(spec), run_one_scalar(spec))


@pytest.mark.parametrize("scenario",
                         ["bursty_mmpp", "failure_churn", "tiered",
                          "scale_load_10", "scale_load_tiered_10"])
def test_vectorized_matches_scalar_reference_across_scenarios(scenario):
    spec = TrialSpec(seed=2, strategy="proposal", scenario=scenario,
                     horizon_slots=8, drain_slots=150)
    _assert_same(run_one(spec), run_one_scalar(spec))


def test_full_registry_replay_is_worker_count_invariant():
    """Same grid -> byte-identical serialized results JSON for 1 vs 2
    workers, across the ENTIRE scenario registry (classic six + every
    scale_load population)."""
    assert {f"scale_load_{n}" for n in SCALE_LOAD_USERS} <= \
        set(ALL_SCENARIOS)
    assert {f"scale_load_tiered_{n}" for n in SCALE_LOAD_USERS} <= \
        set(ALL_SCENARIOS)
    # lbrr everywhere (cheap, exercises scenario/env streams), plus the
    # full controller on a classic and a scale_load cell
    specs = [TrialSpec(seed=1, strategy="lbrr", scenario=s,
                       horizon_slots=3, drain_slots=60)
             for s in ALL_SCENARIOS]
    specs += [TrialSpec(seed=1, strategy="proposal", scenario=s,
                        horizon_slots=3, drain_slots=60)
              for s in ("baseline", "scale_load_25")]
    seq = run_grid(specs, n_workers=1)
    par = run_grid(specs, n_workers=2)
    assert results.dumps(seq) == results.dumps(par)
