"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            paged_decode_attention_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.selective_scan import selective_scan_pallas

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 128, 64),    # MHA
    (2, 8, 2, 256, 64),    # GQA
    (1, 4, 1, 192, 128),   # MQA, ragged seq vs 128 blocks
    (2, 2, 2, 64, 256),    # wide head (gemma3-like)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_flash_attention(b, h, kv, s, d, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=64, block_k=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    assert out.shape == exp.shape and out.dtype == q.dtype
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - exp.astype(jnp.float32)))
    assert float(err) < _tol(dtype) * 10, float(err)


@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 256, 64),
    (2, 8, 2, 512, 64),
    (3, 4, 1, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, kv, s, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, kv, s, d), dtype)
    vc = jax.random.normal(ks[2], (b, kv, s, d), dtype)
    pos = jnp.arange(b, dtype=jnp.int32) * (s // max(b, 1)) + 5
    out = decode_attention_pallas(q, kc, vc, pos, block_s=128,
                                  interpret=True)
    exp = ref.decode_attention_ref(q, kc, vc, pos)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - exp.astype(jnp.float32)))
    assert float(err) < _tol(dtype) * 10, float(err)


@pytest.mark.parametrize("b,h,kv,nb,bs,d", [
    (2, 4, 4, 4, 16, 64),     # MHA
    (3, 8, 2, 3, 8, 32),      # GQA, odd pool
    (2, 4, 1, 5, 32, 128),    # MQA, wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(b, h, kv, nb, bs, d, dtype):
    """Block-table-gather kernel vs the paged oracle AND vs the dense
    kernel on the pre-gathered logical view (the two must agree
    bitwise: paging only changes addressing, never math)."""
    nb_phys = b * nb + 3   # slack blocks the tables never reference
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    kp = jax.random.normal(ks[1], (kv, nb_phys, bs, d), dtype)
    vp = jax.random.normal(ks[2], (kv, nb_phys, bs, d), dtype)
    rng = np.random.default_rng(0)
    ids = rng.permutation(nb_phys - 1)[: b * nb].reshape(b, nb) + 1
    tables = jnp.asarray(ids, jnp.int32)
    pos = jnp.asarray(rng.integers(0, nb * bs, size=b), jnp.int32)

    out = paged_decode_attention_pallas(q, kp, vp, tables, pos,
                                        interpret=True)
    exp = ref.paged_decode_attention_ref(q, kp, vp, tables, pos)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - exp.astype(jnp.float32)))
    assert float(err) < _tol(dtype) * 10, float(err)

    kg = jnp.moveaxis(kp[:, tables], 1, 0).reshape(b, kv, nb * bs, d)
    vg = jnp.moveaxis(vp[:, tables], 1, 0).reshape(b, kv, nb * bs, d)
    dense = decode_attention_pallas(q, kg, vg, pos, block_s=bs,
                                    interpret=True)
    assert float(jnp.max(jnp.abs(
        out.astype(jnp.float32) - dense.astype(jnp.float32)))) == 0.0


@pytest.mark.parametrize("b,t,di,ds", [
    (1, 64, 128, 16),
    (2, 100, 256, 16),     # t not a multiple of the chunk
    (2, 128, 512, 8),
])
def test_selective_scan(b, t, di, ds):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (b, t, di)))
    bm = jax.random.normal(ks[1], (b, t, ds))
    cm = jax.random.normal(ks[2], (b, t, ds))
    x = jax.random.normal(ks[3], (b, t, di))
    a_neg = -jnp.abs(jax.random.normal(ks[4], (di, ds)))
    h0 = jax.random.normal(ks[5], (b, di, ds))
    y, h_t = selective_scan_pallas(dt, bm, cm, x, a_neg, h0,
                                   block_di=128, chunk_t=64, interpret=True)
    y_exp, h_exp = ref.selective_scan_ref(dt, bm, cm, x, a_neg, h0)
    assert float(jnp.max(jnp.abs(y - y_exp))) < 1e-3
    assert float(jnp.max(jnp.abs(h_t - h_exp))) < 1e-3


@pytest.mark.parametrize("shape", [(8, 128), (3, 37, 256), (2, 5, 7, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(k1, shape, dtype)
    scale = jax.random.normal(k2, shape[-1:], dtype)
    out = rmsnorm_pallas(x, scale, interpret=True)
    exp = ref.rmsnorm_ref(x, scale)
    err = jnp.max(jnp.abs(out.astype(jnp.float32)
                          - exp.astype(jnp.float32)))
    assert float(err) < _tol(dtype) * 5
