"""JAX-free unit tests for speculative decoding scheduler paths.

Driven through :class:`repro.serving.testbed.FakeEngine` — the real
``_PagedEngine`` state machine with a numpy verify oracle — and
:class:`ScriptedDraft`, whose per-round acceptance schedule makes
rollback/budget arithmetic exactly predictable.  Byte-identity of the
streams themselves is pinned by tests/test_differential.py (randomized)
and tests/test_speculative.py (real models); here we pin the
*accounting*: budget clamps, position rollback, host-sync and counter
bookkeeping, EC admission's spec_accept discount, and SpecConfig
normalization.
"""
import numpy as np
import pytest

from repro.serving.engine import Request
from repro.serving.scheduler import (ADMIT, DEFER, REJECT, CapacityView,
                                     EDFCapacityPolicy)
from repro.serving.speculative import NgramDraft, SpecConfig
from repro.serving.testbed import FakeEngine, ScriptedDraft, fake_stream


def drive(spec, prompts=((1, 2, 3), (5, 6)), n=20, **kw):
    kw.setdefault("max_len", 96)
    eng = FakeEngine(speculative=spec, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=n))
    done = eng.run()
    return eng, {r.id: r for r in done}


# ----------------------------------------------------------------------
# stream correctness against the testbed oracle
# ----------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 4, 8])
def test_spec_stream_matches_oracle(k):
    eng, done = drive(k)
    for r in done.values():
        assert r.out_tokens == fake_stream(r.prompt, len(r.out_tokens))
        assert len(r.out_tokens) == r.max_new_tokens


def test_scripted_acceptance_schedule_exact():
    """schedule=[a] with K drafts emits exactly min(a, K) + 1 tokens
    per row per round (accepted prefix + correction/bonus)."""
    for a, k, per_round in [(0, 4, 1), (2, 4, 3), (4, 4, 5), (6, 4, 5)]:
        eng, done = drive({"k": k, "provider": ScriptedDraft([a])},
                          prompts=[(1, 2, 3)], n=15, max_rows=1)
        r = done[0]
        assert r.out_tokens == fake_stream(r.prompt, 15)
        full, rem = divmod(15, per_round)
        assert eng.spec_rounds == full + (1 if rem else 0)
        assert eng.spec_accept_mean() == pytest.approx(
            15 / eng.spec_rounds)


def test_budget_clamp_max_new_tokens():
    """A row one token from its max_new_tokens cap emits exactly one
    token from a fully-accepting verify round — never overshoots."""
    eng, done = drive({"k": 8, "provider": ScriptedDraft()},
                      prompts=[(1, 2, 3)], n=1, max_rows=1)
    assert done[0].out_tokens == fake_stream([1, 2, 3], 1)
    assert eng.spec_rounds == 1 and eng.spec_emitted == 1


def test_rollback_accounting():
    """pos advances only by emitted tokens; rejected draft tails leave
    no trace in the ledger-visible position or the token counters."""
    eng = FakeEngine(speculative={"k": 4, "provider": ScriptedDraft([1])},
                     max_rows=1, max_len=96)
    eng.submit(Request(id=0, prompt=[1, 2, 3], max_new_tokens=12))
    plen = 3
    emitted = 0
    while not eng._idle():
        eng.step()
        req = eng.rows[0]
        if req is not None:
            emitted = len(req.out_tokens)
            # emitted <= 2/round (schedule [1]); pos = prompt KV + out
            assert emitted == eng.spec_emitted
            assert int(eng.pos[0]) == plen - 1 + emitted
    assert eng.spec_drafted == 4 * eng.spec_rounds
    assert eng.spec_accepted == 1 * eng.spec_rounds


def test_one_host_sync_per_round():
    eng, done = drive({"k": 4, "provider": ScriptedDraft()}, n=24)
    # prefill/reset are host no-ops in the testbed: every sync is a
    # verify round — <= 1 sync/round, and each live row contributes at
    # most K+1 tokens per round (the 1/(K+1) syncs-per-token floor)
    assert eng.n_host_syncs == eng.spec_rounds
    assert eng._spec_row_rounds * (4 + 1) >= eng.spec_emitted


def test_acceptance_rate_bounds():
    eng, _ = drive({"k": 4, "provider": ScriptedDraft([0, 4, 2])}, n=30)
    assert 0.0 <= eng.acceptance_rate <= 1.0
    assert 1.0 <= eng.spec_accept_mean() <= 5.0
    # non-spec engine: neutral telemetry
    eng2, _ = drive(None)
    assert eng2.acceptance_rate == 0.0
    assert eng2.spec_accept_mean() == 1.0
    assert eng2.spec_rounds == 0


def test_spec_off_identical_to_baseline():
    _, base = drive(None)
    _, spec = drive({"k": 4, "provider": ScriptedDraft()})
    for i, r in base.items():
        assert spec[i].out_tokens == r.out_tokens


def test_preemption_resume_under_spec():
    """A tight pool forces preempt-by-recompute mid-stream; resumed
    rows must still match the oracle byte-for-byte."""
    eng, done = drive({"k": 4, "provider": ScriptedDraft([4, 0])},
                      prompts=[(1, 2, 3), (5, 6), (9, 9, 9, 2)],
                      n=18, max_rows=2, block_size=8, num_blocks=8)
    assert done and all(
        r.out_tokens == fake_stream(r.prompt, len(r.out_tokens))
        for r in done.values())


# ----------------------------------------------------------------------
# EC admission: spec_accept discount
# ----------------------------------------------------------------------
def _view(free, total, granule=8, spec_accept=1.0):
    return CapacityView(free_tokens=free, total_tokens=total,
                        granule=granule, spec_accept=spec_accept)


def test_ec_discount_admits_with_speculative_speedup():
    """With fixed Gamma priors, a deficit too slow to clear at 1
    token/step clears in time at spec_accept tokens/step: the verdict
    flips REJECT -> DEFER (waiting is now worth it)."""
    def verdict(spec_accept):
        pol = EDFCapacityPolicy(service_shape=1.0, service_scale=0.35)
        req = Request(id=0, prompt=list(range(64)), max_new_tokens=8,
                      qos="interactive")
        req.t_submit = 0
        return pol.admission_test(
            req, 2, _view(0, 256, spec_accept=spec_accept))[0]

    assert verdict(1.0) == REJECT
    assert verdict(4.0) == DEFER


def test_ec_discount_only_scales_fixed_priors():
    """Online-learned service stats observe the accelerated process
    already — spec_accept must not double-discount them."""
    pol = EDFCapacityPolicy()
    for _ in range(2 * pol.MIN_SAMPLES * pol.SAMPLE_WINDOW):
        pol.on_step(pol._last_t + 1 if pol._last_t else 1, [], [])
        pol.on_free(1, 0)
    shape, scale = pol.service_stats()
    assert shape is not None
    req = Request(id=0, prompt=list(range(64)), max_new_tokens=8,
                  qos="interactive")
    req.t_submit = 0
    t = 2 * pol.MIN_SAMPLES * pol.SAMPLE_WINDOW + 1
    v1 = pol.admission_test(req, t, _view(0, 256, spec_accept=1.0))
    v4 = pol.admission_test(req, t, _view(0, 256, spec_accept=4.0))
    assert v1 == v4  # learned stats: discount is a no-op


def test_capacity_view_defaults_spec_accept():
    assert _view(0, 64).spec_accept == 1.0


# ----------------------------------------------------------------------
# SpecConfig normalization + draft providers
# ----------------------------------------------------------------------
def test_spec_config_make_forms():
    assert SpecConfig.make(None) is None
    assert SpecConfig.make(False) is None
    assert SpecConfig.make(True).k == 4
    assert SpecConfig.make(7).k == 7
    cfg = SpecConfig.make({"k": 2, "ngram": 5})
    assert cfg.k == 2 and isinstance(cfg.provider, NgramDraft)
    assert cfg.provider.n == 5
    sd = ScriptedDraft()
    assert SpecConfig.make(sd).provider is sd
    with pytest.raises(ValueError):
        SpecConfig.make(0)
    with pytest.raises(ValueError):
        SpecConfig.make({"draft": "quantum"})
    with pytest.raises(ValueError):
        SpecConfig.make("ngram")


def test_spec_config_never_shares_providers():
    proto = SpecConfig(k=2)
    a, b = SpecConfig.make(proto), SpecConfig.make(proto)
    assert a is not proto and a is not b
    assert a.provider is not b.provider


def test_ngram_draft_repeats_and_matches():
    d = NgramDraft(n=3)
    # cyclic history: the n-gram index recovers the cycle exactly
    hist = [1, 2, 3] * 4
    assert d.propose(0, hist, 4) == [1, 2, 3, 1]
    # no match anywhere: fall back to repeating the last token
    assert d.propose(0, [9], 3) == [9, 9, 9]
    assert d.propose(0, [], 2) == [0, 0]


def test_gated_arch_disables_spec():
    from repro.configs import get_smoke_config
    eng = FakeEngine(cfg=get_smoke_config("falcon-mamba-7b"),
                     speculative=4)
    assert eng.spec is None and eng.spec_gated_off
    eng.submit(Request(id=0, prompt=[1, 2, 3], max_new_tokens=6))
    done = eng.run()
    assert done[0].out_tokens == fake_stream([1, 2, 3], 6)
    assert eng.spec_rounds == 0


def test_timestamps_stamped_per_round():
    """One verify round is one engine step: t_first lands on the same
    device step as admission (non-spec convention) no matter how many
    tokens the round emitted, and t_done on the *round's* step — an
    18-token stream at 9 tokens/round finishes at step 2, which is the
    TPOT speedup the stamps must reflect."""
    eng, done = drive({"k": 8, "provider": ScriptedDraft()},
                      prompts=[(1, 2, 3)], n=18, max_rows=1)
    r = done[0]
    assert r.t_first == r.t_admit
    assert r.t_submit <= r.t_admit <= r.t_first <= r.t_done
    assert r.t_done == r.t_first + eng.spec_rounds - 1
