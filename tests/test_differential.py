"""Cross-engine differential fuzz harness.

Greedy decode — speculative or not, dense or paged, shared or not,
under any scheduling policy — must emit byte-identical per-request
token streams: scheduling moves *when* tokens are computed, never
*which* tokens.  This harness fuzzes that invariant with randomized
traces (request mix, submit times, QoS classes, pool sizes) seeded
through ``_propcheck``, so a failure prints the reproducing
SeedSequence entropy in the falsifying-example note.

Two layers:

* the bulk of the fuzz runs on :class:`repro.serving.testbed.
  FakeEngine` (the real paged scheduler over the integer-recurrence
  oracle, no JAX): every trace replays across policies × prefix
  sharing × spec on/off × worker counts (max_rows) and is checked
  against :func:`fake_stream` plus monotone timestamps;
* one fixed seeded trace runs across the four real JAX engines
  (dense / pipelined / paged / paged-pipelined) × spec on/off and must
  agree stream-for-stream (tests/test_speculative.py sweeps the
  arch × K grid; this pins the cross-engine diagonal).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    raise ImportError  # the seeded fallback IS the harness contract
except ImportError:
    from _propcheck import given, settings, st

from repro.serving.engine import Request
from repro.serving.testbed import FakeEngine, ScriptedDraft, fake_stream

QOS = ["interactive", "standard", "batch"]

#: replay variants: (policy, prefix_sharing, speculative, max_rows)
VARIANTS = [
    ("fifo", True, None, 3),
    ("fifo", False, None, 3),
    ("fifo", True, 4, 3),
    ("fifo", True, {"k": 4, "provider": None}, 2),   # provider drawn
    ("edf", True, None, 3),
    ("edf", True, 4, 3),
    ("edf_ec", True, None, 3),
    ("edf_ec", True, 4, 3),
    # worker-count replay: same trace, different row counts
    ("fifo", True, 4, 2),
    ("fifo", True, 4, 4),
]


def random_trace(rng: np.random.Generator):
    """A randomized request trace: (submit_step, Request ctor kwargs)."""
    n_req = int(rng.integers(3, 7))
    trace = []
    for i in range(n_req):
        plen = int(rng.integers(1, 7))
        trace.append((
            int(rng.integers(0, 6)),  # submit at this engine step
            dict(id=i,
                 prompt=[int(t) for t in rng.integers(0, 997, plen)],
                 max_new_tokens=int(rng.integers(2, 21)),
                 qos=QOS[int(rng.integers(len(QOS)))]),
        ))
    trace.sort(key=lambda e: e[0])
    return trace


def replay(trace, *, policy, prefix_sharing, speculative, max_rows,
           schedule=None):
    """Drive one engine through the trace (mid-stream submissions
    included) and return its completed/rejected/unfinished requests."""
    if isinstance(speculative, dict) and speculative.get("provider") is None:
        speculative = dict(speculative,
                           provider=ScriptedDraft(schedule))
    eng = FakeEngine(policy=policy, prefix_sharing=prefix_sharing,
                     speculative=speculative, max_rows=max_rows,
                     max_len=64, block_size=8,
                     num_blocks=8 * max_rows)
    done = []
    pending = list(trace)
    while pending:
        while pending and pending[0][0] <= eng.t:
            eng.submit(Request(**pending.pop(0)[1]))
        done += eng.step()
    done += eng.run()
    return eng, done


def check_invariants(eng, done, trace, label):
    by_id = {kw["id"]: kw for _, kw in trace}
    for r in done:
        # byte-identity: every completed stream IS the serial greedy
        # reference continuation of its prompt, full length
        want = fake_stream(r.prompt, r.max_new_tokens)
        assert r.out_tokens == want, (
            f"{label}: request {r.id} stream diverged")
        assert r.error is None
        # monotone timestamps
        assert (r.t_submit <= r.t_admit <= r.t_first <= r.t_done), (
            f"{label}: request {r.id} non-monotone timestamps "
            f"{r.t_submit}/{r.t_admit}/{r.t_first}/{r.t_done}")
    # every submitted request is accounted for exactly once
    seen = ([r.id for r in done] + [r.id for r in eng.rejected]
            + [r.id for r in eng.unfinished])
    assert sorted(seen) == sorted(by_id), f"{label}: requests lost"
    for r in eng.rejected:
        assert r.error is not None


@given(entropy=st.integers(0, 2**31 - 1))
@settings(max_examples=30)
def test_differential_fake_engines(entropy):
    """>= 25 randomized traces (tier-1 budget): every variant replays
    the same trace to byte-identical streams and sane bookkeeping."""
    rng = np.random.default_rng(np.random.SeedSequence(entropy))
    trace = random_trace(rng)
    schedule = [int(a) for a in rng.integers(0, 5, size=4)]
    completed = {}
    for policy, sharing, spec, rows in VARIANTS:
        label = f"{policy}/share={sharing}/spec={spec}/rows={rows}"
        eng, done = replay(trace, policy=policy, prefix_sharing=sharing,
                           speculative=spec, max_rows=rows,
                           schedule=schedule)
        check_invariants(eng, done, trace, label)
        completed[label] = {r.id: tuple(r.out_tokens) for r in done}
    # cross-variant agreement: any request completed by two variants
    # got the identical stream (stronger than oracle-match: catches a
    # variant pair that diverged the same wrong way only if the oracle
    # is wrong too — belt and braces)
    labels = list(completed)
    base = completed[labels[0]]
    for lab in labels[1:]:
        for rid, toks in completed[lab].items():
            if rid in base:
                assert toks == base[rid], (
                    f"{lab} vs {labels[0]}: request {rid} diverged")
    # FIFO admits everything eventually: all-complete across worker
    # counts, so the replay is worker-count-invariant end to end
    fifo = [completed[lab] for lab in labels
            if lab.startswith("fifo") and "spec=4" in lab]
    assert all(len(c) == len(trace) for c in fifo)
    assert all(c == fifo[0] for c in fifo[1:])


def test_differential_real_engines():
    """One seeded trace across the four JAX engines × spec off/on:
    stream-for-stream agreement (the cross-engine diagonal)."""
    from repro.configs import get_smoke_config
    from repro.serving.engine import PagedServingEngine, ServingEngine
    from repro.serving.pipeline import (PagedPipelinedEngine,
                                        PipelinedEngine)

    rng = np.random.default_rng(np.random.SeedSequence(20260808))
    n_req = 3
    reqs = [dict(id=i,
                 prompt=[int(t) for t in rng.integers(0, 500,
                                                      rng.integers(2, 5))],
                 max_new_tokens=int(rng.integers(4, 10)))
            for i in range(n_req)]
    cfg = get_smoke_config("smollm-360m")
    dense = dict(max_batch=2, cache_len=48)
    paged = dict(max_rows=2, max_len=48, block_size=8, num_blocks=16)
    cells = [
        (ServingEngine, dense), (PipelinedEngine, dense),
        (PagedServingEngine, paged), (PagedPipelinedEngine, paged),
    ]
    streams = {}
    for engcls, kw in cells:
        for spec in (None, 4):
            eng = engcls(cfg, seed=0, speculative=spec, **kw)
            for r in reqs:
                eng.submit(Request(**r))
            done = eng.run()
            streams[(engcls.__name__, spec)] = {
                r.id: tuple(r.out_tokens) for r in done}
            assert len(done) == n_req
    base = streams[("ServingEngine", None)]
    for key, got in streams.items():
        assert got == base, f"{key} diverged from dense non-spec"
