"""Training substrate: loss decreases, chunked CE == naive CE,
checkpoint roundtrip, optimizer behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.training import checkpoint
from repro.training.data import SyntheticLM
from repro.training.optimizer import adamw_init, cosine_lr
from repro.training.train_step import chunked_ce, loss_fn, make_train_step


def test_chunked_ce_matches_naive():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 12, 16, 40
    x = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (v, d))
    tgt = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    mask = jnp.ones((b, s))
    ce = chunked_ce(x, w, tgt, mask, chunk=5)
    lg = jnp.einsum("bsd,vd->bsv", x, w)
    naive = jnp.mean(jax.nn.logsumexp(lg, -1)
                     - jnp.take_along_axis(lg, tgt[..., None], -1)[..., 0])
    assert float(jnp.abs(ce - naive)) < 1e-4


def test_chunked_ce_grads_match():
    key = jax.random.PRNGKey(3)
    b, s, d, v = 2, 8, 12, 30
    x = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(4), (v, d))
    tgt = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, v)
    mask = jnp.ones((b, s))

    g1 = jax.grad(lambda xx: chunked_ce(xx, w, tgt, mask, chunk=4))(x)

    def naive(xx):
        lg = jnp.einsum("bsd,vd->bsv", xx, w)
        return jnp.mean(jax.nn.logsumexp(lg, -1)
                        - jnp.take_along_axis(lg, tgt[..., None],
                                              -1)[..., 0])
    g2 = jax.grad(naive)(x)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-4


def test_loss_decreases_smollm():
    cfg = get_smoke_config("smollm-360m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, base_lr=3e-3, warmup=5,
                                   total_steps=60))
    data = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["ce"]))
    assert losses[-1] < losses[0] * 0.85, losses[::10]


def test_cosine_lr_schedule():
    assert float(cosine_lr(0, 1.0, warmup=10, total=100)) < 0.2
    assert float(cosine_lr(10, 1.0, warmup=10, total=100)) == pytest.approx(
        1.0, rel=0.05)
    assert float(cosine_lr(99, 1.0, warmup=10, total=100)) < 0.2


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("mixtral-8x7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    path = os.path.join(tmp_path, "ckpt.npz")
    checkpoint.save(path, params)
    restored = checkpoint.restore(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_synthetic_data_deterministic():
    d1 = SyntheticLM(100, 16, 4, seed=1).batch_at(3)
    d2 = SyntheticLM(100, 16, 4, seed=1).batch_at(3)
    np.testing.assert_array_equal(d1["tokens"], d2["tokens"])
    shard = SyntheticLM(100, 16, 4, seed=1).batch_at(3, shard=1, n_shards=2)
    assert shard["tokens"].shape == (2, 16)
