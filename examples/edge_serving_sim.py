"""Paper evaluation in miniature: one sampled edge scenario, all four
deployment strategies, Fig. 3-style metrics.

  PYTHONPATH=src python examples/edge_serving_sim.py [--seed 0]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.experiment import run_trial  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--horizon", type=int, default=60)
    args = ap.parse_args()
    print(f"seed={args.seed}: 6 core MSs, 9 light MSs, 4 task types, "
          f"10 nodes, 6 users (Table I ranges)")
    rows = run_trial(args.seed, horizon_slots=args.horizon)
    print(f"{'strategy':10s} {'on_time':>8s} {'completed':>10s} "
          f"{'cost':>10s} {'p95 ms':>8s}")
    for r in rows:
        print(f"{r['strategy']:10s} {r['on_time']:8.3f} "
              f"{r['completed']:10.3f} {r['total_cost']:10.1f} "
              f"{r['p95_latency_ms']:8.1f}")


if __name__ == "__main__":
    main()
