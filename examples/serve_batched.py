"""End-to-end driver (the paper's kind is SERVING): serve a small model
with batched requests, where

  1. the model is decomposed into core/light microservices
     (repro.microservice),
  2. stage latencies are MEASURED from the real jit'd model on this host,
  3. the paper's static placement + effective-capacity Lyapunov
     controller schedule those microservices on a simulated edge network,
  4. and the same model actually serves the token traffic through the
     continuous-batching engine.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.network import make_network
from repro.core.online_controller import ProposalStrategy
from repro.core.simulator import Simulator
from repro.microservice.partition import (decompose, profile_stage_ms,
                                          to_application)
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = get_smoke_config("smollm-360m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # ---- 1-2: decompose + profile real stage latencies ----------------
    stages = decompose(cfg, n_core_stages=2)
    b, s = 4, 32
    batch = {"tokens": jnp.ones((b, s), jnp.int32)}
    fwd = jax.jit(lambda p, bt: model.forward(p, bt)[0])
    full_ms = profile_stage_ms(fwd, params, batch)
    measured = {
        "tokenize": 0.05, "sample": 0.10, "detokenize": 0.05,
        "stage0": full_ms / 2, "stage1": full_ms / 2,
    }
    print("measured stage latencies (ms):",
          {k: round(v, 2) for k, v in measured.items()})

    # ---- 3: paper machinery schedules the microservices ----------------
    rng = np.random.default_rng(0)
    app = to_application(cfg, stages, rng, measured_ms=measured,
                         deadline_ms=80.0, rate=0.3)
    net = make_network(rng)
    strat = ProposalStrategy(kappa=4)
    sim = Simulator(app, net, strat, rng=np.random.default_rng(1),
                    horizon_slots=40, drain_slots=300)
    m = sim.run()
    print("placement:", {app.ms(mm).name: int(xv.sum())
                         for mm, xv in sim.x_cr.items()})
    print(f"edge sim: on_time={m['on_time']:.3f} "
          f"completed={m['completed']:.3f} cost={m['total_cost']:.0f}")

    # ---- 4: actually serve batched requests ---------------------------
    eng = ServingEngine(cfg, params=params, max_batch=4, cache_len=64)
    n_req = 12
    t0 = time.perf_counter()
    for i in range(n_req):
        eng.submit(Request(id=i, prompt=[2 + i % 7, 9, 4],
                           max_new_tokens=12))
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks/dt:.1f} tok/s on CPU)")


if __name__ == "__main__":
    main()
