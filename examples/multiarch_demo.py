"""Every assigned architecture, reduced, one forward + one decode step.

  PYTHONPATH=src python examples/multiarch_demo.py [--arch <id>]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    key = jax.random.PRNGKey(0)
    print(f"{'arch':24s} {'family':7s} {'full params':>12s} "
          f"{'fwd ms':>8s} {'decode ms':>10s}")
    for a in archs:
        full = get_config(a)
        cfg = get_smoke_config(a)
        model = build_model(cfg)
        params = model.init(key)
        b, s = 2, 16
        batch = {"tokens": jnp.ones((b, s), jnp.int32)}
        if cfg.n_image_tokens:
            batch["frontend"] = jnp.ones((b, cfg.n_image_tokens,
                                          cfg.d_model))
        if cfg.is_encoder_decoder:
            batch["frontend"] = jnp.ones((b, cfg.encoder_seq, cfg.d_model))
        fwd = jax.jit(lambda p, bt: model.forward(p, bt)[0])
        out = fwd(params, batch)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        jax.block_until_ready(fwd(params, batch))
        fwd_ms = (time.perf_counter() - t0) * 1e3

        _, cache, _ = model.prefill(params, batch, cache_len=s + 4)
        dec = jax.jit(model.decode_step)
        step = {"token": jnp.ones((b, 1), jnp.int32),
                "pos": jnp.full((b,), s, jnp.int32)}
        lg, cache = dec(params, cache, step)
        jax.block_until_ready(lg)
        t0 = time.perf_counter()
        lg, cache = dec(params, cache, step)
        jax.block_until_ready(lg)
        dec_ms = (time.perf_counter() - t0) * 1e3
        print(f"{a:24s} {full.family:7s} {full.num_params()/1e9:10.1f}B "
              f"{fwd_ms:8.1f} {dec_ms:10.1f}")


if __name__ == "__main__":
    main()
