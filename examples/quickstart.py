"""Quickstart: train a reduced SmolLM on synthetic data, checkpoint,
reload, and generate a few tokens.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serving.engine import Request, ServingEngine
from repro.training import checkpoint
from repro.training.data import SyntheticLM
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step


def main():
    cfg = get_smoke_config("smollm-360m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(p.size for p in jax.tree.leaves(params))
    print(f"model {cfg.name}: {n/1e6:.2f}M params")

    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, base_lr=3e-3, warmup=10,
                                   total_steps=100))
    data = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=8, seed=0)
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, opt, metrics = step(params, opt, batch)
        if i % 10 == 0:
            print(f"step {i:3d}  ce={float(metrics['ce']):.3f}  "
                  f"gnorm={float(metrics['grad_norm']):.2f}  "
                  f"lr={float(metrics['lr']):.2e}")

    checkpoint.save("/tmp/quickstart_ckpt.npz", params)
    params = checkpoint.restore("/tmp/quickstart_ckpt.npz", params)
    print("checkpoint roundtrip OK")

    eng = ServingEngine(cfg, params=params, max_batch=2, cache_len=80)
    eng.submit(Request(id=0, prompt=[5, 17, 31], max_new_tokens=10))
    done = eng.run()
    print(f"generated: {done[0].out_tokens}")


if __name__ == "__main__":
    main()
