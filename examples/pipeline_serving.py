"""Pipelined serving walkthrough: the profile→place→execute loop.

A model is decomposed into core stages (microservice.partition), each
stage's real decode latency is measured on this host, the paper's
static integer program places the stages on a simulated edge network,
and the same model then serves token traffic *through that placement* —
every activation hand-off between stages pays the network's transfer
cost.  See ARCHITECTURE.md §Pipeline executor for the dataflow.

  PYTHONPATH=src python examples/pipeline_serving.py [--arch smollm-360m]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import get_smoke_config
from repro.core.network import make_network
from repro.serving import PipelinedEngine, Request
from repro.serving.pipeline import place_stages


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--stages", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rng = np.random.default_rng(args.seed)
    net = make_network(rng)

    # ---- 1. build the pipelined engine (stages own param/cache slices)
    eng = PipelinedEngine(cfg, n_stages=args.stages, max_batch=4,
                          cache_len=64, prefill_chunk=8, net=net)
    print(f"{cfg.name}: {args.stages} core stages over "
          f"{cfg.n_layers} layers "
          f"{[ (s.lo, s.hi) for s in eng.stages ]}, "
          f"entry node {eng.entry_node}")

    # ---- 2. profile real per-stage decode latency on this host --------
    measured = eng.profile()
    print("measured stage latency (ms):",
          {k: round(v, 2) for k, v in measured.items()})

    # ---- 3. place: measurements -> application -> integer program ----
    app = eng.to_application(rng, measured_ms=measured)
    for strat in ("static_ip", "round_robin"):
        print(f"  {strat:12s} -> {place_stages(app, net, strat)}")
    eng.set_placement(place_stages(app, net, "static_ip"))

    # ---- 4. execute: serve batched requests through the placement ----
    prompts = [[2 + i % 7, 9, 4, 11, 5, 3, 8, 6] for i in range(10)]
    for i, p in enumerate(prompts):
        eng.submit(Request(id=i, prompt=p, max_new_tokens=12))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print(f"simulated transfer: {eng.transfer_mb:.3f} MB, "
          f"{eng.transfer_ms:.2f} ms over hops "
          f"{ {f'{s}->{d}': v['count'] for (s, d), v in eng.hops.items()} }")


if __name__ == "__main__":
    main()
