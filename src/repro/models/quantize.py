"""Weight-only quantization: packed param pytrees + the qdot dispatch.

``quantize_params(params, fmt)`` rewrites the projection weights of a
``Model.init`` pytree into packed quant leaves; everything else —
embeddings, norms, biases, the LM head, SSM conv/scan params, MoE
experts — stays in the model dtype.  A quantized weight is a dict

    {"q": packed ints, "s": f32 scales}

so it survives every pytree transform the serving stack applies to
params (segment-scan stacking, ``slice_blocks`` stage slicing,
``jax.tree.map`` leading-dim slices) without special cases.

Formats
-------
* ``"int8"`` — per-output-channel symmetric: ``q`` int8 with the shape
  of ``w``; ``s`` f32 ``(..., 1, N)`` = amax over K / 127.
* ``"int4"`` — per-group along K (``group``=64, falling back to
  gcd(K, group) when K is not a multiple): values clipped to [-8, 7],
  biased by +8 and packed two nibbles per byte — ``q`` uint8
  ``(..., K//2, N)`` (packed row r holds k=2r low, k=2r+1 high);
  ``s`` f32 ``(..., K//G, N)`` = per-group amax / 7.

Selection is by key name: exactly the dense projection weights
(``QUANT_KEYS``) quantize.  SSM (in_proj/conv_w/A_log/...) and MoE
(router/we_*) keys never collide with ``QUANT_KEYS``, so those blocks
auto-gate off the same way prefix sharing and speculation gate off
unsupported archs.  Odd-K weights also stay dense (int4 packs pairs).

``qdot(x, w)`` is the single matmul entry point for the projection
sites (attention ``_proj_q``/``_proj_kv``/``_gqa_out``, ``layers.mlp``):
a plain array runs the *exact* einsum the dense path always ran (bf16
streams stay byte-identical with quantization off), a quant dict runs
the dequant-fused path.  On CPU that path is a ``lax.scan`` over
contiguous K-chunks (dequantize one (c, N) tile into registers/L2,
accumulate f32) — the jnp analogue of the Pallas tile kernel in
``kernels/quant_matmul.py``, same relationship the model's attention
has to the flash kernel.  Quantized params enter jit as ordinary
static-shaped operands and are never donated (weights are not linear
state); mutating packed leaves anywhere outside this module is a lint
error (reprolint ``quant-static-weights``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# Exactly the dense projection weights: QKV/O and the SwiGLU MLP.
# Biases, norms, embeddings, the head, SSM and MoE params all miss
# this set and stay in the model dtype.
QUANT_KEYS = frozenset(
    {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"})

QFORMATS = (None, "bf16", "int8", "int4")
DEFAULT_GROUP = 64

# Nominal bytes per weight for capacity math (placement service sizes,
# MBU byte counts use real pytree nbytes instead): int8 = 1 byte,
# int4 = 0.5 byte + one f32 scale per 64-group.
BYTES_PER_PARAM = {None: 2.0, "bf16": 2.0, "int8": 1.0,
                   "int4": 0.5 + 4.0 / DEFAULT_GROUP}

# Golden tolerance policy (SERVING.md §Quantization): a quantized
# stream is pinned *exactly* to its own committed golden
# (tests/golden_decode_quant.json — determinism and cross-engine
# parity stay hard gates), and its fraction of absolute token matches
# against the bf16 golden must clear the per-format floor below.
# Floors sit under the measured minima on the smoke sweep (int8 >=
# 0.67, int4 >= 0.33 outside the exception): quantization error may
# flip argmax at near-ties, and one flipped token reshapes the whole
# suffix, so the fraction — not near-equality of every token — is the
# right lever.  Exception: mixtral int4 — a single router argmax flip
# reselects experts and cascades, so the exact pin is the binding gate
# there and the fraction floor is vacuous.
GOLDEN_TOKEN_MATCH_FLOOR = {"int8": 0.6, "int4": 0.25}
GOLDEN_TOKEN_MATCH_EXCEPTIONS = {("mixtral-8x7b", "int4"): 0.0}


def golden_token_match_floor(arch: str, fmt: str) -> float:
    """Per-(arch, fmt) floor on the fraction of quantized golden tokens
    that must equal the bf16 golden (SERVING.md §Quantization)."""
    arch = arch.removesuffix("-smoke")
    return GOLDEN_TOKEN_MATCH_EXCEPTIONS.get((arch, fmt),
                                             GOLDEN_TOKEN_MATCH_FLOOR[fmt])


def bytes_per_param(fmt: Optional[str]) -> float:
    """Nominal bytes/weight for format ``fmt`` (bf16 baseline 2.0)."""
    if fmt not in BYTES_PER_PARAM:
        raise ValueError(f"unknown qformat {fmt!r}; known: {QFORMATS}")
    return BYTES_PER_PARAM[fmt]


def is_quantized(w) -> bool:
    """True for a packed quant leaf (the qdot dispatch predicate)."""
    return isinstance(w, dict) and "q" in w and "s" in w


# ----------------------------------------------------------------------
# Per-array quantize / pack
# ----------------------------------------------------------------------
def quantize_int8(w):
    """(…, K, N) -> {"q" int8 same shape, "s" f32 (…, 1, N)}."""
    wf = jnp.asarray(w).astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(wf / s), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s}


def pack_int4(q):
    """(…, K, N) ints in [-8, 7] -> (…, K//2, N) uint8 (k=2r low
    nibble, k=2r+1 high nibble, both biased +8)."""
    u = (q + 8).astype(jnp.uint8)
    return u[..., 0::2, :] | (u[..., 1::2, :] << 4)


def unpack_int4(packed):
    """Inverse of :func:`pack_int4`: (…, K//2, N) -> (…, K, N) int8."""
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    stacked = jnp.stack([lo, hi], axis=-2)     # (…, K//2, 2, N)
    return stacked.reshape(*packed.shape[:-2],
                           2 * packed.shape[-2], packed.shape[-1])


def _int4_group(k: int, group: int) -> int:
    return group if k % group == 0 else math.gcd(k, group)


def quantize_int4(w, group: int = DEFAULT_GROUP):
    """(…, K, N) -> {"q" uint8 (…, K//2, N), "s" f32 (…, K//G, N)}.

    K must be even (nibbles pack in pairs); G falls back to
    gcd(K, group) when K is not a multiple of ``group``.
    """
    wf = jnp.asarray(w).astype(jnp.float32)
    k, n = wf.shape[-2], wf.shape[-1]
    assert k % 2 == 0, f"int4 needs even K, got {k}"
    g = _int4_group(k, group)
    wg = wf.reshape(*wf.shape[:-2], k // g, g, n)
    amax = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / 7.0
    q = jnp.clip(jnp.round(wg / s), -8, 7)
    q = q.reshape(*wf.shape[:-2], k, n).astype(jnp.int8)
    return {"q": pack_int4(q), "s": s[..., 0, :]}


def dequantize(w) -> jnp.ndarray:
    """Expand one quant leaf back to an f32 weight matrix."""
    if w["q"].dtype == jnp.int8:               # per-channel int8
        return w["q"].astype(jnp.float32) * w["s"]
    k = 2 * w["q"].shape[-2]                   # packed int4 per-group
    g = k // w["s"].shape[-2]
    return (unpack_int4(w["q"]).astype(jnp.float32)
            * jnp.repeat(w["s"], g, axis=-2))


# ----------------------------------------------------------------------
# Pytree rewrite
# ----------------------------------------------------------------------
def _quantize_leaf(w, fmt: str, group: int):
    if w.ndim < 2 or (fmt == "int4" and w.shape[-2] % 2):
        return w                               # gate off (stay dense)
    if fmt == "int8":
        return quantize_int8(w)
    return quantize_int4(w, group)


def quantize_params(params, fmt: Optional[str],
                    group: int = DEFAULT_GROUP):
    """Rewrite every ``QUANT_KEYS`` weight in a param pytree to a packed
    quant leaf.  Idempotent (already-packed leaves pass through) and a
    no-op for ``fmt`` in (None, "bf16").  Works on full ``Model.init``
    trees and on stacked segment trees alike — stacking adds leading
    dims, and both formats quantize over the trailing (K, N) dims.
    """
    if fmt not in QFORMATS:
        raise ValueError(f"unknown qformat {fmt!r}; known: {QFORMATS}")
    if fmt in (None, "bf16"):
        return params

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, val in node.items():
                if (key in QUANT_KEYS and not is_quantized(val)
                        and hasattr(val, "ndim")):
                    out[key] = _quantize_leaf(val, fmt, group)
                else:
                    out[key] = walk(val)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


def dequantize_params(params, dtype=jnp.bfloat16):
    """Expand every packed leaf back to dense weights in ``dtype``
    (round-trip testing; the serving path never calls this)."""
    def walk(node):
        if is_quantized(node):
            return dequantize(node).astype(dtype)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params)


# ----------------------------------------------------------------------
# The matmul dispatch (traced inside the engines' jits)
# ----------------------------------------------------------------------
def _chunk_len(k: int, multiple: int = 1, cap: int = 256) -> int:
    """Largest divisor of K that is <= cap and a multiple of
    ``multiple`` (the int4 group, so one chunk's scales are whole
    rows).  Chosen at trace time — shapes are static."""
    best = multiple
    c = multiple
    while c <= cap:
        if k % c == 0:
            best = c
        c += multiple
    return best


def _qdot_int8(x, q, s):
    """x (…, K) @ dequant(q (K, N), s (1, N)) via a K-chunked scan.

    One (c, N) int8 chunk converts to f32 and accumulates per step —
    the converted tile dies in cache, so HBM traffic is the int8 bytes
    plus the (M, N) accumulator, not a full f32 weight copy (a naive
    convert-then-dot moves 9 bytes/weight and loses to dense).
    """
    k, n = q.shape
    c = _chunk_len(k)
    xf = x.reshape(-1, k).astype(jnp.float32)
    xb = xf.reshape(-1, k // c, c).transpose(1, 0, 2)   # (K/c, M, c)
    qb = q.reshape(k // c, c, n)

    def body(acc, inp):
        xc, qc = inp
        return acc + xc @ qc.astype(jnp.float32), None

    acc, _ = jax.lax.scan(body, jnp.zeros((xf.shape[0], n), jnp.float32),
                          (xb, qb))
    out = acc * s
    return out.astype(x.dtype).reshape(*x.shape[:-1], n)


def _qdot_int4(x, q, s):
    """x (…, K) @ dequant(q (K//2, N) packed, s (K//G, N)), K-chunked
    with chunks aligned to whole scale groups."""
    k2, n = q.shape
    k = 2 * k2
    g = k // s.shape[-2]
    c = _chunk_len(k, multiple=g)
    xf = x.reshape(-1, k).astype(jnp.float32)
    xb = xf.reshape(-1, k // c, c).transpose(1, 0, 2)   # (K/c, M, c)
    qb = q.reshape(k // c, c // 2, n)
    sb = s.reshape(k // c, c // g, n)

    def body(acc, inp):
        xc, qc, sc = inp
        w = (unpack_int4(qc).astype(jnp.float32)
             * jnp.repeat(sc, g, axis=-2))
        return acc + xc @ w, None

    acc, _ = jax.lax.scan(body, jnp.zeros((xf.shape[0], n), jnp.float32),
                          (xb, qb, sb))
    return acc.astype(x.dtype).reshape(*x.shape[:-1], n)


def qdot(x, w) -> jnp.ndarray:
    """Contract the last dim of ``x`` with the K dim of weight ``w``.

    Structural dispatch: a plain array runs the einsum the dense path
    always ran (identical HLO — bf16 goldens stay byte-identical), a
    packed leaf runs the dequant-fused path for its format.
    """
    if is_quantized(w):
        if w["q"].dtype == jnp.int8:
            return _qdot_int8(x, w["q"], w["s"])
        return _qdot_int4(x, w["q"], w["s"])
    return jnp.einsum("...k,kn->...n", x, w)
