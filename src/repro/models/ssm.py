"""Mamba1 selective scan and Mamba2 (SSD, scalar-A-per-head) blocks.

Sequence mode uses `lax.scan` over time with carry (B, ...) state; decode
mode is the single-step update.  The recurrence is elementwise in d_inner,
so tensor-parallelism over d_inner introduces no collectives inside the
scan (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init
from repro.sharding.specs import constrain


# ----------------------------------------------------------------------
# Shared helpers
# ----------------------------------------------------------------------
def _causal_conv(x, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv. x: (B,T,C), conv_w: (W,C) -> (B,T,C).

    conv_state (B, W-1, C) carries the last inputs of a previous chunk;
    None is equivalent to zeros (start of sequence).
    """
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.pad(x, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(pad[:, i:i + x.shape[1], :] * conv_w[i] for i in range(w))
    return out + conv_b


def _conv_step(conv_state, x_t, conv_w, conv_b):
    """conv_state: (B, W-1, C) past inputs; x_t: (B, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window, conv_w) + conv_b
    return out, window[:, 1:, :]


# ----------------------------------------------------------------------
# Mamba 1
# ----------------------------------------------------------------------
def mamba1_init(key, cfg, dtype) -> dict:
    d, di, ds = cfg.d_model, cfg.d_inner_eff, cfg.ssm_state
    dt_rank = max(1, d // 16)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], (di, dt_rank + 2 * ds), dtype),
        "dt_proj": _dense_init(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.full((di,), -2.0, dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }


def _mamba1_inner(params, x_c, z, cfg):
    """Per-timestep SSM inputs from conv output. x_c: (B,T,di)."""
    d = cfg.d_model
    ds = cfg.ssm_state
    dt_rank = max(1, d // 16)
    proj = jnp.einsum("btd,de->bte", x_c, params["x_proj"])
    dt_r = proj[..., :dt_rank]
    b_mat = proj[..., dt_rank:dt_rank + ds].astype(jnp.float32)
    c_mat = proj[..., dt_rank + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_r, params["dt_proj"])
        + params["dt_bias"]).astype(jnp.float32)
    return dt, b_mat, c_mat


def _mamba1_scan_step(h, inputs, a_neg):
    """h: (B,di,ds). One recurrence step, fp32."""
    dt_t, b_t, c_t, x_t = inputs  # (B,di), (B,ds), (B,ds), (B,di)
    decay = jnp.exp(dt_t[..., None] * a_neg[None])  # (B,di,ds)
    incr = (dt_t * x_t)[..., None] * b_t[:, None, :]
    h = decay * h + incr
    y_t = jnp.einsum("bds,bs->bd", h, c_t)
    return h, y_t


def mamba1_seq(params, x, cfg, h0=None, conv_state=None):
    """Full-sequence forward. x: (B,T,D) -> (y, (h_T, conv_state_T)).

    h0 / conv_state resume the recurrence from a previous chunk
    (chunked prefill); None means start-of-sequence zeros.
    """
    b, t, _ = x.shape
    di, ds = cfg.d_inner_eff, cfg.ssm_state
    xz = jnp.einsum("btd,de->bte", x, params["in_proj"])
    x_i, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_i, params["conv_w"], params["conv_b"],
                                   conv_state))
    dt, b_mat, c_mat = _mamba1_inner(params, x_c, z, cfg)
    a_neg = -jnp.exp(params["A_log"])  # (di, ds)
    x32 = x_c.astype(jnp.float32)

    if h0 is None:
        h0 = jnp.zeros((b, di, ds), jnp.float32)
    h0 = constrain(h0, "ssm_state")

    def step(h, inp):
        return _mamba1_scan_step(h, inp, a_neg)

    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b_mat, 1, 0),
          jnp.moveaxis(c_mat, 1, 0), jnp.moveaxis(x32, 1, 0))
    h_t, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,T,di)
    y = y + params["D"] * x32
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("btd,de->bte", y, params["out_proj"])
    return out, (h_t, _next_conv_state(x_i, conv_state, cfg))


def _next_conv_state(x_i, conv_state, cfg):
    """Last W-1 SSM inputs after a chunk (prepends the carried state so
    chunks shorter than the conv window still roll forward correctly)."""
    w1 = cfg.conv_width - 1
    if conv_state is None:
        conv_state = jnp.zeros((x_i.shape[0], w1, x_i.shape[-1]), x_i.dtype)
    return jnp.concatenate(
        [conv_state.astype(x_i.dtype), x_i], axis=1)[:, -w1:, :]


def mamba1_step(params, x, state, cfg):
    """Decode step. x: (B,1,D); state = (h: (B,di,ds), conv: (B,W-1,di))."""
    h, conv_state = state
    xz = jnp.einsum("btd,de->bte", x, params["in_proj"])[:, 0]
    x_i, z = jnp.split(xz, 2, axis=-1)  # (B, di)
    x_c, conv_state = _conv_step(conv_state, x_i, params["conv_w"],
                                 params["conv_b"])
    x_c = jax.nn.silu(x_c)
    dt, b_mat, c_mat = _mamba1_inner(params, x_c[:, None, :], None, cfg)
    a_neg = -jnp.exp(params["A_log"])
    h, y = _mamba1_scan_step(
        h, (dt[:, 0], b_mat[:, 0], c_mat[:, 0],
            x_c.astype(jnp.float32)), a_neg)
    y = y + params["D"] * x_c.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bd,de->be", y, params["out_proj"])[:, None, :]
    return out, (h, conv_state)


# ----------------------------------------------------------------------
# Mamba 2 (SSD with scalar A per head)
# ----------------------------------------------------------------------
def mamba2_init(key, cfg, dtype) -> dict:
    d, di, ds = cfg.d_model, cfg.d_inner_eff, cfg.ssm_state
    nh = max(1, di // cfg.mamba2_headdim)
    ks = jax.random.split(key, 5)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "bc_proj": _dense_init(ks[2], (d, 2 * ds), dtype),
        "dt_w": _dense_init(ks[3], (d, nh), dtype),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }


def _mamba2_heads(x_c, cfg):
    b, t, di = x_c.shape
    hd = cfg.mamba2_headdim
    return x_c.reshape(b, t, di // hd, hd)


def _mamba2_scan_step(h, inputs, a_neg):
    """h: (B,nh,hd,ds)."""
    dt_t, b_t, c_t, x_t = inputs  # (B,nh), (B,ds), (B,ds), (B,nh,hd)
    decay = jnp.exp(dt_t * a_neg)[..., None, None]  # (B,nh,1,1)
    incr = (dt_t[..., None] * x_t)[..., None] * b_t[:, None, None, :]
    h = decay * h + incr
    y_t = jnp.einsum("bnhs,bs->bnh", h, c_t)
    return h, y_t


def mamba2_seq(params, x, cfg, h0=None, conv_state=None):
    """Full-sequence SSD forward; h0/conv_state as in :func:`mamba1_seq`."""
    b, t, _ = x.shape
    di, ds = cfg.d_inner_eff, cfg.ssm_state
    hd = cfg.mamba2_headdim
    nh = di // hd
    xz = jnp.einsum("btd,de->bte", x, params["in_proj"])
    x_i, z = jnp.split(xz, 2, axis=-1)
    x_c = jax.nn.silu(_causal_conv(x_i, params["conv_w"], params["conv_b"],
                                   conv_state))
    bc = jnp.einsum("btd,de->bte", x, params["bc_proj"]).astype(jnp.float32)
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btd,dn->btn", x, params["dt_w"]).astype(jnp.float32)
        + params["dt_bias"])
    a_neg = -jnp.exp(params["A_log"])  # (nh,)
    xh = _mamba2_heads(x_c.astype(jnp.float32), cfg)

    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)

    def step(h, inp):
        return _mamba2_scan_step(h, inp, a_neg)

    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b_mat, 1, 0),
          jnp.moveaxis(c_mat, 1, 0), jnp.moveaxis(xh, 1, 0))
    h_t, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)  # (B,T,nh,hd)
    y = y + params["D"][:, None] * xh
    y = y.reshape(b, t, di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("btd,de->bte", y, params["out_proj"])
    return out, (h_t, _next_conv_state(x_i, conv_state, cfg))


def mamba2_step(params, x, state, cfg):
    h, conv_state = state
    di = cfg.d_inner_eff
    hd = cfg.mamba2_headdim
    nh = di // hd
    x0 = x[:, 0]
    xz = jnp.einsum("bd,de->be", x0, params["in_proj"])
    x_i, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = _conv_step(conv_state, x_i, params["conv_w"],
                                 params["conv_b"])
    x_c = jax.nn.silu(x_c)
    bc = jnp.einsum("bd,de->be", x0, params["bc_proj"]).astype(jnp.float32)
    b_mat, c_mat = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bd,dn->bn", x0, params["dt_w"]).astype(jnp.float32)
        + params["dt_bias"])
    a_neg = -jnp.exp(params["A_log"])
    xh = x_c.astype(jnp.float32).reshape(-1, nh, hd)
    h, y = _mamba2_scan_step(h, (dt, b_mat, c_mat, xh), a_neg)
    y = y + params["D"][:, None] * xh
    y = y.reshape(x.shape[0], di)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bd,de->be", y, params["out_proj"])[:, None, :]
    return out, (h, conv_state)
