"""Blocks + segment-scan stacking.

A model is a ``block_pattern`` (one kind per layer).  Contiguous runs of the
same kind are *segments*: their params are stacked with a leading layer dim
and applied with ``lax.scan`` — this keeps lowering/compile time roughly
O(#segments), not O(#layers), which matters for the 512-device dry-run of
80–100-layer models.

Weight-shared blocks (zamba2) draw params from a single ``shared`` set and
are applied outside the scan.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp, mlp_init, rmsnorm, rmsnorm_init
from repro.sharding.specs import constrain


@dataclass(frozen=True)
class Segment:
    kind: str
    length: int
    shared: bool


def build_segments(cfg) -> List[Segment]:
    segs: List[Segment] = []
    for b in cfg.block_pattern:
        shared = b == cfg.shared_block_kind
        if segs and segs[-1].kind == b and not shared and not segs[-1].shared:
            segs[-1] = Segment(b, segs[-1].length + 1, False)
        else:
            segs.append(Segment(b, 1, shared))
    return segs


# ----------------------------------------------------------------------
# Single block
# ----------------------------------------------------------------------
def _has_mlp(kind: str, cfg) -> bool:
    return kind in ("attn", "swa", "cross") and cfg.mlp_kind != "none"


def block_init(key, kind: str, cfg, dtype, has_enc_cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p = {"ln1": rmsnorm_init(d, dtype)}
    if kind in ("attn", "swa"):
        p["attn"] = attn_mod.attention_init(ks[0], cfg, dtype)
    elif kind == "cross":
        p["xattn"] = attn_mod.attention_init(ks[0], cfg, dtype, cross=True)
    elif kind == "mamba1":
        p["mamba"] = ssm_mod.mamba1_init(ks[0], cfg, dtype)
    elif kind == "mamba2":
        p["mamba"] = ssm_mod.mamba2_init(ks[0], cfg, dtype)
    if has_enc_cross and kind in ("attn", "swa"):
        p["ln_x"] = rmsnorm_init(d, dtype)
        p["enc_xattn"] = attn_mod.attention_init(ks[1], cfg, dtype, cross=True)
    if _has_mlp(kind, cfg):
        p["ln2"] = rmsnorm_init(d, dtype)
        if cfg.mlp_kind == "moe":
            p["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dtype)
    return p


def _empty_aux():
    return {"moe_aux_loss": jnp.zeros((), jnp.float32),
            "moe_drop_frac": jnp.zeros((), jnp.float32)}


def block_apply(params: dict, x, *, kind: str, cfg, mode: str,
                positions=None, pos=None, cache: Optional[dict] = None,
                frontend=None, enc_src=None, causal: bool = True,
                paged: Optional[dict] = None,
                qformat: Optional[str] = None,
                ) -> Tuple[jnp.ndarray, Optional[dict], dict]:
    """Apply one block.  Returns (x, cache_out, aux).

    ``paged`` switches the decode/chunk cache paths to block-pool
    addressing (block tables from ``models.kvcache.PagedCache.meta``);
    train/prefill modes are dense-only.

    ``qformat`` tags the weight format the params were packed to
    ("int8"/"int4", `models/quantize.py`).  Numeric dispatch is
    *structural* — ``qdot`` routes on packed-leaf-vs-array, so a block
    whose weights stayed dense (SSM, MoE, odd-K) runs the exact dense
    math — but the tag travels with the call so jit keys, stage
    slices, and the roofline audit all see which format they measure.
    """
    aux = _empty_aux()
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    cache_out = None

    if kind in ("attn", "swa"):
        if mode == "decode":
            if paged is not None:
                a, kv = attn_mod.paged_decode_self_attention(
                    params["attn"], h, cache, paged, pos, cfg, kind)
            else:
                a, kv = attn_mod.decode_self_attention(
                    params["attn"], h, {"k": cache["k"], "v": cache["v"]},
                    pos, cfg, kind)
            cache_out = dict(cache, **kv)
        elif mode == "chunk":
            if paged is not None:
                a, kv = attn_mod.paged_chunk_self_attention(
                    params["attn"], h, cache, paged, pos, cfg, kind)
            else:
                a, kv = attn_mod.chunk_self_attention(
                    params["attn"], h, {"k": cache["k"], "v": cache["v"]},
                    pos, cfg, kind)
            cache_out = dict(cache, **kv)
        else:
            a, kv = attn_mod.self_attention(params["attn"], h, positions,
                                            cfg, kind, causal=causal)
            if mode == "prefill":
                cache_out = _seed_attn_cache(kv, cache, kind, cfg)
        x = x + a
        if "enc_xattn" in params:  # enc-dec decoder block
            hx = rmsnorm(params["ln_x"], x, cfg.norm_eps)
            if mode in ("decode", "chunk"):
                xkv = (attn_mod.paged_cross_view(cache, paged,
                                                 cfg.encoder_seq)
                       if paged is not None
                       else {"k": cache["xk"], "v": cache["xv"]})
            else:
                xkv = attn_mod.make_cross_kv(params["enc_xattn"], enc_src, cfg)
                if mode == "prefill":
                    cache_out = dict(cache_out or cache,
                                     xk=xkv["k"], xv=xkv["v"])
            x = x + attn_mod.cross_attention(params["enc_xattn"], hx, xkv, cfg)
    elif kind == "cross":
        if mode in ("decode", "chunk"):
            if paged is not None:
                src = cfg.n_image_tokens or cfg.encoder_seq
                xkv = attn_mod.paged_cross_view(cache, paged, src)
            else:
                xkv = {"k": cache["xk"], "v": cache["xv"]}
            cache_out = cache
        else:
            xkv = attn_mod.make_cross_kv(params["xattn"], frontend, cfg)
            if mode == "prefill":
                cache_out = {"xk": xkv["k"], "xv": xkv["v"]}
        x = x + attn_mod.cross_attention(params["xattn"], h, xkv, cfg)
    elif kind in ("mamba1", "mamba2"):
        fn_seq = ssm_mod.mamba1_seq if kind == "mamba1" else ssm_mod.mamba2_seq
        fn_step = ssm_mod.mamba1_step if kind == "mamba1" else ssm_mod.mamba2_step
        if mode == "decode":
            a, (hs, cs) = fn_step(params["mamba"], h, (cache["h"], cache["conv"]),
                                  cfg)
            cache_out = {"h": hs, "conv": cs}
        elif mode == "chunk":
            a, (hs, cs) = fn_seq(params["mamba"], h, cfg,
                                 h0=cache["h"], conv_state=cache["conv"])
            cache_out = {"h": hs, "conv": cs}
        else:
            a, (hs, cs) = fn_seq(params["mamba"], h, cfg)
            if mode == "prefill":
                cache_out = {"h": hs, "conv": cs}
        x = x + a
    else:
        raise ValueError(kind)

    if _has_mlp(kind, cfg):
        h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
        if cfg.mlp_kind == "moe":
            m, moe_aux = moe_mod.moe_apply(params["moe"], h2, cfg)
            aux = {k: aux[k] + moe_aux[k] for k in aux}
        else:
            m = mlp(params["mlp"], h2)
        x = x + m
    return constrain(x, "act_btd"), cache_out, aux


def _seed_attn_cache(kv, cache, kind, cfg):
    """Write prefill K/V into a fixed-size cache buffer."""
    if cache is None:
        return kv
    k, v = kv["k"], kv["v"]
    s_cache = cache["k"].shape[-3]
    s_new = k.shape[-3]
    if kind == "swa" and s_new > s_cache:
        # keep last `window` entries; ring-consistent because slot = pos % W
        # and after a full wrap the ring holds exactly the last W positions
        # in rotated order (attention is permutation-invariant post-rope).
        start = s_new - s_cache
        shift = start % s_cache
        k_tail = jnp.roll(k[..., start:, :, :], shift, axis=-3)
        v_tail = jnp.roll(v[..., start:, :, :], shift, axis=-3)
        return dict(cache, k=k_tail, v=v_tail)
    pad = s_cache - min(s_new, s_cache)
    k_new = jnp.pad(k[..., -s_cache:, :, :], _pad_spec(k, pad))
    v_new = jnp.pad(v[..., -s_cache:, :, :], _pad_spec(v, pad))
    return dict(cache, k=k_new.astype(cache["k"].dtype),
                v=v_new.astype(cache["v"].dtype))


def _pad_spec(arr, pad):
    spec = [(0, 0)] * arr.ndim
    spec[-3] = (0, pad)
    return spec


# ----------------------------------------------------------------------
# Segment init / apply
# ----------------------------------------------------------------------
def init_segments(key, cfg, dtype, has_enc_cross: bool = False):
    segs = build_segments(cfg)
    keys = jax.random.split(key, len(segs) + 1)
    seg_params = []
    shared_params = None
    for seg, k in zip(segs, keys):
        if seg.shared:
            if shared_params is None:
                shared_params = block_init(keys[-1], seg.kind, cfg, dtype,
                                           has_enc_cross)
            seg_params.append(None)
        elif seg.length == 1:
            seg_params.append(block_init(k, seg.kind, cfg, dtype,
                                         has_enc_cross))
        else:
            ks = jax.random.split(k, seg.length)
            seg_params.append(
                jax.vmap(lambda kk: block_init(kk, seg.kind, cfg, dtype,
                                               has_enc_cross))(ks))
    return {"segments": seg_params, "shared": shared_params}


# ----------------------------------------------------------------------
# Layer-range restriction (pipeline-parallel stages)
# ----------------------------------------------------------------------
def segment_slices(cfg, lo: int, hi: int):
    """Map decoder layers [lo, hi) onto the segment list.

    Returns [(seg_index, a, b)]: full-model segment ``seg_index``
    contributes its local layers [a, b).  Stage boundaries may fall
    inside a segment, in which case the stacked params/caches are sliced
    along their leading layer dim.
    """
    assert 0 <= lo < hi <= cfg.n_layers, (lo, hi, cfg.n_layers)
    out = []
    base = 0
    for i, seg in enumerate(build_segments(cfg)):
        a, b = max(lo, base), min(hi, base + seg.length)
        if a < b:
            out.append((i, a - base, b - base))
        base += seg.length
    return out


def segment_range(cfg, lo: int, hi: int) -> List[Segment]:
    """Segment list restricted to decoder layers [lo, hi)."""
    segs = build_segments(cfg)
    return [Segment(segs[i].kind, b - a, segs[i].shared)
            for i, a, b in segment_slices(cfg, lo, hi)]


def slice_blocks(blocks: dict, cfg, lo: int, hi: int) -> dict:
    """Restrict a ``{"segments", "shared"}`` param tree to layers [lo, hi).

    The result aligns with :func:`segment_range` and holds *only* the
    stage's parameters (plus the shared set, which weight-tied layers
    draw from wherever they run) — a pipeline stage sliced this way owns
    nothing outside its layer range.
    """
    segs = build_segments(cfg)
    sub = []
    for i, a, b in segment_slices(cfg, lo, hi):
        p = blocks["segments"][i]
        if segs[i].shared or p is None:
            sub.append(None)
        elif segs[i].length == 1:
            sub.append(p)                      # unstacked single layer
        elif b - a == 1:
            sub.append(jax.tree.map(lambda t: t[a], p))  # noqa: B023
        else:
            sub.append(jax.tree.map(lambda t: t[a:b], p))  # noqa: B023
    return {"segments": sub, "shared": blocks["shared"]}


def apply_segments(blocks, x, *, cfg, mode, segs=None, positions=None,
                   pos=None, caches=None, frontend=None, enc_src=None,
                   causal=True, remat=None, unroll=False, paged=None,
                   qformat=None):
    """Run all segments.  caches: list aligned with segments (or None).

    remat: checkpoint each block in training so backward recomputes
    activations (defaults to True for mode=="train").
    unroll: replace lax.scan with a Python loop (used by the roofline cost
    audit, where scan bodies would be counted once by cost_analysis).
    paged: block-table metadata dict for paged decode/chunk caches —
    shared by every segment (tables are per-request, not per-layer), so
    it rides in the closure, not through the scan.
    qformat: weight-format tag for packed params (models/quantize.py) —
    rides in the closure like ``paged``; packed {"q","s"} leaves stack
    and slice through the scan exactly like dense weights.
    """
    segs = segs if segs is not None else build_segments(cfg)
    remat = (mode == "train") if remat is None else remat
    aux_total = _empty_aux()
    new_caches = []
    for i, seg in enumerate(segs):
        params = blocks["shared"] if seg.shared else blocks["segments"][i]
        cache = caches[i] if caches is not None else None
        kw = dict(kind=seg.kind, cfg=cfg, mode=mode, positions=positions,
                  pos=pos, frontend=frontend, enc_src=enc_src, causal=causal,
                  paged=paged, qformat=qformat)

        def apply_one(p, xx, c):
            return block_apply(p, xx, cache=c, **kw)

        if remat:
            apply_one = jax.checkpoint(apply_one)

        if seg.length == 1 or seg.shared:
            c0 = (None if cache is None
                  else jax.tree.map(lambda a: a[0], cache))
            x, c_out, aux = apply_one(params, x, c0)
            if c_out is not None:
                c_out = jax.tree.map(lambda a: a[None], c_out)
        elif unroll:
            c_outs, auxes = [], []
            for j in range(seg.length):
                pj = jax.tree.map(lambda a: a[j], params)
                cj = None if cache is None else jax.tree.map(
                    lambda a: a[j], cache)
                x, c_out, aux = apply_one(pj, x, cj)
                c_outs.append(c_out)
                auxes.append(aux)
            c_out = (None if c_outs[0] is None else jax.tree.map(
                lambda *a: jnp.stack(a), *c_outs))
            aux = jax.tree.map(lambda *a: sum(a), *auxes)
        else:
            def body(carry, slices):
                p, c = slices
                y, c_out, aux = apply_one(p, carry, c)
                return y, (c_out, aux)
            x, (c_out, aux_stack) = jax.lax.scan(body, x, (params, cache))
            aux = jax.tree.map(jnp.sum, aux_stack)
        aux_total = {k: aux_total[k] + aux[k] for k in aux_total}
        new_caches.append(c_out)
    return x, new_caches, aux_total
