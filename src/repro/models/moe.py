"""Sort-based (scatter/gather) Mixture-of-Experts layer.

Classic one-hot dispatch einsum needs a (T, E, C) tensor which is infeasible
for Kimi-K2-scale expert counts (E=384); instead we sort token->expert
assignments and scatter into an (E, C, D) buffer (the standard
expert-parallel layout: the E axis shards over the `model` mesh axis, so
GSPMD lowers the scatter/gather to an all-to-all pair).

Overflowed tokens (expert over capacity) are dropped — they pass through on
the residual stream, matching capacity-factor semantics.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

import os

from repro.models.layers import _dense_init
from repro.sharding.specs import constrain, current_mesh


def moe_init(key, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff_eff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "we_gate": _dense_init(ks[1], (e, d, f), dtype),
        "we_up": _dense_init(ks[2], (e, d, f), dtype),
        "we_down": _dense_init(ks[3], (e, f, d), dtype),
    }


def _capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor
            / cfg.n_experts) + 1
    # MXU-friendly rounding
    return max(8, -(-c // 8) * 8)


def moe_apply(params: dict, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, dict]:
    """x: (..., T, D) -> (..., T, D), aux metrics.

    Works on the flattened token axis.  With REPRO_MOE_SHARDMAP=1 and an
    expert-divisible mesh, dispatch goes through the shard_map
    slice-dispatch path (§Perf iteration M2) instead of GSPMD.
    """
    mesh = current_mesh()
    if (os.environ.get("REPRO_MOE_SHARDMAP") and mesh is not None
            and "model" in mesh.axis_names and x.ndim == 3):
        if cfg.n_experts % mesh.shape["model"] == 0:
            return moe_apply_sharded(params, x, cfg, mesh)
        return moe_apply_capsharded(params, x, cfg, mesh)
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(t, cfg)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # --- position of each assignment within its expert ------------------
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(t * k) - starts[sorted_e]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))

    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # overflow -> dropped

    # --- dispatch --------------------------------------------------------
    x_rep = jnp.repeat(xt, k, axis=0)  # (T*k, D) token order: t0k0 t0k1 ...
    buf = jnp.zeros((e * cap, d), xt.dtype)
    buf = buf.at[slot].set(x_rep, mode="drop")
    buf = constrain(buf.reshape(e, cap, d), "moe_buf")

    # --- expert computation (E, C, D) x (E, D, F) ------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["we_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["we_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["we_down"])
    out_buf = constrain(out_buf, "moe_buf").reshape(e * cap, d)

    # --- combine ----------------------------------------------------------
    gathered = jnp.where(keep[:, None], out_buf.at[slot].get(mode="fill",
                                                             fill_value=0), 0)
    gathered = gathered.reshape(t, k, d)
    y = jnp.sum(gathered * gate_vals[..., None].astype(gathered.dtype), axis=1)

    # --- aux: load-balance loss (Switch-style) ---------------------------
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux_loss = e * jnp.sum(me * ce) * cfg.router_aux_weight
    dropped = jnp.sum(~keep) / (t * k)
    aux = {"moe_aux_loss": aux_loss, "moe_drop_frac": dropped}
    return y.reshape(orig_shape).astype(x.dtype), aux


# ----------------------------------------------------------------------
# §Perf iteration M2: shard_map slice-dispatch MoE
# ----------------------------------------------------------------------
def moe_apply_sharded(params: dict, x: jnp.ndarray, cfg,
                      mesh) -> Tuple[jnp.ndarray, dict]:
    """Expert-parallel MoE with an explicit communication schedule.

    The GSPMD path pays a giant collective because position-in-expert
    needs a *global* argsort over tokens (the partitioner all-gathers the
    assignment arrays).  Here every (data, model) device:

      1. routes its LOCAL tokens (router weights replicated — identical
         compute across the model axis, zero wire bytes);
      2. scatters them into a local (E, C_loc, D) buffer and *slices* the
         expert range it owns (dispatch = free);
      3. runs its E/n_model experts;
      4. gathers its experts' outputs back to token order and psums over
         the model axis — O(T_loc * D) bytes, the only collective.

    Wire bytes per layer: T_loc * D * 4 (one psum) vs the sort path's
    multi-GB gathers — see EXPERIMENTS.md §Perf.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_model = mesh.shape["model"]
    b_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    e, k = cfg.n_experts, cfg.experts_per_token
    e_loc = e // n_model
    d = x.shape[-1]

    def body(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        xt = xl.reshape(-1, d)
        t = xt.shape[0]
        cap = _capacity(t, cfg)

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        flat_e = expert_idx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)        # local sort only
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        pos_sorted = jnp.arange(t * k) - starts[sorted_e]
        pos = jnp.zeros((t * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
        keep = pos < cap
        slot = jnp.where(keep, flat_e * cap + pos, e * cap)

        x_rep = jnp.repeat(xt, k, axis=0)
        buf = jnp.zeros((e * cap, d), xt.dtype)
        buf = buf.at[slot].set(x_rep, mode="drop").reshape(e, cap, d)

        # 2) slice my expert range (weights arrive pre-sliced: (E_loc,..))
        r = jax.lax.axis_index("model")
        my = jax.lax.dynamic_slice_in_dim(buf, r * e_loc, e_loc, axis=0)

        # 3) local expert compute
        g = jnp.einsum("ecd,edf->ecf", my, wg)
        u = jnp.einsum("ecd,edf->ecf", my, wu)
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e_loc * cap, d)

        # 4) token-order gather of MY experts' outputs, then psum
        mine = keep & (flat_e >= r * e_loc) & (flat_e < (r + 1) * e_loc)
        slot_mine = jnp.where(mine, (flat_e - r * e_loc) * cap + pos, 0)
        gathered = jnp.where(
            mine[:, None],
            out_buf.at[slot_mine].get(mode="fill", fill_value=0), 0)
        y = jnp.sum(gathered.reshape(t, k, d)
                    * gate_vals[..., None].astype(gathered.dtype), axis=1)
        y = jax.lax.psum(y, "model")

        # aux (identical across model ranks; psum-average over data later
        # is unnecessary — scalars are consistent estimators per shard)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e,
                                     dtype=jnp.float32), axis=0)
        aux_loss = e * jnp.sum(me * ce) * cfg.router_aux_weight
        dropped = jnp.sum(~keep) / (t * k)
        return (y.reshape(bl, sl, d).astype(xl.dtype), aux_loss, dropped)

    y, aux_loss, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(P(b_axes, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(b_axes, None, None), P(), P()),
        check_rep=False,
    )(x, params["router"], params["we_gate"], params["we_up"],
      params["we_down"])
    return y, {"moe_aux_loss": aux_loss, "moe_drop_frac": dropped}


def moe_apply_capsharded(params: dict, x: jnp.ndarray, cfg,
                         mesh) -> Tuple[jnp.ndarray, dict]:
    """§Perf iteration M3: capacity-sharded shard_map MoE for E < n_model
    (mixtral: 8 experts on a 16-wide model axis).

    Every model rank keeps FULL expert weights (8x3 small matrices) but
    processes only its 1/n_model slice of every expert's capacity;
    the single collective is the final output psum (O(T_loc * D)).
    Expert FLOPs per device drop n_model-fold vs. the GSPMD fallback,
    which could not shard an 8-long expert dim over 16 ranks.
    """
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n_model = mesh.shape["model"]
    b_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    e, k = cfg.n_experts, cfg.experts_per_token
    d = x.shape[-1]

    def body(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        xt = xl.reshape(-1, d)
        t = xt.shape[0]
        cap = _capacity(t, cfg)
        cap_loc = -(-cap // n_model)

        logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

        flat_e = expert_idx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        pos_sorted = jnp.arange(t * k) - starts[sorted_e]
        pos = jnp.zeros((t * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
        keep = pos < cap

        # my capacity window of every expert
        r = jax.lax.axis_index("model")
        lo = r * cap_loc
        mine = keep & (pos >= lo) & (pos < lo + cap_loc)
        slot = jnp.where(mine, flat_e * cap_loc + (pos - lo), e * cap_loc)

        x_rep = jnp.repeat(xt, k, axis=0)
        buf = jnp.zeros((e * cap_loc, d), xt.dtype)
        buf = buf.at[slot].set(x_rep, mode="drop").reshape(e, cap_loc, d)

        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd).reshape(
            e * cap_loc, d)

        gathered = jnp.where(
            mine[:, None],
            out_buf.at[jnp.where(mine, slot, 0)].get(
                mode="fill", fill_value=0), 0)
        y = jnp.sum(gathered.reshape(t, k, d)
                    * gate_vals[..., None].astype(gathered.dtype), axis=1)
        y = jax.lax.psum(y, "model")

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e,
                                     dtype=jnp.float32), axis=0)
        aux_loss = e * jnp.sum(me * ce) * cfg.router_aux_weight
        dropped = jnp.sum(~keep) / (t * k)
        return (y.reshape(bl, sl, d).astype(xl.dtype), aux_loss, dropped)

    y, aux_loss, dropped = shard_map(
        body, mesh=mesh,
        in_specs=(P(b_axes, None, None), P(None, None),
                  P(None, None, None), P(None, None, None),
                  P(None, None, None)),
        out_specs=(P(b_axes, None, None), P(), P()),
        check_rep=False,
    )(x, params["router"], params["we_gate"], params["we_up"],
      params["we_down"])
    return y, {"moe_aux_loss": aux_loss, "moe_drop_frac": dropped}
