"""Grouped-query attention: full / sliding-window / cross, train + decode.

Rotary is applied to K at *write* time, so decode attention over a cache
(ring buffer for SWA) is permutation-safe.  Score math is fp32.

Decode paths are the body of the engines' fused macro-step
(``Model.decode_steps``, a ``lax.scan`` carrying the cache): ``pos`` may
be *frozen* for rows the scheduler has masked (a finished or empty batch
row keeps re-writing its last slot from token 0 — the same ops the
per-token host loop always ran for inactive rows), and under buffer
donation the cache-in/cache-out pairs alias, so the ``.at[].set`` writes
update the pools in place.  Both rely on the invariants documented in
`src/repro/models/kvcache.py`: stale KV is position-masked, unallocated
paged slots resolve to the never-read scratch block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, rotary
from repro.models.quantize import qdot
from repro.sharding.specs import constrain

NEG_INF = -1e30


def attention_init(key, cfg, dtype, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, kv * hd), dtype),
        "wv": _dense_init(ks[2], (d, kv * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _proj_q(params, x, cfg):
    # qdot == the einsum these projections always ran for plain
    # arrays; packed weight leaves (models/quantize.py) take the
    # dequant-fused path — biases stay in the model dtype either way
    q = qdot(x, params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    q = q.reshape(*x.shape[:-1], cfg.n_heads, cfg.head_dim)
    return constrain(q, "act_bthd")


def _proj_kv(params, x, cfg):
    k = qdot(x, params["wk"])
    v = qdot(x, params["wv"])
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    k = k.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(*x.shape[:-1], cfg.n_kv_heads, cfg.head_dim)
    return constrain(k, "act_btkv"), constrain(v, "act_btkv")


def _gqa_scores(q, k, cfg):
    """q: (B,Q,H,hd), k: (B,S,KV,hd) -> (B,KV,G,Q,S) fp32 scores."""
    b, qlen, h, hd = q.shape
    kvh = cfg.n_kv_heads
    g = h // kvh
    qg = q.reshape(b, qlen, kvh, g, hd)
    scores = jnp.einsum("bqngh,bsnh->bngqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    return scores * (hd ** -0.5)


def _gqa_out(probs, v, params, cfg, out_dtype):
    """probs: (B,KV,G,Q,S), v: (B,S,KV,hd) -> (B,Q,D)."""
    b = probs.shape[0]
    out = jnp.einsum("bngqs,bsnh->bqngh", probs, v.astype(jnp.float32))
    out = out.reshape(b, out.shape[1], cfg.n_heads * cfg.head_dim)
    out = out.astype(out_dtype)
    return qdot(out, params["wo"])


def _causal_mask(qlen: int, klen: int, q_offset, window: int = 0):
    """(Q, S) additive mask; window>0 limits lookback."""
    qpos = jnp.arange(qlen)[:, None] + q_offset
    kpos = jnp.arange(klen)[None, :]
    ok = kpos <= qpos
    if window > 0:
        ok = ok & (kpos > qpos - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


Q_CHUNK = 4096  # max query-block width for the unrolled blockwise attention


def self_attention(params, x, positions, cfg, kind: str,
                   causal: bool = True) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence self-attention (train / prefill).

    Long sequences are processed in *statically unrolled* query blocks
    (Python loop, not lax.scan) so (a) the S x S score buffer never
    materializes — per-block peak is (B, H, Q_CHUNK, S) — and (b) HLO
    cost_analysis still counts every block's FLOPs (scan bodies are
    counted once; unrolled blocks are not).  Sliding-window blocks
    additionally slice K/V to the reachable window.  This is the jnp
    analogue of the Pallas flash kernel in repro.kernels.

    Returns (out, {"k","v"}) so prefill can populate the cache.
    """
    q = _proj_q(params, x, cfg)
    k, v = _proj_kv(params, x, cfg)
    q = rotary(q, positions, cfg.rope_theta)
    k = rotary(k, positions, cfg.rope_theta)
    s = x.shape[-2]
    window = cfg.window if kind == "swa" else 0

    if s <= Q_CHUNK:
        scores = _gqa_scores(q, k, cfg)
        if causal:
            scores = scores + _causal_mask(s, s, 0, window)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v, params, cfg, x.dtype)
        return out, {"k": k, "v": v}

    outs = []
    for q0 in range(0, s, Q_CHUNK):
        q1 = min(q0 + Q_CHUNK, s)
        qb = q[:, q0:q1]
        if causal:
            k0 = max(0, q0 - window + 1) if window else 0
            k1 = q1  # keys beyond the block are masked anyway
        else:
            k0, k1 = 0, s
        kb, vb = k[:, k0:k1], v[:, k0:k1]
        scores = _gqa_scores(qb, kb, cfg)
        if causal:
            qpos = jnp.arange(q0, q1)[:, None]
            kpos = jnp.arange(k0, k1)[None, :]
            ok = kpos <= qpos
            if window:
                ok = ok & (kpos > qpos - window)
            scores = scores + jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
        probs = jax.nn.softmax(scores, axis=-1)
        outs.append(_gqa_out(probs, vb, params, cfg, x.dtype))
    return jnp.concatenate(outs, axis=1), {"k": k, "v": v}


def cross_attention(params, x, kv: dict, cfg) -> jnp.ndarray:
    """x attends to precomputed (k, v) from another modality/stack."""
    q = _proj_q(params, x, cfg)  # no rotary across modalities
    scores = _gqa_scores(q, kv["k"], cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, kv["v"], params, cfg, x.dtype)


def make_cross_kv(params, src, cfg) -> dict:
    k, v = _proj_kv(params, src, cfg)
    return {"k": k, "v": v}


def chunk_self_attention(params, x, cache: dict, pos, cfg,
                         kind: str) -> Tuple[jnp.ndarray, dict]:
    """C-token cache-resuming attention (chunked prefill).

    x: (B,C,D) tokens at absolute positions pos[b] .. pos[b]+C-1;
    cache {"k","v"}: (B,S,KV,hd) holding all positions < pos[b]
    (ring-buffered for swa).  Returns (out, updated cache) such that the
    cache afterwards equals what C successive ``decode_self_attention``
    calls would have produced; out matches them token-for-token.
    """
    b, c, _ = x.shape
    cache_len = cache["k"].shape[1]
    q = _proj_q(params, x, cfg)
    k_new, v_new = _proj_kv(params, x, cfg)
    positions = pos[:, None] + jnp.arange(c)[None, :]          # (B,C)
    q = rotary(q, positions, cfg.rope_theta)
    k_new = rotary(k_new, positions, cfg.rope_theta)
    qpos = positions[:, None, :, None]                         # (B,1,C,1)

    if kind == "swa" and cfg.window:
        # --- ring buffer: future in-chunk writes may clobber slots a
        # query earlier in the chunk must still see, so score against
        # [old ring ; chunk keys] with analytic old positions instead of
        # write-then-mask.  Old slot j holds the most recent position
        # p < pos with p % W == j, i.e. p_old = pos - W + ((j - pos) mod W).
        w = cache_len
        j = jnp.arange(w)[None, :]
        p_old = pos[:, None] - w + (j - pos[:, None]) % w      # (B,W)
        k_all = jnp.concatenate([cache["k"], k_new], axis=1)
        v_all = jnp.concatenate([cache["v"], v_new], axis=1)
        kpos = jnp.concatenate(
            [p_old, positions], axis=1)[:, None, None, :]      # (B,1,1,W+C)
        valid = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - w)
        scores = _gqa_scores(q, k_all, cfg)
        scores = scores + jnp.where(valid, 0.0, NEG_INF).astype(
            jnp.float32)[:, :, None]                 # (B,1,1,C,W+C)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v_all, params, cfg, x.dtype)
        # ring write: the last min(C, W) chunk keys land in the cache
        # (earlier ones would be clobbered; slicing avoids duplicate
        # scatter indices, whose write order is unspecified)
        keep = min(c, w)
        slots = positions[:, -keep:] % w
        bidx = jnp.arange(b)[:, None]
        k = cache["k"].at[bidx, slots].set(k_new[:, -keep:])
        v = cache["v"].at[bidx, slots].set(v_new[:, -keep:])
        return out, {"k": k, "v": v}

    # --- linear cache: write the chunk, then mask.  Slot index ==
    # position, so keys at slots >= pos[b]+i (in-chunk future or stale
    # entries from a previous occupant of this batch row) mask out and
    # slots < pos hold the true prefix.
    slots = jnp.minimum(positions, cache_len - 1)
    bidx = jnp.arange(b)[:, None]
    k = cache["k"].at[bidx, slots].set(k_new)
    v = cache["v"].at[bidx, slots].set(v_new)
    scores = _gqa_scores(q, k, cfg)                            # (B,KV,G,C,S)
    kpos = jnp.arange(cache_len)[None, None, None, :]
    valid = kpos <= qpos                                       # (B,1,C,S)
    scores = scores + jnp.where(valid, 0.0, NEG_INF).astype(
        jnp.float32)[:, :, None]                     # (B,1,1,C,S)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, params, cfg, x.dtype)
    return out, {"k": k, "v": v}


# ----------------------------------------------------------------------
# Paged variants: block pools + block tables (see models/kvcache.py).
# Same math as the dense paths below, addressed through per-request
# block tables; greedy outputs are bit-identical because the gathered
# view reproduces the dense cache's logical slot order and every
# stale/unallocated slot is masked exactly where the dense path masks
# its zero-initialised slots.
# ----------------------------------------------------------------------
def _paged_gather(pool, tables, take: Optional[int] = None):
    """pool (NB, bs, KV, hd) gathered through tables (B, nb) into the
    logical view (B, nb*bs, KV, hd), optionally truncated to ``take``
    slots (SWA ring / cross source shorter than the block grid)."""
    g = pool[tables]                                 # (B, nb, bs, KV, hd)
    b, nb, bs = g.shape[:3]
    g = g.reshape(b, nb * bs, *g.shape[3:])
    return g if take is None else g[:, :take]


def _decode_valid(pos, s: int, ring: bool):
    """(B, S) bool validity of cache slots for one-token decode: slot
    index <= pos, plus the ring's all-slots-valid regime once a SWA ring
    has fully wrapped (pos >= window - 1).  Shared by the dense and
    paged decode paths so their masking stays bit-for-bit aligned."""
    sidx = jnp.arange(s)
    valid = sidx[None, :] <= pos[:, None]
    if ring:
        valid = valid | (pos[:, None] >= s - 1)
    return valid


def paged_cross_view(cache: dict, paged: dict, src: int) -> dict:
    """Cross-KV logical view of each row's cross blocks (zeroed at
    admission, so this matches the dense engines' zero cross rows)."""
    return {"k": _paged_gather(cache["xk"], paged["cross_tables"], src),
            "v": _paged_gather(cache["xv"], paged["cross_tables"], src)}


def paged_decode_self_attention(params, x, cache: dict, paged: dict, pos,
                                cfg, kind: str) -> Tuple[jnp.ndarray, dict]:
    """One-token decode against paged block pools.

    x: (B,1,D); cache {"k","v"}: (NB_phys, bs, KV, hd) pools; paged
    carries the block tables (``tables`` always; ``swa_tables`` for
    ring segments).  Mirrors :func:`decode_self_attention` slot-for-
    slot: the new K/V lands at the physical home of the dense slot and
    scores run over the gathered logical view.
    """
    b = x.shape[0]
    bs = cache["k"].shape[1]
    max_len = paged["tables"].shape[1] * bs
    q = _proj_q(params, x, cfg)
    k_new, v_new = _proj_kv(params, x, cfg)
    q = rotary(q, pos[:, None], cfg.rope_theta)
    k_new = rotary(k_new, pos[:, None], cfg.rope_theta)

    if kind == "swa" and cfg.window:
        tables = paged["swa_tables"]
        s = min(cfg.window, max_len)       # dense ring size min(W, seq_len)
        slot = pos % s
    else:
        tables = paged["tables"]
        s = max_len
        slot = jnp.minimum(pos, s - 1)
    bidx = jnp.arange(b)
    phys = tables[bidx, slot // bs]
    off = slot % bs
    # rows of a decode batch own disjoint blocks; only inactive rows
    # share the scratch block (id 0), whose content is never read
    k_pool = cache["k"].at[phys, off].set(k_new[:, 0])
    v_pool = cache["v"].at[phys, off].set(v_new[:, 0])

    kg = _paged_gather(k_pool, tables, s)
    vg = _paged_gather(v_pool, tables, s)
    scores = _gqa_scores(q, kg, cfg)                 # (B,KV,G,1,S)
    valid = _decode_valid(pos, s, ring=(kind == "swa" and bool(cfg.window)))
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    scores = scores + mask[:, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, vg, params, cfg, x.dtype)
    return out, {"k": k_pool, "v": v_pool}


def paged_chunk_self_attention(params, x, cache: dict, paged: dict, pos,
                               cfg, kind: str) -> Tuple[jnp.ndarray, dict]:
    """C-token cache-resuming attention against paged pools (chunked
    prefill of ONE request — tables in ``paged`` are the row's slices,
    batch dim 1).  Mirrors :func:`chunk_self_attention` branch-for-
    branch: linear segments write-then-mask through the table, SWA
    scores [old ring ∪ chunk keys] with analytic old-ring positions
    and ring-writes the last ``min(C, W)`` keys."""
    b, c, _ = x.shape
    bs = cache["k"].shape[1]
    max_len = paged["tables"].shape[1] * bs
    q = _proj_q(params, x, cfg)
    k_new, v_new = _proj_kv(params, x, cfg)
    positions = pos[:, None] + jnp.arange(c)[None, :]          # (B,C)
    q = rotary(q, positions, cfg.rope_theta)
    k_new = rotary(k_new, positions, cfg.rope_theta)
    qpos = positions[:, None, :, None]                         # (B,1,C,1)
    bidx = jnp.arange(b)[:, None]

    if kind == "swa" and cfg.window:
        tables = paged["swa_tables"]
        w = min(cfg.window, max_len)
        j = jnp.arange(w)[None, :]
        p_old = pos[:, None] - w + (j - pos[:, None]) % w      # (B,W)
        k_old = _paged_gather(cache["k"], tables, w)
        v_old = _paged_gather(cache["v"], tables, w)
        k_all = jnp.concatenate([k_old, k_new], axis=1)
        v_all = jnp.concatenate([v_old, v_new], axis=1)
        kpos = jnp.concatenate(
            [p_old, positions], axis=1)[:, None, None, :]      # (B,1,1,W+C)
        valid = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - w)
        scores = _gqa_scores(q, k_all, cfg)
        scores = scores + jnp.where(valid, 0.0, NEG_INF).astype(
            jnp.float32)[:, :, None]
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v_all, params, cfg, x.dtype)
        keep = min(c, w)
        slots = positions[:, -keep:] % w
        phys = tables[bidx, slots // bs]
        off = slots % bs
        k = cache["k"].at[phys, off].set(k_new[:, -keep:])
        v = cache["v"].at[phys, off].set(v_new[:, -keep:])
        return out, {"k": k, "v": v}

    tables = paged["tables"]
    slots = jnp.minimum(positions, max_len - 1)
    phys = tables[bidx, slots // bs]
    off = slots % bs
    k = cache["k"].at[phys, off].set(k_new)
    v = cache["v"].at[phys, off].set(v_new)
    kg = _paged_gather(k, tables)                    # (B, max_len, KV, hd)
    vg = _paged_gather(v, tables)
    scores = _gqa_scores(q, kg, cfg)
    kpos = jnp.arange(max_len)[None, None, None, :]
    valid = kpos <= qpos
    scores = scores + jnp.where(valid, 0.0, NEG_INF).astype(
        jnp.float32)[:, :, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, vg, params, cfg, x.dtype)
    return out, {"k": k, "v": v}


def decode_self_attention(params, x, cache: dict, pos, cfg,
                          kind: str) -> Tuple[jnp.ndarray, dict]:
    """One-token decode against a KV cache.

    x: (B,1,D); cache {"k","v"}: (B,S,KV,hd) (S = window for swa);
    pos: (B,) absolute position of the new token.
    """
    b, _, _ = x.shape
    cache_len = cache["k"].shape[1]
    q = _proj_q(params, x, cfg)
    k_new, v_new = _proj_kv(params, x, cfg)
    q = rotary(q, pos[:, None], cfg.rope_theta)
    k_new = rotary(k_new, pos[:, None], cfg.rope_theta)

    if kind == "swa":
        slot = pos % cache_len
    else:
        slot = jnp.minimum(pos, cache_len - 1)
    bidx = jnp.arange(b)
    k = cache["k"].at[bidx, slot].set(k_new[:, 0])
    v = cache["v"].at[bidx, slot].set(v_new[:, 0])

    scores = _gqa_scores(q, k, cfg)  # (B,KV,G,1,S)
    # swa: ring buffer — every slot valid once pos >= window-1
    valid = _decode_valid(pos, cache_len, ring=(kind == "swa"))
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    scores = scores + mask[:, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, params, cfg, x.dtype)
    return out, {"k": k, "v": v}
