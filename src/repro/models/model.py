"""Unified model: embedding + segments (+ encoder) + head.

Public API
----------
``m = build_model(cfg)``
``params = m.init(key)``
``logits, aux = m.forward(params, batch)``                       # train
``logits, cache, aux = m.prefill(params, batch, cache_len)``     # prefill
``logits, cache = m.decode_step(params, cache, batch)``          # decode
``toks, cache = m.decode_steps(params, cache, batch, k=K)``      # fused K-step
``hidden, cache = m.prefill_chunk(params, cache, toks, p0, i)``  # chunked admit
``sp = m.stage_params(params, lo, hi)`` / ``m.run_stages(...)``  # pipeline

Batch dicts (all jnp arrays / ShapeDtypeStructs):
  train/prefill: {"tokens": (B,S) i32, ["frontend": (B,T,D)]}
  decode:        {"token": (B,1) i32, "pos": (B,) i32}
                 (+ frontend context lives in the cache)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import quantize
from repro.models import transformer as tfm
from repro.models.kvcache import cache_struct
from repro.models.layers import embed, embed_init, rmsnorm, rmsnorm_init, unembed
from repro.sharding.specs import constrain


class Model:
    def __init__(self, cfg: ModelConfig, unroll: bool = False,
                 qformat: Optional[str] = None):
        self.cfg = cfg
        self.segments = tfm.build_segments(cfg)
        self.dtype = jnp.dtype(cfg.dtype)
        self.unroll = unroll  # Python-loop layers (roofline cost audit)
        # weight-only quantization format tag ("int8"/"int4", or None
        # for the bf16 baseline).  The model never quantizes params
        # itself — callers pack them via models.quantize.quantize_params
        # (engines do this at construction); qdot dispatches on the
        # packed leaves structurally, and this tag rides through
        # apply_segments so every path is labelled with its format.
        if qformat not in quantize.QFORMATS:
            raise ValueError(f"unknown qformat {qformat!r}; "
                             f"known: {quantize.QFORMATS}")
        self.qformat = qformat if qformat != "bf16" else None

    # ------------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_blocks, k_enc, k_head = jax.random.split(key, 4)
        params = {
            "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                                self.dtype),
            "blocks": tfm.init_segments(
                k_blocks, cfg, self.dtype,
                has_enc_cross=cfg.is_encoder_decoder),
            "final_norm": rmsnorm_init(cfg.d_model, self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(k_head, cfg.vocab_size,
                                           cfg.d_model, self.dtype)
        if cfg.is_encoder_decoder:
            import dataclasses
            enc_cfg = dataclasses.replace(
                cfg, n_layers=cfg.n_encoder_layers,
                block_pattern=tuple(["attn"] * cfg.n_encoder_layers),
                is_encoder_decoder=False, shared_block_kind="")
            params["encoder"] = {
                "blocks": tfm.init_segments(k_enc, enc_cfg, self.dtype),
                "final_norm": rmsnorm_init(cfg.d_model, self.dtype),
            }
        return params

    # ------------------------------------------------------------------
    def _encode(self, params, frontend):
        """Bidirectional encoder over stub frontend embeddings."""
        import dataclasses
        cfg = self.cfg
        enc_cfg = dataclasses.replace(
            cfg, n_layers=cfg.n_encoder_layers,
            block_pattern=tuple(["attn"] * cfg.n_encoder_layers),
            is_encoder_decoder=False, shared_block_kind="")
        b, s, _ = frontend.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, _, _ = tfm.apply_segments(
            params["encoder"]["blocks"], frontend.astype(self.dtype),
            cfg=enc_cfg, mode="train", positions=positions, causal=False,
            qformat=self.qformat)
        return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)

    def _head(self, params, x):
        cfg = self.cfg
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        return unembed(head, x)  # note: vocab dim is padded (see embed_init)

    # ------------------------------------------------------------------
    def forward(self, params, batch, mode: str = "train",
                caches: Optional[list] = None, return_hidden: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = embed(params["embed"], tokens).astype(self.dtype)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        frontend = batch.get("frontend")
        enc_src = None
        if cfg.is_encoder_decoder:
            enc_src = self._encode(params, frontend)
        x, new_caches, aux = tfm.apply_segments(
            params["blocks"], x, cfg=cfg, mode=mode, segs=self.segments,
            positions=positions, caches=caches,
            frontend=frontend.astype(self.dtype) if (
                frontend is not None and not cfg.is_encoder_decoder) else None,
            enc_src=enc_src, unroll=self.unroll, qformat=self.qformat)
        if return_hidden:
            x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
            return x, new_caches, aux
        logits = self._head(params, x)
        return logits, new_caches, aux

    def head_weight(self, params):
        cfg = self.cfg
        return (params["embed"] if cfg.tie_embeddings
                else params["lm_head"])["w"]

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cache_len: int, dtype=None,
                   layers=None):
        """Cache/state pytree; ``layers=(lo, hi)`` restricts it to a
        decoder layer range (a pipeline stage's slice)."""
        return cache_struct(self.cfg, batch, cache_len, dtype or self.dtype,
                            layers=layers)

    # ------------------------------------------------------------------
    # Pipeline-parallel stage API (see serving/pipeline.py)
    # ------------------------------------------------------------------
    def stage_params(self, params, lo: int, hi: int, *, entry: bool = False,
                    exit_head: bool = False) -> dict:
        """Parameter subtree owned by a stage running layers [lo, hi).

        The entry stage additionally owns the embedding, the exit stage
        the final norm + LM head; everything else is only the stage's
        layer slice (plus the weight-shared set, if any).
        """
        cfg = self.cfg
        p = {"blocks": tfm.slice_blocks(params["blocks"], cfg, lo, hi)}
        if entry:
            p["embed"] = params["embed"]
        if exit_head:
            p["final_norm"] = params["final_norm"]
            p["lm_head"] = (params["embed"] if cfg.tie_embeddings
                            else params["lm_head"])
        return p

    def run_stages(self, stage_p, x, lo: int, hi: int, *, mode: str,
                   positions=None, pos=None, caches=None, paged=None):
        """Run decoder layers [lo, hi) from :meth:`stage_params` output.

        x is hidden states (B,T,D) — or token ids (B,T) for a stage that
        owns the embedding.  A stage that owns the head returns logits.
        Composing consecutive stages reproduces the monolithic forward
        op-for-op.  ``paged`` switches decode/chunk cache addressing to
        block pools (`models/kvcache.py`).  Returns (x, new_caches, aux).
        """
        cfg = self.cfg
        if "embed" in stage_p:
            x = embed(stage_p["embed"], x).astype(self.dtype)
        x, new_caches, aux = tfm.apply_segments(
            stage_p["blocks"], x, cfg=cfg, mode=mode,
            segs=tfm.segment_range(cfg, lo, hi),
            positions=positions, pos=pos, caches=caches, unroll=self.unroll,
            paged=paged, qformat=self.qformat)
        if "lm_head" in stage_p:
            x = rmsnorm(stage_p["final_norm"], x, cfg.norm_eps)
            x = unembed(stage_p["lm_head"], x)
        return x, new_caches, aux

    # ------------------------------------------------------------------
    def prefill_chunk(self, params, caches, tokens, pos0, slot):
        """Chunked prefill of one batch row against the shared cache.

        tokens: (1, C) processed at absolute positions pos0 .. pos0+C-1;
        only batch row ``slot`` of ``caches`` is read and written (other
        rows' KV *and* SSM states are untouched — the token-by-token
        path through ``decode_step`` would advance co-batched SSM states
        spuriously).  One jitted call per chunk replaces C decode
        dispatches.  Returns (hidden (1,C,D), caches) — no LM head:
        admission discards prompt logits, so computing them would waste
        a C x d_model x vocab matmul per chunk.
        """
        def run(row):
            x = embed(params["embed"], tokens).astype(self.dtype)
            pos = jnp.reshape(pos0, (1,)).astype(jnp.int32)
            x, new_row, _ = tfm.apply_segments(
                params["blocks"], x, cfg=self.cfg, mode="chunk",
                segs=self.segments, pos=pos, caches=row,
                unroll=self.unroll, qformat=self.qformat)
            return x, new_row

        return row_isolated(run, caches, slot)

    # ------------------------------------------------------------------
    def prefill(self, params, batch, cache_len: Optional[int] = None):
        b, s = batch["tokens"].shape
        caches = self.init_cache(b, cache_len or s)
        logits, new_caches, aux = self.forward(params, batch, mode="prefill",
                                               caches=caches)
        return logits, new_caches, aux

    def _decode_x(self, params, caches, token, pos, paged=None):
        """Shared decode body: embed one token per row and run the
        segment stack against the cache (dense rows or paged pools).
        Returns (hidden (B,1,D), new caches)."""
        x = embed(params["embed"], token).astype(self.dtype)
        x, new_caches, _ = tfm.apply_segments(
            params["blocks"], x, cfg=self.cfg, mode="decode",
            segs=self.segments, pos=pos, caches=caches, unroll=self.unroll,
            paged=paged, qformat=self.qformat)
        return x, new_caches

    def decode_step(self, params, caches, batch):
        """One new token against the cache.  batch: {"token","pos"}."""
        x, new_caches = self._decode_x(params, caches, batch["token"],
                                       batch["pos"])
        logits = self._head(params, x)
        return logits, new_caches

    def decode_steps(self, params, caches, batch, paged=None, *, k: int):
        """K fused greedy decode steps on device (the serving hot loop).

        One ``lax.scan`` runs ``k`` decode iterations without leaving the
        device: argmax over the *logical* (un-padded) vocab, token
        feedback, per-row ``pos`` bump, and per-row done masking all
        happen inside the loop, so the host syncs once per ``k`` tokens
        and never sees full logits.  batch:

        * ``token`` (B,1) i32 — first decode input per row (the host
          engines' ``_next_tokens``: last prompt/output token for live
          rows, 0 for dead ones);
        * ``pos``   (B,)  i32 — absolute position of that token;
        * ``budget`` (B,) i32 — decode steps each row may take.  A row
          whose budget hits 0 mid-scan is masked exactly the way the
          host loop treats an inactive batch row: it keeps running with
          token 0 at a frozen ``pos`` (so its per-step compute — and any
          MoE co-batch coupling — is bitwise what the per-token engines
          did), but its emitted tokens are -1 and its state stops
          advancing.

        Returns (tokens (B,k) i32, caches); row r's valid prefix is its
        first ``budget[r]`` entries.  With ``paged`` set, caches are
        block pools and the block tables must already cover every write
        in [pos, pos + budget) — the scheduler grows rows *before* the
        scan (writes past the covered range land in the scratch block).
        Token streams are identical to ``k`` successive
        :meth:`decode_step` calls for every ``k`` (tests/test_paged.py).
        """
        vocab = self.cfg.vocab_size

        def body(carry, _):
            caches, tok, pos, budget = carry
            x, caches = self._decode_x(params, caches, tok, pos,
                                       paged=paged)
            logits = self._head(params, x)                  # (B,1,V_pad)
            tok, pos, budget, emit = greedy_scan_update(logits, pos,
                                                        budget, vocab)
            return (caches, tok, pos, budget), emit

        carry = (caches, batch["token"], batch["pos"], batch["budget"])
        (caches, _, _, _), toks = jax.lax.scan(body, carry, None, length=k)
        return jnp.transpose(toks), caches

    def verify_steps(self, params, caches, batch, paged=None):
        """Teacher-forced parallel verification of K draft tokens (the
        speculative-decoding scorer, SERVING.md §Speculative decoding).

        One chunk-mode forward scores every draft position in a single
        jitted dispatch: the (B, S) chunk ``[t0, d0..d_{S-2}]`` (the
        row's next decode input followed by its K = S-1 draft tokens)
        is embedded and run through the segment stack at positions
        ``pos..pos+S-1``, writing KV exactly where sequential decode
        would.  Greedy targets over the logical vocab are compared
        against the drafts (:func:`greedy_verify_update`): row r emits
        its longest exactly-matching draft prefix plus the greedy
        correction/bonus token, clamped to ``budget[r]``; non-emitted
        slots are -1.  Because every accepted draft *is* the greedy
        target at its position, the emitted stream — and the KV
        written at emitted positions — is byte-identical to plain
        greedy decode; KV written above the accepted length is stale
        by position (attention masks it, and the next round's writes
        land on top), which is why the engines gate speculation to
        pure-attention archs (`serving/speculative.py`).

        batch: ``token`` (B, S) i32, ``pos`` (B,) i32 (position of
        ``token[:, 0]``), ``budget`` (B,) i32 (max tokens this row may
        emit; 0 masks the row).  With ``paged`` set the caches are
        block pools; writes beyond a row's covered range land in the
        scratch block (never read back below the accepted length).
        Returns (emit (B, S) i32, caches).
        """
        x = embed(params["embed"], batch["token"]).astype(self.dtype)
        x, new_caches, _ = tfm.apply_segments(
            params["blocks"], x, cfg=self.cfg, mode="chunk",
            segs=self.segments, pos=batch["pos"], caches=caches,
            unroll=self.unroll, paged=paged, qformat=self.qformat)
        logits = self._head(params, x)                   # (B,S,V_pad)
        emit = greedy_verify_update(logits, batch["token"],
                                    batch["budget"], self.cfg.vocab_size)
        return emit, new_caches

    # ------------------------------------------------------------------
    # Paged-cache serving API (see serving/engine.py paged engines)
    # ------------------------------------------------------------------
    def paged_decode_step(self, params, caches, batch, paged):
        """One decode step over paged block pools.

        ``caches`` is a :meth:`repro.models.kvcache.PagedCache.struct`
        pytree; ``paged`` the matching block-table metadata
        (:meth:`~repro.models.kvcache.PagedCache.meta`).  Math is
        identical to :meth:`decode_step` — only cache addressing
        changes.  The multi-token hot-loop variant is
        :meth:`decode_steps` with ``paged`` set.
        """
        x, new_caches = self._decode_x(params, caches, batch["token"],
                                       batch["pos"], paged=paged)
        logits = self._head(params, x)
        return logits, new_caches

    def paged_prefill_chunk(self, params, caches, tokens, pos0, row, paged):
        """Chunked prefill of one request against paged pools.

        tokens: (1, C) at absolute positions pos0..; ``paged`` holds the
        request's row-sliced block tables (``meta(row=...)``), so KV
        writes land only in blocks the row owns; SSM state rows are
        sliced/written back via :func:`ssm_row_isolated`.  Returns
        (hidden (1,C,D), caches) — no LM head, as in
        :meth:`prefill_chunk`.
        """
        def run(row_caches):
            x = embed(params["embed"], tokens).astype(self.dtype)
            pos = jnp.reshape(pos0, (1,)).astype(jnp.int32)
            x, new_caches, _ = tfm.apply_segments(
                params["blocks"], x, cfg=self.cfg, mode="chunk",
                segs=self.segments, pos=pos, caches=row_caches,
                unroll=self.unroll, paged=paged, qformat=self.qformat)
            return x, new_caches

        return ssm_row_isolated(run, self.segments, caches, row)


def greedy_scan_update(logits, pos, budget, vocab: int):
    """One macro-step scan iteration's greedy bookkeeping, shared by
    :meth:`Model.decode_steps` and the pipelined fused macro
    (`serving/pipeline.py`) so the masking semantics cannot drift.

    Returns (tok (B,1), pos (B,), budget (B,), emit (B,)).  A row's
    last live step emits its sampled token and bumps ``pos``, but the
    *feedback* token is masked by the post-step budget: the host loop
    feeds token 0 for a freed slot starting the step AFTER the one that
    finished it, and the masked-row compute must stay bitwise identical
    to that (it is co-batched with live rows — MoE capacity routing
    sees it)."""
    nxt = jnp.argmax(logits[:, -1, :vocab], axis=-1).astype(jnp.int32)
    live = budget > 0
    emit = jnp.where(live, nxt, -1)
    budget = budget - live.astype(jnp.int32)
    tok = jnp.where(budget > 0, nxt, 0)[:, None]
    pos = jnp.where(live, pos + 1, pos)
    return tok, pos, budget, emit


def greedy_verify_update(logits, tokens, budget, vocab: int):
    """Greedy draft-verification bookkeeping, shared by
    :meth:`Model.verify_steps` and the pipelined fused verify
    (`serving/pipeline.py`) so the acceptance semantics cannot drift.

    ``logits`` (B, S, V_pad) score the fed chunk ``tokens`` (B, S) =
    ``[t0, d0..d_{S-2}]``; the greedy target ``g[:, j]`` predicts the
    token at position ``pos + j + 1``.  Draft ``d_j`` is accepted iff
    every earlier draft matched and ``g[:, j] == d_j`` (the longest
    exactly-matching prefix); the round then also emits ``g`` at the
    first mismatch (the correction token) or, on full acceptance, at
    the final position (the bonus token).  Emission is clamped to
    ``budget`` and a zero-budget row emits nothing.  Since matched
    drafts ARE the greedy targets, the emitted prefix is simply
    ``g[:, :n_emit]`` — the exact greedy stream — with -1 in
    non-emitted slots.
    """
    g = jnp.argmax(logits[:, :, :vocab], axis=-1).astype(jnp.int32)
    match = (g[:, :-1] == tokens[:, 1:]).astype(jnp.int32)      # (B,S-1)
    acc = jnp.cumprod(match, axis=1).sum(axis=1)                # (B,)
    n_emit = jnp.minimum(acc + 1, budget)                       # (B,)
    cols = jnp.arange(g.shape[1], dtype=jnp.int32)[None, :]
    return jnp.where(cols < n_emit[:, None], g, -1)


def ssm_row_isolated(apply_fn, segs, caches, row):
    """:func:`row_isolated` for paged pytrees: only SSM/conv state
    leaves carry per-request rows (KV pools are addressed through block
    tables, which already isolate the request), so only the mamba
    segments are sliced at ``row`` and written back.
    apply_fn(caches) -> (out, new_caches)."""
    ssm = [seg.kind in ("mamba1", "mamba2") for seg in segs]
    sliced = [jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, row, 1, axis=1), c)
        if is_ssm else c for is_ssm, c in zip(ssm, caches)]
    out, new = apply_fn(sliced)
    merged = [jax.tree.map(
        lambda full, r: jax.lax.dynamic_update_slice_in_dim(
            full, r.astype(full.dtype), row, axis=1), c, n)
        if is_ssm else n for is_ssm, c, n in zip(ssm, caches, new)]
    return out, merged


def row_isolated(apply_fn, caches, slot):
    """Run ``apply_fn`` against batch row ``slot`` of a cache pytree
    (leaves (n_layers, batch, ...)): the row is sliced out (keeping a
    batch dim of 1), transformed, and written back — every other row's
    state is bit-untouched.  apply_fn(row) -> (out, new_row).
    Returns (out, updated caches)."""
    row = jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
        caches)
    out, new_row = apply_fn(row)
    caches = jax.tree.map(
        lambda full, r: jax.lax.dynamic_update_slice_in_dim(
            full, r.astype(full.dtype), slot, axis=1),
        caches, new_row)
    return out, caches


def build_model(cfg: ModelConfig, unroll: bool = False,
                qformat: Optional[str] = None) -> Model:
    return Model(cfg, unroll=unroll, qformat=qformat)
