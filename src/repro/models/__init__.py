from repro.models.model import Model, build_model  # noqa: F401
from repro.models.quantize import (  # noqa: F401
    quantize_params, dequantize_params, bytes_per_param)
