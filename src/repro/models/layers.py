"""Shared building blocks: norms, embeddings, rotary, MLPs.

All modules are (init, apply) pairs of pure functions over param pytrees —
no framework.  Params are dicts of jnp arrays; inits take an explicit key.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.quantize import qdot
from repro.sharding.specs import constrain


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------------
# RMSNorm
# ----------------------------------------------------------------------
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------
def embed_init(key, vocab: int, d: int, dtype, pad_to: int = 256) -> dict:
    """Vocab is padded up to a multiple of ``pad_to`` so the table and the
    logits shard cleanly over the `model` axis (MaxText-style padding;
    sampler slices back to the logical vocab)."""
    vpad = -(-vocab // pad_to) * pad_to
    # d^-0.5 keeps init logits O(1) whether the table is used as an
    # embedding (rmsnorm renormalizes) or as a (tied) unembedding head
    return {"w": _dense_init(key, (vpad, d), dtype, scale=d ** -0.5)}


def embed(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    out = jnp.take(params["w"], tokens, axis=0)
    return constrain(out, "act_btd")


def unembed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("...d,vd->...v", x, params["w"])
    return constrain(logits, "act_btv")


# ----------------------------------------------------------------------
# Rotary embeddings
# ----------------------------------------------------------------------
def rotary(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Apply rotary embedding.

    x: (..., seq, n_heads, head_dim); positions: broadcastable to (..., seq).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:2 * half]
    rot1 = x1 * cos - x2 * sin
    rot2 = x2 * cos + x1 * sin
    out = jnp.concatenate([rot1, rot2, x[..., 2 * half:]], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d, d_ff), dtype),
        "w_up": _dense_init(k2, (d, d_ff), dtype),
        "w_down": _dense_init(k3, (d_ff, d), dtype),
    }


def mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    # qdot == the einsum these sites always ran for plain arrays;
    # packed leaves (models/quantize.py) take the dequant-fused path
    g = qdot(x, params["w_gate"])
    u = qdot(x, params["w_up"])
    h = constrain(jax.nn.silu(g) * u, "act_btf")
    return qdot(h, params["w_down"])
