"""Per-segment cache/state construction (abstract — works under eval_shape)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.transformer import build_segments, segment_range


def cache_struct(cfg, batch: int, seq_len: int, dtype, layers=None) -> list:
    """One entry per segment, each a dict with leading layer dim.

    ``layers=(lo, hi)`` restricts the structure to that decoder layer
    range (a pipeline stage's slice — aligned with
    :func:`repro.models.transformer.segment_range`).
    """
    segs = (build_segments(cfg) if layers is None
            else segment_range(cfg, *layers))
    caches = []
    for seg in segs:
        n = seg.length
        if seg.kind in ("attn", "cross") or (
                seg.kind == "swa" and not cfg.window):
            s = seq_len
        elif seg.kind == "swa":
            s = min(cfg.window, seq_len)
        if seg.kind in ("attn", "swa"):
            c = {
                "k": jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
            }
            if cfg.is_encoder_decoder:
                c["xk"] = jnp.zeros(
                    (n, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                    dtype)
                c["xv"] = jnp.zeros_like(c["xk"])
        elif seg.kind == "cross":
            src = cfg.n_image_tokens or cfg.encoder_seq
            c = {
                "xk": jnp.zeros((n, batch, src, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
                "xv": jnp.zeros((n, batch, src, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
            }
        elif seg.kind == "mamba1":
            di, ds = cfg.d_inner_eff, cfg.ssm_state
            c = {
                "h": jnp.zeros((n, batch, di, ds), jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, di), dtype),
            }
        elif seg.kind == "mamba2":
            di, ds = cfg.d_inner_eff, cfg.ssm_state
            nh = di // cfg.mamba2_headdim
            c = {
                "h": jnp.zeros((n, batch, nh, cfg.mamba2_headdim, ds),
                               jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, di), dtype),
            }
        else:
            raise ValueError(seg.kind)
        caches.append(c)
    return caches


def cache_bytes(cfg, batch: int, seq_len: int, bytes_per_el: int = 2) -> int:
    import jax
    struct = jax.eval_shape(lambda: cache_struct(cfg, batch, seq_len,
                                                 jnp.bfloat16))
    return sum(x.size * bytes_per_el for x in jax.tree.leaves(struct))
