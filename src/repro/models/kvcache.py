"""Per-segment cache/state construction: dense slot rows and paged pools.

Two cache layouts share the same per-segment pytree structure (one list
entry per segment, leaves with a leading layer dim):

* **Dense** (:func:`cache_struct`) — one full ``seq_len`` row per batch
  slot: attn/swa leaves are ``(n_layers, batch, slots, kv_heads, hd)``
  where slot == absolute position for attn and ``pos % window`` for the
  SWA ring.  Memory is reserved worst-case per slot, so admission is
  slot-granular (`serving/engine.py`'s dense engines).
* **Paged** (:class:`PagedCache` + :meth:`PagedCache.struct`) —
  fixed-size blocks in a shared pool: attn/swa/cross leaves are
  ``(n_layers, num_physical_blocks, block_size, kv_heads, hd)`` and a
  request's logical slot ``s`` lives at
  ``(tables[row, s // block_size], s % block_size)``.  Admission is
  block-granular (token-level), so mixed-length workloads share the
  pool (`serving/engine.py`'s paged engines).

Cache layout invariants (relied on across models/serving/kernels):

* physical block 0 of every paged pool is the **scratch block**: never
  allocated, it absorbs the writes of inactive decode rows; block-table
  entries of unallocated logical blocks point at scratch, and every
  read through them is masked by position;
* stale attn/swa KV needs no zeroing on block reuse — attention masks
  slots above ``pos`` (and the SWA ring is fully rewritten before its
  all-slots-valid regime at ``pos >= window - 1``);
* cross KV (``xk``/``xv``) is *not* position-masked, so a request's
  cross blocks are zeroed at admission (token requests carry no
  frontend; parity with the dense engines' zero-initialised cross
  rows);
* SSM/conv state stays per-request dense (``(n_layers, rows, ...)``)
  in both layouts and must be zeroed on row (re)use — stale KV is
  masked by position, stale recurrent state is not;
* attn-pool blocks may be **shared** between requests under
  copy-on-write prefix sharing (:class:`PagedCache` with
  ``share_prefixes``): a block's content is a pure function of the
  token-id prefix it caches, a per-block refcount tracks its owners,
  and any write to a block with refcount > 1 first copies it
  (SERVING.md §Prefix sharing);
* speculative write semantics: a draft-verify round writes KV for all
  K+1 chunk positions unconditionally, then the engine advances
  ``pos`` only past the accepted prefix — rejected positions become
  ordinary stale KV (masked by position, overwritten by the next
  chunk), which is why speculative rollback is a ledger-side position
  decrement with **no KV rewrite**, and why it is gated to
  pure-attention archs (stale SSM/recurrent state is not
  position-masked; SERVING.md §Speculative decoding).
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import build_segments, segment_range


def cache_struct(cfg, batch: int, seq_len: int, dtype, layers=None) -> list:
    """One entry per segment, each a dict with leading layer dim.

    ``layers=(lo, hi)`` restricts the structure to that decoder layer
    range (a pipeline stage's slice — aligned with
    :func:`repro.models.transformer.segment_range`).
    """
    segs = (build_segments(cfg) if layers is None
            else segment_range(cfg, *layers))
    caches = []
    for seg in segs:
        n = seg.length
        if seg.kind in ("attn", "cross") or (
                seg.kind == "swa" and not cfg.window):
            s = seq_len
        elif seg.kind == "swa":
            s = min(cfg.window, seq_len)
        if seg.kind in ("attn", "swa"):
            c = {
                "k": jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
            }
            if cfg.is_encoder_decoder:
                c["xk"] = jnp.zeros(
                    (n, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                    dtype)
                c["xv"] = jnp.zeros_like(c["xk"])
        elif seg.kind == "cross":
            src = cfg.n_image_tokens or cfg.encoder_seq
            c = {
                "xk": jnp.zeros((n, batch, src, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
                "xv": jnp.zeros((n, batch, src, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
            }
        elif seg.kind == "mamba1":
            di, ds = cfg.d_inner_eff, cfg.ssm_state
            c = {
                "h": jnp.zeros((n, batch, di, ds), jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, di), dtype),
            }
        elif seg.kind == "mamba2":
            di, ds = cfg.d_inner_eff, cfg.ssm_state
            nh = di // cfg.mamba2_headdim
            c = {
                "h": jnp.zeros((n, batch, nh, cfg.mamba2_headdim, ds),
                               jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, di), dtype),
            }
        else:
            raise ValueError(seg.kind)
        caches.append(c)
    return caches


def cache_bytes(cfg, batch: int, seq_len: int, bytes_per_el: int = 2) -> int:
    struct = jax.eval_shape(lambda: cache_struct(cfg, batch, seq_len,
                                                 jnp.bfloat16))
    return sum(x.size * bytes_per_el for x in jax.tree.leaves(struct))


# ----------------------------------------------------------------------
# Paged cache: block pools + per-request block tables
# ----------------------------------------------------------------------
class PagedCache:
    """Host-side paged-cache ledger: free lists + per-request block tables.

    Three block groups cover the attention segment kinds (SSM state is
    per-request dense, see module docstring):

    ``attn``
        the shared contention pool — ``num_blocks`` usable blocks of
        ``block_size`` tokens; one block id covers the same logical
        token range in *every* attn-kind layer's pool.  Logical slot ==
        absolute position; a request holds
        ``ceil(tokens / block_size)`` blocks and grows block-by-block
        as it decodes (:meth:`ensure`).  This is the group token-level
        admission and preemption arbitrate over.
    ``swa``
        per-request ring of ``ceil(min(window, max_len) / bs)`` blocks
        holding ring slot ``pos % window``; sized worst-case
        (``max_rows`` full rings) so allocation never fails and the
        ring never contends with the attn pool.
    ``cross``
        per-request ``ceil(src / bs)`` blocks of encoder/frontend KV,
        allocated and zeroed at admission (cross reads are not
        position-masked).

    The ledger is pure numpy/python — deterministic LIFO free lists,
    no jax state.  Pool arrays are built separately by :meth:`struct`
    (optionally restricted to a pipeline stage's layer range) so one
    ledger can govern several stage-sliced pools that share block ids.

    ``watermark_blocks`` holds back free attn blocks at admission time:
    a new request is admitted only if its prompt fits *and* the pool
    stays above the watermark, reserving headroom for the decode growth
    of already-running requests (fewer preemptions at high load).

    **Prefix sharing** (``share_prefixes=True``, SERVING.md §Prefix
    sharing).  Attn blocks become *shared* resources under a per-block
    refcount: a host-side prefix index maps the token ids of every
    fully-prefilled block (keyed by the request's whole token prefix up
    to and including that block, so a match is exact by construction —
    attention KV at position ``p`` is a pure function of tokens
    ``[0, p]``) to the physical block caching it.  :meth:`admit` with
    ``tokens=`` matches the longest indexed full-block prefix and maps
    those blocks into the new request's table with a refcount bump
    instead of allocating + re-prefilling them; :meth:`release` (and
    preemption, which uses the same path) decrements refcounts, and a
    block returns to the free list only at refcount zero.  A write into
    a block with refcount > 1 (:meth:`ensure`) triggers
    **copy-on-write**: a fresh block replaces it in the writer's table
    and the pending device-side pool copy is queued in
    :attr:`pending_copies` for the engine to apply before its next
    forward.  Sharing is only sound when the attn pool is the *only*
    per-position state a prefix builds — SSM/conv state, the SWA ring,
    and cross KV are per-request and not content-addressed — so it
    auto-disables (:attr:`sharing_supported`) on configs with those
    segment kinds, and ``admit`` then behaves exactly as before.
    """

    def __init__(self, cfg, *, max_rows: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 watermark_blocks: int = 0, share_prefixes: bool = False):
        assert max_len % block_size == 0, (max_len, block_size)
        self.cfg = cfg
        self.max_rows = max_rows
        self.max_len = max_len
        self.block_size = block_size
        self.nb_logical = max_len // block_size
        self.watermark_blocks = watermark_blocks

        kinds = {s.kind for s in build_segments(cfg)}
        self.has_swa = "swa" in kinds and bool(cfg.window)
        self.window_eff = min(cfg.window, max_len) if self.has_swa else 0
        self.nb_swa = (-(-self.window_eff // block_size)
                       if self.has_swa else 0)
        src = (cfg.n_image_tokens or cfg.encoder_seq
               if ("cross" in kinds or cfg.is_encoder_decoder) else 0)
        self.cross_src = src
        self.nb_cross = -(-src // block_size) if src else 0

        self.num_blocks = (max_rows * self.nb_logical
                           if num_blocks is None else num_blocks)
        self._groups = {"attn": self.num_blocks,
                        "swa": max_rows * self.nb_swa,
                        "cross": max_rows * self.nb_cross}
        # prefix sharing: only the attn pool is content-addressed (SSM/
        # conv state, the SWA ring, and cross KV are per-request state a
        # skipped prefill would not rebuild)
        self.sharing_supported = not (
            self.has_swa or self.nb_cross
            or kinds & {"mamba1", "mamba2"})
        self.share_prefixes = bool(share_prefixes) and self.sharing_supported
        # per-attn-block owner count; a block is free iff refcount 0
        self._ref = np.zeros(self.num_blocks + 1, np.int32)
        # token-prefix bytes -> physical block caching that full block,
        # plus the reverse map for de-indexing at refcount zero
        self._prefix_index: Dict[bytes, int] = {}
        self._block_key: Dict[int, bytes] = {}
        # COW pool copies (src, dst) awaiting device application —
        # engines drain via take_pending_copies() before each forward
        self.pending_copies: List[Tuple[int, int]] = []
        self._hit_tokens_row = np.zeros(max_rows, np.int32)
        self.n_prefix_hits = 0      # admissions that matched >= 1 block
        self.prefix_tokens_hit = 0  # prefill tokens skipped, cumulative
        self.blocks_saved = 0       # allocations avoided by sharing
        self.n_cow_copies = 0
        # LIFO free lists; block id 0 is the scratch block of each group
        self._free = {g: list(range(n, 0, -1))
                      for g, n in self._groups.items()}
        self._held = {g: [[] for _ in range(max_rows)]
                      for g in self._groups}
        self.tables = np.zeros((max_rows, self.nb_logical), np.int32)
        self.swa_tables = np.zeros((max_rows, max(self.nb_swa, 1)), np.int32)
        self.cross_tables = np.zeros((max_rows, max(self.nb_cross, 1)),
                                     np.int32)
        # incremental device snapshot: the ledger version bumps on every
        # table mutation (admit/growth/release/preempt); meta() re-uploads
        # only when the version moved, so steady-state decode reuses one
        # immutable device copy instead of copying every table per forward
        self._version = 0
        self._meta_version = -1
        self._meta_cache: Optional[dict] = None
        self.n_meta_uploads = 0

    # -------------------------------------------------------------- pools
    def struct(self, dtype, layers=None) -> list:
        """Block-pool pytree for decoder layers ``layers`` (default all).

        Mirrors :func:`cache_struct` segment-for-segment; attn/swa/cross
        leaves swap the per-slot batch rows for
        ``(group_blocks + 1, block_size)`` physical pools (+1 for the
        scratch block), SSM leaves keep ``max_rows`` state rows.
        """
        cfg = self.cfg
        segs = (build_segments(cfg) if layers is None
                else segment_range(cfg, *layers))
        bs, kvh, hd = self.block_size, cfg.n_kv_heads, cfg.head_dim
        nb_attn = self._groups["attn"] + 1
        nb_swa = self._groups["swa"] + 1
        nb_cross = self._groups["cross"] + 1
        caches = []
        for seg in segs:
            n = seg.length
            if seg.kind in ("attn", "swa"):
                nb = (nb_swa if (seg.kind == "swa" and cfg.window)
                      else nb_attn)
                c = {"k": jnp.zeros((n, nb, bs, kvh, hd), dtype),
                     "v": jnp.zeros((n, nb, bs, kvh, hd), dtype)}
                if cfg.is_encoder_decoder:
                    c["xk"] = jnp.zeros((n, nb_cross, bs, kvh, hd), dtype)
                    c["xv"] = jnp.zeros_like(c["xk"])
            elif seg.kind == "cross":
                c = {"xk": jnp.zeros((n, nb_cross, bs, kvh, hd), dtype),
                     "xv": jnp.zeros((n, nb_cross, bs, kvh, hd), dtype)}
            elif seg.kind == "mamba1":
                di, ds = cfg.d_inner_eff, cfg.ssm_state
                c = {"h": jnp.zeros((n, self.max_rows, di, ds), jnp.float32),
                     "conv": jnp.zeros((n, self.max_rows, cfg.conv_width - 1,
                                        di), dtype)}
            elif seg.kind == "mamba2":
                di, ds = cfg.d_inner_eff, cfg.ssm_state
                nh = di // cfg.mamba2_headdim
                c = {"h": jnp.zeros((n, self.max_rows, nh,
                                     cfg.mamba2_headdim, ds), jnp.float32),
                     "conv": jnp.zeros((n, self.max_rows, cfg.conv_width - 1,
                                        di), dtype)}
            else:
                raise ValueError(seg.kind)
            caches.append(c)
        return caches

    # ---------------------------------------------------------- metadata
    def meta(self, row: Optional[int] = None) -> dict:
        """Block-table metadata for a jitted forward call.

        Snapshot copies (``jnp.asarray`` aliases numpy buffers on CPU
        and the jitted callee dispatches asynchronously — the ledger
        must stay mutable on the host side).  ``row`` restricts tables
        to one request (the chunked-prefill path).

        The full-table snapshot (``row=None``, the per-decode path) is
        cached against :attr:`_version`: it is rebuilt only when the
        ledger actually changed since the last upload — during steady-
        state decode the same immutable device arrays are handed to
        every macro-step.  (:attr:`n_meta_uploads` counts rebuilds;
        benchmarks/engine_bench.py reports uploads per token.)
        """
        if row is None:
            if self._meta_version == self._version:
                return self._meta_cache
            self._meta_cache = self._build_meta(slice(None))
            self._meta_version = self._version
            self.n_meta_uploads += 1
            return self._meta_cache
        return self._build_meta(slice(row, row + 1))

    def _build_meta(self, sel) -> dict:
        out = {"tables": jnp.asarray(self.tables[sel].copy())}
        if self.has_swa:
            out["swa_tables"] = jnp.asarray(self.swa_tables[sel].copy())
        if self.nb_cross:
            out["cross_tables"] = jnp.asarray(self.cross_tables[sel].copy())
        return out

    # -------------------------------------------------------- accounting
    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return len(self._free["attn"])

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks

    def utilization(self) -> float:
        return (self.used_blocks / self.num_blocks) if self.num_blocks else 0.0

    def fits(self, total_tokens: int) -> bool:
        """Can a request ever run: worst-case footprint vs pool size."""
        return self.blocks_needed(total_tokens) <= self.num_blocks

    # ---------------------------------------------------- prefix index
    def _prefix_key(self, tokens, logical: int) -> bytes:
        """Index key of logical block ``logical`` for a request whose
        prefilled token ids are ``tokens``: the *whole* prefix through
        that block, so equal keys imply bitwise-equal cached KV."""
        end = (logical + 1) * self.block_size
        return np.asarray(tokens[:end], np.int32).tobytes()

    def _match_blocks(self, tokens) -> List[int]:
        """Longest indexed full-block prefix of ``tokens`` (the
        request's to-be-prefilled ids), as physical block ids.  Only
        blocks *fully covered* by ``tokens`` can match — the block
        holding a request's first decode write is never shared."""
        if not self.share_prefixes or tokens is None:
            return []
        out: List[int] = []
        for j in range(len(tokens) // self.block_size):
            blk = self._prefix_index.get(self._prefix_key(tokens, j))
            if blk is None:
                break
            out.append(blk)
        return out

    def probe_hit(self, tokens) -> int:
        """Blocks an admission with ``tokens`` would share rather than
        allocate — the scheduler's effective-capacity admission test
        subtracts this from the modeled block demand
        (`serving/scheduler.py::EDFCapacityPolicy`)."""
        return len(self._match_blocks(tokens))

    def hit_tokens(self, row: int) -> int:
        """Prefill tokens row ``row``'s last :meth:`admit` matched (a
        multiple of ``block_size``) — the span the engine skips."""
        return int(self._hit_tokens_row[row])

    def _register_prefixes(self, row: int, tokens) -> None:
        """Index every fully-prefilled block of ``tokens`` that is not
        indexed yet (matched blocks are already present under the same
        keys).  Called at admit time: the row's prefill writes the
        claimed content before any matcher can read it."""
        for j in range(len(tokens) // self.block_size):
            key = self._prefix_key(tokens, j)
            if key not in self._prefix_index:
                blk = int(self.tables[row, j])
                self._prefix_index[key] = blk
                self._block_key[blk] = key

    def _deindex(self, blk: int) -> None:
        key = self._block_key.pop(blk, None)
        if key is not None and self._prefix_index.get(key) == blk:
            del self._prefix_index[key]

    def can_admit(self, n_tokens: int, watermark: Optional[int] = None,
                  tokens=None) -> bool:
        """``watermark`` overrides the configured headroom — the
        scheduler drops it to 0 when nothing is running (headroom only
        exists to protect active requests' decode growth; holding an
        idle pool back would deadlock a lone large request).
        ``tokens`` (the to-be-prefilled ids) lets a prefix hit shrink
        the fresh-block demand."""
        wm = self.watermark_blocks if watermark is None else watermark
        need = self.blocks_needed(n_tokens) - len(self._match_blocks(tokens))
        return (len(self._free["attn"]) - wm >= need
                and len(self._free["swa"]) >= self.nb_swa
                and len(self._free["cross"]) >= self.nb_cross)

    def _alloc(self, group: str, row: int, table: np.ndarray,
               logical: int) -> bool:
        free = self._free[group]
        if not free:
            return False
        blk = free.pop()
        self._held[group][row].append(blk)
        table[row, logical] = blk
        if group == "attn":
            self._ref[blk] = 1
        self._version += 1
        return True

    def _alloc_or_die(self, group: str, row: int, table: np.ndarray,
                      logical: int):
        # callers hold the can_admit guarantee; a failure here is ledger
        # corruption, and must raise even under ``python -O``
        if not self._alloc(group, row, table, logical):
            raise RuntimeError(
                f"{group} pool exhausted mid-admit (row {row}, logical "
                f"{logical}) despite can_admit — ledger corrupted")

    def admit(self, row: int, n_tokens: int,
              watermark: Optional[int] = None, tokens=None) -> bool:
        """Allocate row ``row``'s blocks for logical slots [0, n_tokens)
        plus its full SWA ring and cross blocks.  All-or-nothing.

        With sharing enabled and ``tokens`` (the ids the engine is
        about to prefill, i.e. ``(prompt + out)[:-1]``), the longest
        indexed full-block prefix is *mapped* instead of allocated:
        matched blocks enter the row's table with a refcount bump, and
        :meth:`hit_tokens` reports the span whose prefill the engine
        skips.  Fresh fully-prefilled blocks are registered in the
        prefix index for later arrivals to match."""
        if any(self._held[g][row] for g in self._held):
            raise RuntimeError(f"admit: row {row} still holds blocks")
        matched = self._match_blocks(tokens)
        if not self.can_admit(n_tokens, watermark=watermark,
                              tokens=tokens):
            return False
        for j, blk in enumerate(matched):
            self._ref[blk] += 1
            self._held["attn"][row].append(blk)
            self.tables[row, j] = blk
        if matched:
            self._version += 1
        for j in range(len(matched), self.blocks_needed(n_tokens)):
            self._alloc_or_die("attn", row, self.tables, j)
        for j in range(self.nb_swa):
            self._alloc_or_die("swa", row, self.swa_tables, j)
        for j in range(self.nb_cross):
            self._alloc_or_die("cross", row, self.cross_tables, j)
        if self.share_prefixes and tokens is not None:
            self._register_prefixes(row, tokens)
        hit = len(matched) * self.block_size
        self._hit_tokens_row[row] = hit
        if matched:
            self.n_prefix_hits += 1
            self.prefix_tokens_hit += hit
            self.blocks_saved += len(matched)
        return True

    def _cow(self, row: int, logical: int, src: int) -> bool:
        """Copy-on-write: give ``row`` a private copy of shared block
        ``src`` before it writes into logical slot ``logical``.  The
        device-side pool copy is queued in :attr:`pending_copies`
        (engines apply it before their next forward); the ledger side —
        table entry, held list, refcounts — swaps immediately.  Returns
        False when no free block exists (the scheduler must preempt);
        the shared mapping is left untouched in that case."""
        free = self._free["attn"]
        if not free:
            return False
        dst = free.pop()
        self._ref[dst] = 1
        self._ref[src] -= 1
        held = self._held["attn"][row]
        held[held.index(src)] = dst
        self.tables[row, logical] = dst
        self.pending_copies.append((src, dst))
        self.n_cow_copies += 1
        self._version += 1
        return True

    def ensure(self, row: int, pos: int) -> bool:
        """Grow row ``row`` to cover a *write* at absolute position
        ``pos`` (decode step).  A covered position whose block is
        shared (refcount > 1) triggers copy-on-write; a covered block
        this row owns exclusively but that is still in the prefix index
        is de-indexed (its content is about to diverge from the indexed
        token prefix).  Returns False when the attn pool is exhausted —
        the scheduler must preempt."""
        logical = min(pos, self.max_len - 1) // self.block_size
        held = len(self._held["attn"][row])
        if logical < held:
            blk = int(self.tables[row, logical])
            if self._ref[blk] > 1:
                return self._cow(row, logical, blk)
            if blk in self._block_key:
                self._deindex(blk)
            return True
        if logical != held:  # growth is 1 block/step by construction
            raise RuntimeError(
                f"ensure: row {row} skipped to logical block {logical} "
                f"with only {held} held")
        return self._alloc("attn", row, self.tables, logical)

    def take_pending_copies(self) -> List[Tuple[int, int]]:
        """Drain the queued COW ``(src, dst)`` pool copies.  The caller
        must apply them to every attn-pool leaf (device side) before
        the next forward reads or writes the ``dst`` blocks."""
        out, self.pending_copies = self.pending_copies, []
        return out

    def release(self, row: int):
        """Drop every block reference row ``row`` holds (completion or
        preemption).  Attn blocks are refcounted: a block returns to
        the free list (and leaves the prefix index) only when its last
        owner releases it — a preempted request's shared prefix blocks
        stay resident for their surviving sharers."""
        blocks, free = self._held["attn"][row], self._free["attn"]
        for b in reversed(blocks):  # LIFO order matches the old ledger
            if self._ref[b] <= 0:  # guard must survive ``python -O``
                raise RuntimeError(f"double free of attn block {b}")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._deindex(b)
                free.append(b)
        blocks.clear()
        for g, table in (("swa", self.swa_tables),
                         ("cross", self.cross_tables)):
            blocks, free = self._held[g][row], self._free[g]
            dup = set(blocks) & set(free)
            if dup:  # guard must survive ``python -O``
                raise RuntimeError(
                    f"double free of {g} blocks {sorted(dup)}")
            free.extend(reversed(blocks))
            blocks.clear()
        self.tables[row] = 0
        self.swa_tables[row] = 0
        self.cross_tables[row] = 0
        self._hit_tokens_row[row] = 0
        self._version += 1

    def check(self):
        """Ledger invariants: every block is exactly one of
        {free, scratch, referenced}; attn refcounts equal both the
        held-list multiplicity and the table occupancy (sharing maps a
        block into several rows' tables, once each); no leak, no
        double-book; index entries only on live attn blocks."""
        for g, n in self._groups.items():
            free = self._free[g]
            held = [b for row in self._held[g] for b in row]
            assert len(set(free)) == len(free), f"{g}: dup in free list"
            assert 0 not in free and 0 not in held, f"{g}: scratch booked"
            if g == "attn":
                held_n = Counter(held)
                occupancy = Counter(
                    b for row in range(self.max_rows)
                    for b in self.tables[row].tolist() if b != 0)
                free_set = set(free)
                for b in range(1, n + 1):
                    r = int(self._ref[b])
                    assert r == held_n.get(b, 0), \
                        f"attn: block {b} refcount {r} != held {held_n.get(b, 0)}"
                    assert r == occupancy.get(b, 0), \
                        (f"attn: block {b} refcount {r} != table "
                         f"occupancy {occupancy.get(b, 0)}")
                    assert (b in free_set) == (r == 0), \
                        (f"attn: block {b} ref {r} "
                         f"{'in' if b in free_set else 'not in'} free list")
                assert len(free) + len(set(held)) == n, \
                    f"attn: leak ({len(free)} free + {len(set(held))} held)"
            else:
                assert len(set(held)) == len(held), f"{g}: block shared"
                assert sorted(free + held) == list(range(1, n + 1)), \
                    f"{g}: leak ({len(free)} free + {len(held)} held != {n})"
        for blk, key in self._block_key.items():
            assert self._prefix_index.get(key) == blk, \
                f"index: block {blk} reverse-mapped to a stale key"
            assert self._ref[blk] >= 1, f"index: freed block {blk} indexed"
        assert len(self._prefix_index) == len(self._block_key), \
            "index: forward/reverse maps out of sync"
        for table, g in ((self.tables, "attn"), (self.swa_tables, "swa"),
                         (self.cross_tables, "cross")):
            for row in range(self.max_rows):
                ids = set(table[row].tolist()) - {0}
                assert ids <= set(self._held[g][row]), \
                    f"{g}: row {row} maps unheld blocks"


def paged_reset_row(caches, segs, row, cross_ids=None):
    """Zero decode row ``row``'s per-request state in a paged pytree:
    SSM/conv state rows, plus its cross-KV blocks (``cross_ids``, the
    row's cross-table entries) — scratch id 0 padding is harmless.
    Attn/swa pools are untouched (stale KV is position-masked)."""
    out = []
    for seg, c in zip(segs, caches):
        if seg.kind in ("mamba1", "mamba2"):
            c = jax.tree.map(lambda a: a.at[:, row].set(0), c)
        elif cross_ids is not None and ("xk" in c or "xv" in c):
            c = {k: (v.at[:, cross_ids].set(0) if k in ("xk", "xv") else v)
                 for k, v in c.items()}
        out.append(c)
    return out


def paged_copy_blocks(caches, segs, src, dst, *, has_swa: bool = False):
    """Apply queued copy-on-write pool copies to a paged pytree.

    ``src``/``dst`` are equal-length int arrays of physical attn-pool
    block ids (from :meth:`PagedCache.take_pending_copies`); each dst
    block becomes a byte-copy of its src block across every attn-pool
    k/v leaf.  Sharing is gated off for SWA/cross/SSM architectures, so
    only the shared attn pool ever needs copying; ``has_swa`` asserts
    that gate held (a windowless "swa" segment shares the attn pool in
    :meth:`PagedCache.struct` and is copied like one)."""
    assert not has_swa, "COW on an SWA architecture (sharing is gated off)"
    out = []
    for seg, c in zip(segs, caches):
        if seg.kind in ("attn", "swa"):
            c = {k: (v.at[:, dst].set(v[:, src]) if k in ("k", "v") else v)
                 for k, v in c.items()}
        out.append(c)
    return out
