"""Per-segment cache/state construction: dense slot rows and paged pools.

Two cache layouts share the same per-segment pytree structure (one list
entry per segment, leaves with a leading layer dim):

* **Dense** (:func:`cache_struct`) — one full ``seq_len`` row per batch
  slot: attn/swa leaves are ``(n_layers, batch, slots, kv_heads, hd)``
  where slot == absolute position for attn and ``pos % window`` for the
  SWA ring.  Memory is reserved worst-case per slot, so admission is
  slot-granular (`serving/engine.py`'s dense engines).
* **Paged** (:class:`PagedCache` + :meth:`PagedCache.struct`) —
  fixed-size blocks in a shared pool: attn/swa/cross leaves are
  ``(n_layers, num_physical_blocks, block_size, kv_heads, hd)`` and a
  request's logical slot ``s`` lives at
  ``(tables[row, s // block_size], s % block_size)``.  Admission is
  block-granular (token-level), so mixed-length workloads share the
  pool (`serving/engine.py`'s paged engines).

Cache layout invariants (relied on across models/serving/kernels):

* physical block 0 of every paged pool is the **scratch block**: never
  allocated, it absorbs the writes of inactive decode rows; block-table
  entries of unallocated logical blocks point at scratch, and every
  read through them is masked by position;
* stale attn/swa KV needs no zeroing on block reuse — attention masks
  slots above ``pos`` (and the SWA ring is fully rewritten before its
  all-slots-valid regime at ``pos >= window - 1``);
* cross KV (``xk``/``xv``) is *not* position-masked, so a request's
  cross blocks are zeroed at admission (token requests carry no
  frontend; parity with the dense engines' zero-initialised cross
  rows);
* SSM/conv state stays per-request dense (``(n_layers, rows, ...)``)
  in both layouts and must be zeroed on row (re)use — stale KV is
  masked by position, stale recurrent state is not.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import build_segments, segment_range


def cache_struct(cfg, batch: int, seq_len: int, dtype, layers=None) -> list:
    """One entry per segment, each a dict with leading layer dim.

    ``layers=(lo, hi)`` restricts the structure to that decoder layer
    range (a pipeline stage's slice — aligned with
    :func:`repro.models.transformer.segment_range`).
    """
    segs = (build_segments(cfg) if layers is None
            else segment_range(cfg, *layers))
    caches = []
    for seg in segs:
        n = seg.length
        if seg.kind in ("attn", "cross") or (
                seg.kind == "swa" and not cfg.window):
            s = seq_len
        elif seg.kind == "swa":
            s = min(cfg.window, seq_len)
        if seg.kind in ("attn", "swa"):
            c = {
                "k": jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((n, batch, s, cfg.n_kv_heads, cfg.head_dim),
                               dtype),
            }
            if cfg.is_encoder_decoder:
                c["xk"] = jnp.zeros(
                    (n, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim),
                    dtype)
                c["xv"] = jnp.zeros_like(c["xk"])
        elif seg.kind == "cross":
            src = cfg.n_image_tokens or cfg.encoder_seq
            c = {
                "xk": jnp.zeros((n, batch, src, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
                "xv": jnp.zeros((n, batch, src, cfg.n_kv_heads, cfg.head_dim),
                                dtype),
            }
        elif seg.kind == "mamba1":
            di, ds = cfg.d_inner_eff, cfg.ssm_state
            c = {
                "h": jnp.zeros((n, batch, di, ds), jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, di), dtype),
            }
        elif seg.kind == "mamba2":
            di, ds = cfg.d_inner_eff, cfg.ssm_state
            nh = di // cfg.mamba2_headdim
            c = {
                "h": jnp.zeros((n, batch, nh, cfg.mamba2_headdim, ds),
                               jnp.float32),
                "conv": jnp.zeros((n, batch, cfg.conv_width - 1, di), dtype),
            }
        else:
            raise ValueError(seg.kind)
        caches.append(c)
    return caches


def cache_bytes(cfg, batch: int, seq_len: int, bytes_per_el: int = 2) -> int:
    struct = jax.eval_shape(lambda: cache_struct(cfg, batch, seq_len,
                                                 jnp.bfloat16))
    return sum(x.size * bytes_per_el for x in jax.tree.leaves(struct))


# ----------------------------------------------------------------------
# Paged cache: block pools + per-request block tables
# ----------------------------------------------------------------------
class PagedCache:
    """Host-side paged-cache ledger: free lists + per-request block tables.

    Three block groups cover the attention segment kinds (SSM state is
    per-request dense, see module docstring):

    ``attn``
        the shared contention pool — ``num_blocks`` usable blocks of
        ``block_size`` tokens; one block id covers the same logical
        token range in *every* attn-kind layer's pool.  Logical slot ==
        absolute position; a request holds
        ``ceil(tokens / block_size)`` blocks and grows block-by-block
        as it decodes (:meth:`ensure`).  This is the group token-level
        admission and preemption arbitrate over.
    ``swa``
        per-request ring of ``ceil(min(window, max_len) / bs)`` blocks
        holding ring slot ``pos % window``; sized worst-case
        (``max_rows`` full rings) so allocation never fails and the
        ring never contends with the attn pool.
    ``cross``
        per-request ``ceil(src / bs)`` blocks of encoder/frontend KV,
        allocated and zeroed at admission (cross reads are not
        position-masked).

    The ledger is pure numpy/python — deterministic LIFO free lists,
    no jax state.  Pool arrays are built separately by :meth:`struct`
    (optionally restricted to a pipeline stage's layer range) so one
    ledger can govern several stage-sliced pools that share block ids.

    ``watermark_blocks`` holds back free attn blocks at admission time:
    a new request is admitted only if its prompt fits *and* the pool
    stays above the watermark, reserving headroom for the decode growth
    of already-running requests (fewer preemptions at high load).
    """

    def __init__(self, cfg, *, max_rows: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 watermark_blocks: int = 0):
        assert max_len % block_size == 0, (max_len, block_size)
        self.cfg = cfg
        self.max_rows = max_rows
        self.max_len = max_len
        self.block_size = block_size
        self.nb_logical = max_len // block_size
        self.watermark_blocks = watermark_blocks

        kinds = {s.kind for s in build_segments(cfg)}
        self.has_swa = "swa" in kinds and bool(cfg.window)
        self.window_eff = min(cfg.window, max_len) if self.has_swa else 0
        self.nb_swa = (-(-self.window_eff // block_size)
                       if self.has_swa else 0)
        src = (cfg.n_image_tokens or cfg.encoder_seq
               if ("cross" in kinds or cfg.is_encoder_decoder) else 0)
        self.cross_src = src
        self.nb_cross = -(-src // block_size) if src else 0

        self.num_blocks = (max_rows * self.nb_logical
                           if num_blocks is None else num_blocks)
        self._groups = {"attn": self.num_blocks,
                        "swa": max_rows * self.nb_swa,
                        "cross": max_rows * self.nb_cross}
        # LIFO free lists; block id 0 is the scratch block of each group
        self._free = {g: list(range(n, 0, -1))
                      for g, n in self._groups.items()}
        self._held = {g: [[] for _ in range(max_rows)]
                      for g in self._groups}
        self.tables = np.zeros((max_rows, self.nb_logical), np.int32)
        self.swa_tables = np.zeros((max_rows, max(self.nb_swa, 1)), np.int32)
        self.cross_tables = np.zeros((max_rows, max(self.nb_cross, 1)),
                                     np.int32)
        # incremental device snapshot: the ledger version bumps on every
        # table mutation (admit/growth/release/preempt); meta() re-uploads
        # only when the version moved, so steady-state decode reuses one
        # immutable device copy instead of copying every table per forward
        self._version = 0
        self._meta_version = -1
        self._meta_cache: Optional[dict] = None
        self.n_meta_uploads = 0

    # -------------------------------------------------------------- pools
    def struct(self, dtype, layers=None) -> list:
        """Block-pool pytree for decoder layers ``layers`` (default all).

        Mirrors :func:`cache_struct` segment-for-segment; attn/swa/cross
        leaves swap the per-slot batch rows for
        ``(group_blocks + 1, block_size)`` physical pools (+1 for the
        scratch block), SSM leaves keep ``max_rows`` state rows.
        """
        cfg = self.cfg
        segs = (build_segments(cfg) if layers is None
                else segment_range(cfg, *layers))
        bs, kvh, hd = self.block_size, cfg.n_kv_heads, cfg.head_dim
        nb_attn = self._groups["attn"] + 1
        nb_swa = self._groups["swa"] + 1
        nb_cross = self._groups["cross"] + 1
        caches = []
        for seg in segs:
            n = seg.length
            if seg.kind in ("attn", "swa"):
                nb = (nb_swa if (seg.kind == "swa" and cfg.window)
                      else nb_attn)
                c = {"k": jnp.zeros((n, nb, bs, kvh, hd), dtype),
                     "v": jnp.zeros((n, nb, bs, kvh, hd), dtype)}
                if cfg.is_encoder_decoder:
                    c["xk"] = jnp.zeros((n, nb_cross, bs, kvh, hd), dtype)
                    c["xv"] = jnp.zeros_like(c["xk"])
            elif seg.kind == "cross":
                c = {"xk": jnp.zeros((n, nb_cross, bs, kvh, hd), dtype),
                     "xv": jnp.zeros((n, nb_cross, bs, kvh, hd), dtype)}
            elif seg.kind == "mamba1":
                di, ds = cfg.d_inner_eff, cfg.ssm_state
                c = {"h": jnp.zeros((n, self.max_rows, di, ds), jnp.float32),
                     "conv": jnp.zeros((n, self.max_rows, cfg.conv_width - 1,
                                        di), dtype)}
            elif seg.kind == "mamba2":
                di, ds = cfg.d_inner_eff, cfg.ssm_state
                nh = di // cfg.mamba2_headdim
                c = {"h": jnp.zeros((n, self.max_rows, nh,
                                     cfg.mamba2_headdim, ds), jnp.float32),
                     "conv": jnp.zeros((n, self.max_rows, cfg.conv_width - 1,
                                        di), dtype)}
            else:
                raise ValueError(seg.kind)
            caches.append(c)
        return caches

    # ---------------------------------------------------------- metadata
    def meta(self, row: Optional[int] = None) -> dict:
        """Block-table metadata for a jitted forward call.

        Snapshot copies (``jnp.asarray`` aliases numpy buffers on CPU
        and the jitted callee dispatches asynchronously — the ledger
        must stay mutable on the host side).  ``row`` restricts tables
        to one request (the chunked-prefill path).

        The full-table snapshot (``row=None``, the per-decode path) is
        cached against :attr:`_version`: it is rebuilt only when the
        ledger actually changed since the last upload — during steady-
        state decode the same immutable device arrays are handed to
        every macro-step.  (:attr:`n_meta_uploads` counts rebuilds;
        benchmarks/engine_bench.py reports uploads per token.)
        """
        if row is None:
            if self._meta_version == self._version:
                return self._meta_cache
            self._meta_cache = self._build_meta(slice(None))
            self._meta_version = self._version
            self.n_meta_uploads += 1
            return self._meta_cache
        return self._build_meta(slice(row, row + 1))

    def _build_meta(self, sel) -> dict:
        out = {"tables": jnp.asarray(self.tables[sel].copy())}
        if self.has_swa:
            out["swa_tables"] = jnp.asarray(self.swa_tables[sel].copy())
        if self.nb_cross:
            out["cross_tables"] = jnp.asarray(self.cross_tables[sel].copy())
        return out

    # -------------------------------------------------------- accounting
    def blocks_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.block_size)

    @property
    def free_blocks(self) -> int:
        return len(self._free["attn"])

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - self.free_blocks

    def utilization(self) -> float:
        return (self.used_blocks / self.num_blocks) if self.num_blocks else 0.0

    def fits(self, total_tokens: int) -> bool:
        """Can a request ever run: worst-case footprint vs pool size."""
        return self.blocks_needed(total_tokens) <= self.num_blocks

    def can_admit(self, n_tokens: int,
                  watermark: Optional[int] = None) -> bool:
        """``watermark`` overrides the configured headroom — the
        scheduler drops it to 0 when nothing is running (headroom only
        exists to protect active requests' decode growth; holding an
        idle pool back would deadlock a lone large request)."""
        wm = self.watermark_blocks if watermark is None else watermark
        need = self.blocks_needed(n_tokens)
        return (len(self._free["attn"]) - wm >= need
                and len(self._free["swa"]) >= self.nb_swa
                and len(self._free["cross"]) >= self.nb_cross)

    def _alloc(self, group: str, row: int, table: np.ndarray,
               logical: int) -> bool:
        free = self._free[group]
        if not free:
            return False
        blk = free.pop()
        self._held[group][row].append(blk)
        table[row, logical] = blk
        self._version += 1
        return True

    def _alloc_or_die(self, group: str, row: int, table: np.ndarray,
                      logical: int):
        # callers hold the can_admit guarantee; a failure here is ledger
        # corruption, and must raise even under ``python -O``
        if not self._alloc(group, row, table, logical):
            raise RuntimeError(
                f"{group} pool exhausted mid-admit (row {row}, logical "
                f"{logical}) despite can_admit — ledger corrupted")

    def admit(self, row: int, n_tokens: int,
              watermark: Optional[int] = None) -> bool:
        """Allocate row ``row``'s blocks for logical slots [0, n_tokens)
        plus its full SWA ring and cross blocks.  All-or-nothing."""
        if any(self._held[g][row] for g in self._held):
            raise RuntimeError(f"admit: row {row} still holds blocks")
        if not self.can_admit(n_tokens, watermark=watermark):
            return False
        for j in range(self.blocks_needed(n_tokens)):
            self._alloc_or_die("attn", row, self.tables, j)
        for j in range(self.nb_swa):
            self._alloc_or_die("swa", row, self.swa_tables, j)
        for j in range(self.nb_cross):
            self._alloc_or_die("cross", row, self.cross_tables, j)
        return True

    def ensure(self, row: int, pos: int) -> bool:
        """Grow row ``row`` to cover a write at absolute position
        ``pos`` (decode step).  Returns False when the attn pool is
        exhausted — the scheduler must preempt."""
        logical = min(pos, self.max_len - 1) // self.block_size
        held = len(self._held["attn"][row])
        if logical < held:
            return True
        if logical != held:  # growth is 1 block/step by construction
            raise RuntimeError(
                f"ensure: row {row} skipped to logical block {logical} "
                f"with only {held} held")
        return self._alloc("attn", row, self.tables, logical)

    def release(self, row: int):
        """Return every block row ``row`` holds (completion/preemption)."""
        for g, table in (("attn", self.tables), ("swa", self.swa_tables),
                         ("cross", self.cross_tables)):
            blocks, free = self._held[g][row], self._free[g]
            dup = set(blocks) & set(free)
            if dup:  # guard must survive ``python -O``
                raise RuntimeError(
                    f"double free of {g} blocks {sorted(dup)}")
            free.extend(reversed(blocks))
            blocks.clear()
        self.tables[row] = 0
        self.swa_tables[row] = 0
        self.cross_tables[row] = 0
        self._version += 1

    def check(self):
        """Free-list/table invariants (no leak, no double-book)."""
        for g, n in self._groups.items():
            free = self._free[g]
            held = [b for row in self._held[g] for b in row]
            assert len(set(free)) == len(free), f"{g}: dup in free list"
            assert 0 not in free and 0 not in held, f"{g}: scratch booked"
            assert sorted(free + held) == list(range(1, n + 1)), \
                f"{g}: leak ({len(free)} free + {len(held)} held != {n})"
        for table, g in ((self.tables, "attn"), (self.swa_tables, "swa"),
                         (self.cross_tables, "cross")):
            for row in range(self.max_rows):
                ids = set(table[row].tolist()) - {0}
                assert ids <= set(self._held[g][row]), \
                    f"{g}: row {row} maps unheld blocks"


def paged_reset_row(caches, segs, row, cross_ids=None):
    """Zero decode row ``row``'s per-request state in a paged pytree:
    SSM/conv state rows, plus its cross-KV blocks (``cross_ids``, the
    row's cross-table entries) — scratch id 0 padding is harmless.
    Attn/swa pools are untouched (stale KV is position-masked)."""
    out = []
    for seg, c in zip(segs, caches):
        if seg.kind in ("mamba1", "mamba2"):
            c = jax.tree.map(lambda a: a.at[:, row].set(0), c)
        elif cross_ids is not None and ("xk" in c or "xv" in c):
            c = {k: (v.at[:, cross_ids].set(0) if k in ("xk", "xv") else v)
                 for k, v in c.items()}
        out.append(c)
    return out
