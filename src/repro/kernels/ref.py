"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q: (B,H,S,D), k/v: (B,KV,S,D) — GQA when H > KV."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, kv, g, s, d).astype(jnp.float32)
    scores = jnp.einsum("bngqd,bnkd->bngqk", qg,
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(s)[None, :]
        ok = kpos <= qpos
        if window > 0:
            ok = ok & (kpos > qpos - window)
        scores = jnp.where(ok, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngqk,bnkd->bngqd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, pos, *,
                         scale: float | None = None):
    """q: (B,H,D); caches: (B,KV,S,D); pos: (B,) valid-length-1 indices."""
    b, h, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bngd,bnkd->bngk", qg,
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] <= pos[:, None]           # (B,S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngk,bnkd->bngd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, pos, *,
                               scale: float | None = None):
    """Paged flash-decode oracle: gather the logical view, then score.

    q: (B,H,D); pools: (KV, NB, bs, D) physical block pools;
    block_tables: (B, nb) int32 physical ids per logical block
    (unallocated entries may point anywhere in range — they are masked
    by ``pos``); pos: (B,) valid-length-1 indices.
    """
    kv, nb_phys, bs, d = k_pool.shape
    b = q.shape[0]
    nb = block_tables.shape[1]
    # (KV, NB, bs, D)[:, tables] -> (KV, B, nb, bs, D) -> (B, KV, S, D)
    kg = jnp.moveaxis(k_pool[:, block_tables], 1, 0).reshape(
        b, kv, nb * bs, d)
    vg = jnp.moveaxis(v_pool[:, block_tables], 1, 0).reshape(
        b, kv, nb * bs, d)
    return decode_attention_ref(q, kg, vg, pos, scale=scale)


def selective_scan_ref(dt, b_mat, c_mat, x, a_neg, h0):
    """Mamba1 recurrence oracle.

    dt, x: (B,T,DI); b_mat, c_mat: (B,T,DS); a_neg: (DI,DS);
    h0: (B,DI,DS).  Returns (y: (B,T,DI), h_T).
    """
    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        decay = jnp.exp(dt_t[..., None] * a_neg[None])
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b_mat, 1, 0),
          jnp.moveaxis(c_mat, 1, 0), jnp.moveaxis(x, 1, 0))
    h_t, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1), h_t


def quant_matmul_int8_ref(x, q, s):
    """x (..., K) @ dequant(q (K, N) int8, s (1, N) f32) -> (..., N).

    Per-output-channel symmetric scales: w = q * s.  Dequant-then-dot
    in f32, result cast back to x.dtype — the numeric contract the
    fused Pallas kernel must reproduce to f32 round-off.
    """
    w = q.astype(jnp.float32) * s
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def unpack_int4_ref(packed):
    """(K//2, N) uint8 -> (K, N) int8 in [-8, 7].

    Packed row r holds k=2r in the low nibble and k=2r+1 in the high
    nibble; stored nibbles are biased by +8 (see models/quantize.py).
    """
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    k2, n = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)


def quant_matmul_int4_ref(x, q, s):
    """x (..., K) @ dequant(q (K//2, N) packed uint8, s (K//G, N) f32).

    Per-group scales along K (G inferred from the shapes):
    w[k] = (nibble[k] - 8) * s[k // G].
    """
    k = q.shape[-2] * 2
    g = k // s.shape[-2]
    w = unpack_int4_ref(q).astype(jnp.float32) * jnp.repeat(s, g, axis=0)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)
