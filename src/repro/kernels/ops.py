"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels execute with interpret=True for
correctness validation; on TPU set REPRO_PALLAS_COMPILE=1 (or pass
interpret=False) to compile for real.  Each op falls back to the ref.py
oracle with use_pallas=False.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.selective_scan import selective_scan_pallas


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_COMPILE"):
        return False
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "use_pallas", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    interpret = _interpret_default() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  interpret=interpret)


# reprolint: disable-next=jit-donation -- read-only KV view: returns
# attention output, not an updated cache; donating would invalidate
# the caller's live cache buffers (engines donate at their own jits)
@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q, k_cache, v_cache, pos, *, use_pallas: bool = True,
                     interpret: bool | None = None):
    if not use_pallas:
        return ref.decode_attention_ref(q, k_cache, v_cache, pos)
    interpret = _interpret_default() if interpret is None else interpret
    return decode_attention_pallas(q, k_cache, v_cache, pos,
                                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def selective_scan(dt, b_mat, c_mat, x, a_neg, h0, *,
                   use_pallas: bool = True, interpret: bool | None = None):
    if not use_pallas:
        return ref.selective_scan_ref(dt, b_mat, c_mat, x, a_neg, h0)
    interpret = _interpret_default() if interpret is None else interpret
    return selective_scan_pallas(dt, b_mat, c_mat, x, a_neg, h0,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def quant_matmul(x, q, s, *, use_pallas: bool = True,
                 interpret: bool | None = None):
    """Weight-only dequant-fused matmul; format inferred from q.dtype
    (int8 = per-channel, uint8 = packed int4 per-group — layouts in
    kernels/quant_matmul.py; producer in models/quantize.py)."""
    if not use_pallas:
        if q.dtype == jnp.int8:
            return ref.quant_matmul_int8_ref(x, q, s)
        return ref.quant_matmul_int4_ref(x, q, s)
    interpret = _interpret_default() if interpret is None else interpret
    return quant_matmul_pallas(x, q, s, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "use_pallas",
                                             "interpret"))
def rmsnorm(x, scale, eps: float = 1e-5, *, use_pallas: bool = True,
            interpret: bool | None = None):
    if not use_pallas:
        return ref.rmsnorm_ref(x, scale, eps)
    interpret = _interpret_default() if interpret is None else interpret
    return rmsnorm_pallas(x, scale, eps, interpret=interpret)
