"""Mamba1 selective-scan Pallas TPU kernel.

Grid: (batch, num_di_blocks, num_t_chunks) — time chunks innermost; the
SSM state h (block_di, d_state) persists in VMEM scratch across chunks.
Inside a chunk we run a fori_loop over its timesteps: each step is
elementwise in d_inner (VPU work, no MXU), so the natural TPU layout puts
d_inner on lanes.  d_state (16) rides the sublane dim.

HBM traffic: dt/x are read once per (t, di) tile, B/C once per t — the
kernel is memory-bound by design (arithmetic intensity ~ d_state FLOPs
per loaded element), which is why fusing the whole recurrence beats
XLA's per-step scan graph on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import pl_scratch

DEFAULT_BLOCK_DI = 256
DEFAULT_CHUNK_T = 128


def _scan_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, h0_ref,
                 y_ref, hout_ref, h_scr, *, chunk_t: int, seq_len: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a_neg = a_ref[...].astype(jnp.float32)          # (bdi, ds)

    def step(i, h):
        t_global = ti * chunk_t + i
        dt_t = dt_ref[0, i].astype(jnp.float32)     # (bdi,)
        x_t = x_ref[0, i].astype(jnp.float32)
        b_t = b_ref[0, i].astype(jnp.float32)       # (ds,)
        c_t = c_ref[0, i].astype(jnp.float32)
        decay = jnp.exp(dt_t[:, None] * a_neg)
        h_new = decay * h + (dt_t * x_t)[:, None] * b_t[None, :]
        h = jnp.where(t_global < seq_len, h_new, h)
        y = jnp.sum(h * c_t[None, :], axis=-1)      # (bdi,)
        y_ref[0, i] = jnp.where(t_global < seq_len, y,
                                0.0).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def _finish():
        hout_ref[0] = h_scr[...].astype(hout_ref.dtype)


def selective_scan_pallas(dt, b_mat, c_mat, x, a_neg, h0, *,
                          block_di: int = DEFAULT_BLOCK_DI,
                          chunk_t: int = DEFAULT_CHUNK_T,
                          interpret: bool = True):
    """dt/x: (B,T,DI); b_mat/c_mat: (B,T,DS); a_neg: (DI,DS);
    h0: (B,DI,DS).  Returns (y: (B,T,DI), h_T: (B,DI,DS))."""
    b, t, di = dt.shape
    ds = b_mat.shape[-1]
    block_di = min(block_di, di)
    chunk_t = min(chunk_t, t)
    ndi = -(-di // block_di)
    ntc = -(-t // chunk_t)

    kernel = functools.partial(_scan_kernel, chunk_t=chunk_t, seq_len=t)
    y, h_t = pl.pallas_call(
        kernel,
        grid=(b, ndi, ntc),
        in_specs=[
            pl.BlockSpec((1, chunk_t, block_di),
                         lambda bi, dii, ti: (bi, ti, dii)),
            pl.BlockSpec((1, chunk_t, ds), lambda bi, dii, ti: (bi, ti, 0)),
            pl.BlockSpec((1, chunk_t, ds), lambda bi, dii, ti: (bi, ti, 0)),
            pl.BlockSpec((1, chunk_t, block_di),
                         lambda bi, dii, ti: (bi, ti, dii)),
            pl.BlockSpec((block_di, ds), lambda bi, dii, ti: (dii, 0)),
            pl.BlockSpec((1, block_di, ds), lambda bi, dii, ti: (bi, dii, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk_t, block_di),
                         lambda bi, dii, ti: (bi, ti, dii)),
            pl.BlockSpec((1, block_di, ds), lambda bi, dii, ti: (bi, dii, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t, di), dt.dtype),
            jax.ShapeDtypeStruct((b, di, ds), jnp.float32),
        ],
        scratch_shapes=[pl_scratch((block_di, ds))],
        interpret=interpret,
    )(dt, b_mat, c_mat, x, a_neg, h0)
    return y, h_t
