"""Flash-decode Pallas TPU kernels: one query token vs. a long KV cache.

Two layouts share the running-softmax structure (grid (batch*heads,
num_s_blocks), cache blocks innermost, (m, l, acc) in VMEM scratch):

* :func:`decode_attention_pallas` — dense contiguous caches
  (B, KV, S, D); the per-batch valid length (`pos`) masks stale slots.
* :func:`paged_decode_attention_pallas` — block-pool caches
  (KV, NB, bs, D) addressed through per-request block tables
  (`models/kvcache.py`).  The tables and `pos` ride in scalar prefetch
  (``PrefetchScalarGridSpec``), so the *index map itself* performs the
  block-table gather: grid step (bh, si) DMAs physical block
  ``tables[b, si]`` — the kernel never materializes a request's
  logical view, which is the point of paging (on real TPU the map can
  additionally skip blocks past ``pos`` entirely).

The dense kernel is the single-chip building block of the seq-parallel
distributed decode in repro.serving.decode (shard_map over the `model`
axis + psum-combine of (m, l, acc)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.flash_attention import pl_scratch

DEFAULT_BLOCK_S = 256
NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_s: int, seq_len: int,
                   batch: int, heads: int):
    bh = pl.program_id(0)
    si = pl.program_id(1)
    ns = pl.num_programs(1)
    b = bh // heads

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (1, d)
    k = k_ref[0].astype(jnp.float32)           # (bs, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    valid = (kpos <= pos_ref[b]) & (kpos < seq_len)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, pos, *, scale=None,
                            block_s: int = DEFAULT_BLOCK_S,
                            interpret: bool = True):
    """q: (B,H,D); caches: (B,KV,S,D); pos: (B,) int32.  -> (B,H,D)."""
    b, h, d = q.shape
    kv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    block_s = min(block_s, s)
    ns = -(-s // block_s)

    qf = q.reshape(b * h, 1, d)
    kf = k_cache.reshape(b * kv, s, d)
    vf = v_cache.reshape(b * kv, s, d)

    kernel = functools.partial(
        _decode_kernel, scale=scale, block_s=block_s, seq_len=s,
        batch=b, heads=h)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, ns),
        in_specs=[
            # pos: whole (B,) vector visible to every program instance
            pl.BlockSpec((b,), lambda bh, si: (0,)),
            pl.BlockSpec((1, 1, d), lambda bh, si: (bh, 0, 0)),
            pl.BlockSpec((1, block_s, d),
                         lambda bh, si, g=g: (bh // g, si, 0)),
            pl.BlockSpec((1, block_s, d),
                         lambda bh, si, g=g: (bh // g, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, si: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        scratch_shapes=[
            pl_scratch((1, 1)), pl_scratch((1, 1)), pl_scratch((1, d)),
        ],
        interpret=interpret,
    )(pos.astype(jnp.int32), qf, kf, vf)
    return out.reshape(b, h, d)


def _paged_decode_kernel(tables_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *,
                         scale: float, block_s: int, heads: int):
    """Body is the dense running softmax; the block-table indirection
    happened in the index maps (k_ref/v_ref already hold the physical
    block tables_ref[b, si] selected)."""
    bh = pl.program_id(0)
    si = pl.program_id(1)
    ns = pl.num_programs(1)
    b = bh // heads

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)           # (1, d)
    k = k_ref[0, 0].astype(jnp.float32)        # (bs, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    kpos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    s = jnp.where(kpos <= pos_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pool, v_pool, block_tables, pos, *,
                                  scale=None, interpret: bool = True):
    """Flash decode over paged block pools.

    q: (B,H,D); pools: (KV, NB, bs, D); block_tables: (B, nb) int32
    (entries past a request's length may point anywhere in range —
    ``pos`` masks them); pos: (B,) valid-length-1.  -> (B, H, D).

    The logical KV view is never materialized: each grid step's
    BlockSpec index map reads ``block_tables[b, si]`` from scalar
    prefetch and DMAs that physical block.
    """
    b, h, d = q.shape
    kv, _, block_s, _ = k_pool.shape
    nb = block_tables.shape[1]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale

    qf = q.reshape(b * h, 1, d)
    kernel = functools.partial(
        _paged_decode_kernel, scale=scale, block_s=block_s, heads=h)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,   # block_tables, pos feed the index maps
        grid=(b * h, nb),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda bh, si, tbl, pos: (bh, 0, 0)),
            pl.BlockSpec(
                (1, 1, block_s, d),
                lambda bh, si, tbl, pos, g=g, h=h:
                    ((bh % h) // g, tbl[bh // h, si], 0, 0)),
            pl.BlockSpec(
                (1, 1, block_s, d),
                lambda bh, si, tbl, pos, g=g, h=h:
                    ((bh % h) // g, tbl[bh // h, si], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda bh, si, tbl, pos: (bh, 0, 0)),
        scratch_shapes=[
            pl_scratch((1, 1)), pl_scratch((1, 1)), pl_scratch((1, d)),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * h, 1, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), pos.astype(jnp.int32), qf,
      k_pool, v_pool)
    return out.reshape(b, h, d)
