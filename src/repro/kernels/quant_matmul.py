"""Fused dequantize-matmul Pallas kernels (weight-only int8 / int4).

Decode is memory-bandwidth-bound, so the tokens/s lever is bytes moved
per weight: int8 streams 2x fewer bytes than bf16, packed int4 ~3.6x
(half a byte per weight plus one f32 scale per 64-group).  The
dequantize happens *inside* the matmul tile — the f32 weight tile
exists only in VMEM, never in HBM — which is what makes the format a
bandwidth win rather than a convert-then-matmul wash.

Layouts (produced by ``models/quantize.py``):

* int8 — ``q`` (K, N) int8, ``s`` (1, N) f32: per-output-channel
  symmetric scales, ``w = q * s``.
* int4 — ``q`` (K//2, N) uint8 packing two biased nibbles per byte
  (packed row r holds k=2r in the low nibble, k=2r+1 in the high
  nibble; value = nibble - 8), ``s`` (K//G, N) f32 per-group scales
  along K: ``w[k] = (nibble[k] - 8) * s[k // G]``.

Tolerances: the Pallas kernels match the ``ref.py`` oracles to f32
round-off (different accumulation order; allclose atol 1e-3 at unit
scale) — both dequantize to f32 before the dot.  Against the
*unquantized* dense matmul the error is the quantization error
itself: rel-RMS ~1e-2 for int8, ~1e-1 for int4 on Gaussian weights
(tests/test_quant_matmul.py pins both).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 256
DEFAULT_BLOCK_N = 256


def _qmm_int8_kernel(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w = q_ref[...].astype(jnp.float32)          # dequant in-tile (VMEM)
    o_ref[...] = ((x @ w) * s_ref[...]).astype(o_ref.dtype)


def _qmm_int4_kernel(x_ref, q_ref, s_ref, o_ref, *, group: int):
    packed = q_ref[...]                          # (K//2, bn) uint8
    lo = (packed & 0xF).astype(jnp.int8) - 8
    hi = (packed >> 4).astype(jnp.int8) - 8
    k2, bn = packed.shape
    w = jnp.stack([lo, hi], axis=1).reshape(2 * k2, bn).astype(jnp.float32)
    w = w * jnp.repeat(s_ref[...], group, axis=0)
    o_ref[...] = (x_ref[...].astype(jnp.float32) @ w).astype(o_ref.dtype)


def quant_matmul_pallas(x, q, s, block_m: int = DEFAULT_BLOCK_M,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = True):
    """x (..., K) @ dequant(q, s) -> (..., N) in x.dtype.

    Format is inferred from ``q.dtype``: int8 = per-channel, uint8 =
    packed int4 per-group (see module docstring for layouts).
    """
    orig_shape = x.shape
    k = orig_shape[-1]
    n = q.shape[-1]
    int4 = q.dtype == jnp.uint8
    if int4:
        assert q.shape[-2] * 2 == k, (q.shape, k)
        group = k // s.shape[-2]
    else:
        assert q.shape[-2] == k, (q.shape, k)

    xf = x.reshape(-1, k)
    rows = xf.shape[0]
    bm = min(block_m, rows)
    nm = -(-rows // bm)
    pad_m = nm * bm - rows
    if pad_m:
        xf = jnp.pad(xf, ((0, pad_m), (0, 0)))
    bn = min(block_n, n)
    nn = -(-n // bn)
    pad_n = nn * bn - n
    if pad_n:
        q = jnp.pad(q, ((0, 0), (0, pad_n)))
        s = jnp.pad(s, ((0, 0), (0, pad_n)))

    if int4:
        kernel = functools.partial(_qmm_int4_kernel, group=group)
        q_rows = k // 2
    else:
        kernel = _qmm_int8_kernel
        q_rows = k
    out = pl.pallas_call(
        kernel,
        grid=(nm, nn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((q_rows, bn), lambda i, j: (0, j)),
            pl.BlockSpec((s.shape[0], bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xf.shape[0], nn * bn), x.dtype),
        interpret=interpret,
    )(xf, q, s)
    out = out[:rows, :n]
    return out.reshape(*orig_shape[:-1], n)
