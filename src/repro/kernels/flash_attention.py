"""Flash attention (prefill) Pallas TPU kernel.

Grid: (batch*heads, num_q_blocks, num_k_blocks) — k innermost, so the
running-softmax state lives in VMEM scratch across k steps (TPU grids are
sequential).  Blocks are (BLOCK_Q, head_dim) / (BLOCK_K, head_dim) VMEM
tiles; head_dim is MXU-aligned (128/256).  GQA is handled by the k/v
index_map (q head h reads kv head h // group).  Causal + sliding-window
masking is applied inside the kernel; fully-masked k blocks are skipped
via the grid-pruning predicate in ops.py (we simply mask — XLA-side
pruning would need a custom grid; noted in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0].astype(jnp.float32)          # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    ok = kpos < seq_len
    if causal:
        ok = ok & (kpos <= qpos)
        if window > 0:
            ok = ok & (kpos > qpos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                        # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale=None, block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = True):
    """q: (B,H,S,D); k/v: (B,KV,S,D).  Returns (B,H,S,D)."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    g = h // kv
    scale = d ** -0.5 if scale is None else scale
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    nq = -(-s // block_q)
    nk = -(-s // block_k)

    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * kv, s, d)
    vf = v.reshape(b * kv, s, d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_len=s)

    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        scratch_shapes=[
            pl_scratch((block_q, 1)),
            pl_scratch((block_q, 1)),
            pl_scratch((block_q, d)),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def pl_scratch(shape):
    """VMEM scratch accumulator (TPU); plain array in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY(shape, jnp.float32)
