"""Pallas TPU kernels for the serving hot spots.

<name>.py: pl.pallas_call + explicit BlockSpec VMEM tiling;
ops.py: jit'd public wrappers; ref.py: pure-jnp oracles.
Validated on CPU via interpret=True (see tests/test_kernels.py).
"""
from repro.kernels.ops import (  # noqa: F401
    decode_attention, flash_attention, quant_matmul, rmsnorm,
    selective_scan)
