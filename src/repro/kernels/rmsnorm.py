"""Fused RMSNorm Pallas TPU kernel (row tiles in VMEM, fp32 reduction)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, eps: float = 1e-5,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = True):
    orig_shape = x.shape
    d = orig_shape[-1]
    xf = x.reshape(-1, d)
    rows = xf.shape[0]
    block_rows = min(block_rows, rows)
    nr = -(-rows // block_rows)
    pad = nr * block_rows - rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
