"""SLO-goodput scheduling policies for the serving engines.

This module brings the paper's control theory (Sec. III-B) into the
serving layer: requests carry a **QoS class** with TTFT/TPOT deadlines
(engine-clock steps, :class:`QoSClass`), and the engines' admit /
preempt decisions are delegated to a pluggable
:class:`SchedulerPolicy` (SERVING.md §Scheduling).  Three policies
ship:

``fifo`` (:class:`FIFOPolicy`)
    The pre-policy discipline, bit-for-bit: head-of-line FIFO
    admission, LIFO (newest-admitted) preemption victims, no admission
    test.  The default — every parity harness
    (``tests/golden_decode.json``) runs against it.
``edf`` (:class:`EDFPolicy`)
    Earliest-deadline-first admission over a *slack-aged* deadline key
    with per-class Lyapunov virtual queues
    (:class:`repro.core.lyapunov.VirtualQueues`, eq. 18) driving
    urgency bursts, and deadline-aware preemption: the victim is the
    active request with the **most** slack, never one about to meet
    its TTFT deadline.
``edf_ec`` (:class:`EDFCapacityPolicy`)
    EDF plus an **effective-capacity admission test**
    (:func:`repro.core.effective_capacity.latency_budget`, eq. 21): a
    request that must wait for pool blocks is admitted only if the
    Gamma-modelled block-freeing process covers its deficit within its
    remaining TTFT slack at the class's violation probability — else
    it is rejected up front (``Request.error``) instead of burning
    capacity on a deadline it will miss anyway.

Policies never touch token computation: they reorder *which* request
is admitted or preempted, and greedy decode keeps every request's
token stream independent of that order (outside the pre-existing MoE
co-batch carve-out, SERVING.md) — the goodput parity sweep in
``tests/test_paged.py`` pins FIFO↔EDF stream identity.

**Goodput** — the fraction of submitted requests meeting both
deadlines — is the metric this layer optimizes
(:func:`goodput`, ``benchmarks/goodput_bench.py``):

* TTFT (time to first token): ``t_first - t_submit <= cls.ttft``;
* TPOT (time per output token): the remaining tokens must average
  ``cls.tpot`` steps, ``t_done - t_first <= cls.tpot * (n - 1)``.

All deadline arithmetic is in engine steps (one decode iteration), so
goodput is deterministic given a trace — unlike wall-clock tokens/s.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.effective_capacity import latency_budget
from repro.core.lyapunov import VirtualQueues

# admission-test verdicts
ADMIT = "admit"
DEFER = "defer"     # head-of-line wait: nothing overtakes the choice
REJECT = "reject"


@dataclass(frozen=True)
class QoSClass:
    """One service tier: deadlines in engine-clock steps.

    ``ttft``
        steps allowed from ``t_submit`` to the first emitted token.
    ``tpot``
        steps allowed per output token after the first (the stream
        must *average* this rate, macro-step bursts included).
    ``eps``
        latency-violation probability target — the effective-capacity
        admission test's tail bound (paper eq. 21 ``eps``).
    ``phi``
        virtual-queue weight (paper eq. 19 ``phi_j``): how hard this
        class's deadline debt pulls the EDF key during urgency bursts.
    """

    name: str
    ttft: int
    tpot: float
    eps: float
    phi: float = 1.0


#: Default tiers.  TTFT spans ~1.5 decades so EDF has real choices to
#: make; ``batch`` relies on slack aging to avoid starvation.
QOS_CLASSES: Dict[str, QoSClass] = {
    "interactive": QoSClass("interactive", ttft=16, tpot=2.0,
                            eps=0.05, phi=4.0),
    "standard": QoSClass("standard", ttft=48, tpot=4.0,
                         eps=0.10, phi=1.0),
    "batch": QoSClass("batch", ttft=512, tpot=16.0,
                      eps=0.25, phi=0.25),
}


def get_qos(name: str) -> QoSClass:
    try:
        return QOS_CLASSES[name]
    except KeyError:
        raise KeyError(f"unknown QoS class {name!r}; "
                       f"known: {sorted(QOS_CLASSES)}") from None


# ----------------------------------------------------------------------
# SLO accounting (pure functions of Request stamps)
# ----------------------------------------------------------------------
def ttft_met(req, cls: Optional[QoSClass] = None) -> bool:
    cls = cls or get_qos(req.qos)
    return (req.t_first is not None
            and req.t_first - req.t_submit <= cls.ttft)


def tpot_met(req, cls: Optional[QoSClass] = None) -> bool:
    cls = cls or get_qos(req.qos)
    n = len(req.out_tokens)
    if n <= 1:
        return True
    return req.t_done - req.t_first <= cls.tpot * (n - 1)


def slo_met(req) -> bool:
    """Did this request meet both deadlines?  Rejected and unfinished
    requests count as misses (they produced no on-time stream)."""
    if req.error is not None or req.t_done is None or not req.done:
        return False
    cls = get_qos(req.qos)
    return ttft_met(req, cls) and tpot_met(req, cls)


def goodput(requests: Sequence) -> float:
    """Fraction of submitted requests meeting TTFT **and** TPOT."""
    if not requests:
        return 0.0
    return sum(1 for r in requests if slo_met(r)) / len(requests)


def per_class_stats(requests: Sequence) -> Dict[str, Dict[str, float]]:
    """On-time accounting per QoS class (benchmarks/report.py
    ``--goodput`` renders this as the per-class table)."""
    out: Dict[str, Dict[str, float]] = {}
    for r in requests:
        s = out.setdefault(r.qos, {"n": 0, "on_time": 0, "rejected": 0,
                                   "ttft_sum": 0.0, "ttft_n": 0})
        s["n"] += 1
        s["on_time"] += int(slo_met(r))
        s["rejected"] += int(r.error is not None)
        if r.t_first is not None:
            s["ttft_sum"] += r.t_first - r.t_submit
            s["ttft_n"] += 1
    for s in out.values():
        s["goodput"] = s["on_time"] / s["n"]
        s["ttft_mean"] = (s["ttft_sum"] / s["ttft_n"]) if s["ttft_n"] else 0.0
        del s["ttft_sum"], s["ttft_n"]
    return out


# ----------------------------------------------------------------------
# What a policy may see of the engine's capacity
# ----------------------------------------------------------------------
@dataclass
class CapacityView:
    """Engine-agnostic capacity snapshot handed to
    :meth:`SchedulerPolicy.admission_test`.  ``granule`` is the
    allocation unit in tokens: the paged block size, or a full
    ``cache_len`` row for the dense engines (slot-granular admission
    is just paging with one huge block)."""

    free_tokens: int     # tokens admissible right now (above watermark)
    total_tokens: int    # whole pool
    granule: int         # allocation unit (block_size / cache_len)
    # prefix-sharing probe: tokens -> blocks an admission would *share*
    # rather than allocate (PagedCache.probe_hit; None when the engine
    # has no prefix index).  A cache hit shrinks the modeled service
    # demand in the effective-capacity admission test.
    shared_blocks: Optional[Callable[[List[int]], int]] = None
    # speculative-decoding speedup: mean tokens emitted per live row
    # per verify round (engine spec_accept_mean(); 1.0 when off).  The
    # effective-capacity test scales *fixed* service-time priors by it
    # — online-learned stats already observe the accelerated process.
    spec_accept: float = 1.0

    def blocks(self, n_tokens: int) -> int:
        return -(-n_tokens // self.granule)

    @property
    def free_blocks(self) -> int:
        return self.free_tokens // self.granule


# ----------------------------------------------------------------------
# Policy layer
# ----------------------------------------------------------------------
class SchedulerPolicy:
    """Scheduling hooks the engines delegate to (SERVING.md
    §Scheduling).  The base class IS the FIFO discipline; subclasses
    override the four decision points:

    * :meth:`next_admission` — which queued request to try next
      (head-of-line: a DEFER/blocked choice is never overtaken);
    * :meth:`admission_test` — ``(ADMIT | DEFER | REJECT, message)``
      *before first admission* (resumed requests always pass);
    * :meth:`select_victim` — which active request to preempt when the
      pool is exhausted (``None`` = the needy row preempts itself);
    * :meth:`on_step` / :meth:`on_done` — per-step observation hooks
      (virtual queues, service-rate estimation).

    ``max_preemptions`` (``None`` = unlimited) bounds preemption churn:
    a request preempted that many times is evicted to
    ``engine.rejected`` instead of requeued
    (``_PagedEngine._preempt``).  Policies decide *which* rows run,
    never *what* they compute — token streams are policy-invariant
    (tests/test_paged.py goodput parity sweep).
    """

    name = "fifo"
    max_preemptions: Optional[int] = None

    # -------------------------------------------------------- decisions
    def next_admission(self, queue: List, t: int):
        """The request to try admitting next (FIFO: the queue head)."""
        return queue[0] if queue else None

    def admission_test(self, req, t: int,
                       view: Optional[CapacityView]) -> Tuple[str, Optional[str]]:
        return ADMIT, None

    def select_victim(self, candidates: List[Tuple[int, object]],
                      t: int, needy: int) -> Optional[int]:
        """``candidates`` = active ``(row, request)`` pairs in admission
        order (oldest first).  FIFO/LIFO: preempt the newest."""
        return candidates[-1][0] if candidates else None

    # ------------------------------------------------------ observation
    def on_submit(self, req, t: int):
        pass

    def on_step(self, t: int, queue: List, running: List):
        pass

    def on_done(self, req, t: int):
        pass

    def on_preempt(self, req, t: int):
        pass

    def on_free(self, n_blocks: int, t: int):
        """``n_blocks`` allocation granules returned to the pool
        (completion releases) — service-rate observation hook."""
        pass


class EDFPolicy(SchedulerPolicy):
    """Earliest-deadline-first admission + most-slack preemption.

    The admission key of a queued request is its next deadline, pulled
    earlier by two pressure terms::

        key = deadline - age_rate * wait - phi_c * (H_c - zeta)

    * ``deadline`` — ``t_submit + ttft`` for a fresh request, or the
      *next-token* deadline ``t_first + tpot * (n_out + 1)`` for a
      preempted request resuming mid-stream;
    * **slack aging** — ``age_rate * wait`` guarantees a starving
      ``batch`` request overtakes an endless stream of fresh
      ``interactive`` arrivals within a bounded number of steps
      (tests/test_scheduler_policy.py pins the bound);
    * **urgency bursts** — per-class virtual queues ``H_c``
      (eq. 18: ``H <- max(H + wait_c - ttft_c, zeta)``, updated once
      per engine step with the class's longest queued wait) push a
      whole class forward once its deadline debt accumulates,
      Lyapunov-style; ``phi_c`` weights the push.

    Preemption victims are chosen by **most slack** (the request that
    can best afford a recompute round-trip), never a request still
    awaiting its first token whose TTFT deadline is within
    ``ttft_protect`` steps; ties break to the newest admission (the
    FIFO/LIFO tiebreak, keeping victim choice deterministic).
    ``max_preemptions`` defaults to 8: a request bounced that often is
    evicted rather than thrashed forever.
    """

    name = "edf"

    def __init__(self, *, age_rate: float = 0.5, ttft_protect: int = 4,
                 max_preemptions: Optional[int] = 8):
        self.age_rate = age_rate
        self.ttft_protect = ttft_protect
        self.max_preemptions = max_preemptions
        self.vq = VirtualQueues()

    # -------------------------------------------------------------- keys
    def deadline(self, req) -> float:
        cls = get_qos(req.qos)
        if req.t_first is not None:  # resuming mid-stream: next token due
            return req.t_first + cls.tpot * (len(req.out_tokens) + 1)
        return req.t_submit + cls.ttft

    def admission_key(self, req, t: int) -> float:
        cls = get_qos(req.qos)
        h_boost = cls.phi * (self.vq.get(req.qos) - self.vq.zeta)
        return (self.deadline(req) - self.age_rate * (t - req.t_submit)
                - h_boost)

    def slack(self, req, t: int) -> float:
        return self.deadline(req) - t

    # -------------------------------------------------------- decisions
    def next_admission(self, queue: List, t: int):
        if not queue:
            return None
        return min(queue, key=lambda r: (self.admission_key(r, t),
                                         r.t_submit, r.id))

    def select_victim(self, candidates, t: int, needy: int):
        def protected(req) -> bool:
            # still awaiting its first token with TTFT almost due:
            # preempting it guarantees the miss (already-missed
            # requests get no protection — nothing left to save)
            cls = get_qos(req.qos)
            return (req.t_first is None and not req.out_tokens
                    and 0 <= req.t_submit + cls.ttft - t
                    <= self.ttft_protect)

        eligible = [(row, req) for row, req in candidates
                    if not protected(req)]
        if not eligible:
            return None
        # most slack first; ties -> newest admission (candidates arrive
        # oldest-first, so max() keeps the last of equals)
        best, _ = max(enumerate(eligible),
                      key=lambda e: (self.slack(e[1][1], t), e[0]))
        return eligible[best][0]

    # ------------------------------------------------------ observation
    def on_step(self, t: int, queue: List, running: List):
        """Eq. (18) drift, once per engine step: each class's H moves
        by its longest queued *fresh* wait minus its TTFT budget,
        floored at zeta; classes with nothing queued drain."""
        waits: Dict[str, float] = {}
        for req in queue:
            if req.t_admit is None:
                waits[req.qos] = max(waits.get(req.qos, 0.0),
                                     float(t - req.t_submit))
        for name in set(waits) | set(self.vq.h):
            self.vq.update(name, waits.get(name, 0.0), get_qos(name).ttft)


class EDFCapacityPolicy(EDFPolicy):
    """EDF plus the paper's effective-capacity admission test.

    The block pool's freeing process (blocks released by completions
    per engine step) is modelled as i.i.d. Gamma increments — the same
    service model eq. (20) applies to light-MS rates — with
    ``(shape, scale)`` either supplied or moment-matched online from
    an EWMA of observed per-step frees.  A fresh request that does not
    fit the free pool right now is admitted into the wait only if

        latency_budget(shape, scale, cls.eps, deficit_blocks)
            <= remaining TTFT slack

    (eq. 21's Chernoff inversion, :func:`repro.core.effective_capacity.
    latency_budget`): the smallest statistically-safe time for the
    pool to free its block deficit, at the class's violation
    probability ``eps``.  Otherwise the request is **rejected before
    first admission** — ``t_done`` stamped, ``Request.error`` carrying
    the class name — mirroring the oversized-request ``_reject`` path,
    so capacity is spent only on requests that can still make their
    deadline.  A request whose TTFT slack is already spent is rejected
    on the same path without consulting the model.  Requests that were
    already admitted once (preemption resumes) always pass: their
    admission contract was honoured at first admission.
    """

    name = "edf_ec"

    #: EWMA weight, minimum samples before the online estimate is
    #: trusted (before that the test falls back to plain EDF deferral),
    #: and the sampling window in engine steps.  Completions free
    #: several blocks in one step, so per-step samples are almost all
    #: zero with rare spikes — moment matching them yields a
    #: pathologically small Gamma shape (near-zero effective capacity
    #: and astronomical budgets).  Summing frees over a window averages
    #: the burstiness out; Gamma additivity maps the window estimate
    #: back to per-step ``(shape / W, scale)``.
    EWMA_ALPHA = 0.25
    MIN_SAMPLES = 4
    SAMPLE_WINDOW = 16

    def __init__(self, *, service_shape: Optional[float] = None,
                 service_scale: Optional[float] = None, **kw):
        super().__init__(**kw)
        self._fixed = (service_shape, service_scale)
        self._mean = 0.0       # EWMA of blocks freed per window
        self._mean_sq = 0.0
        self._n_samples = 0
        self._freed = 0.0      # blocks freed in the open window
        self._window_steps = 0
        self._last_t: Optional[int] = None

    # ---------------------------------------------------- service model
    def service_stats(self) -> Tuple[Optional[float], Optional[float]]:
        """Per-engine-step Gamma ``(shape, scale)`` of the block-freeing
        process: the fixed override, else the windowed moment-matched
        EWMA estimate (``None`` until warmed up — the test then defers
        instead of rejecting on a cold model)."""
        if self._fixed[0] is not None:
            return self._fixed
        if self._n_samples < self.MIN_SAMPLES:
            return None, None
        var = max(self._mean_sq - self._mean ** 2, 1e-9)
        mean = self._mean
        if mean <= 1e-9:
            return None, None
        shape_w, scale_w = mean * mean / var, var / mean
        return shape_w / self.SAMPLE_WINDOW, scale_w

    def _observe(self, freed: float):
        a = self.EWMA_ALPHA
        self._mean = (1 - a) * self._mean + a * freed
        self._mean_sq = (1 - a) * self._mean_sq + a * freed * freed
        self._n_samples += 1

    def on_step(self, t: int, queue: List, running: List):
        super().on_step(t, queue, running)
        if self._last_t is not None and t > self._last_t:
            self._window_steps += t - self._last_t
            while self._window_steps >= self.SAMPLE_WINDOW:
                self._observe(self._freed)
                self._freed = 0.0
                self._window_steps -= self.SAMPLE_WINDOW
        self._last_t = t

    def on_free(self, n_blocks: int, t: int):
        """Engine callback: ``n_blocks`` (granules) returned to the
        pool — completion releases, counted into the current step's
        service sample."""
        self._freed += max(0, n_blocks)

    # -------------------------------------------------------- admission
    def admission_test(self, req, t: int, view: Optional[CapacityView]):
        if req.t_admit is not None or view is None:
            return ADMIT, None
        cls = get_qos(req.qos)
        slack = req.t_submit + cls.ttft - t
        if slack < 0:
            return REJECT, (
                f"{cls.name}: TTFT deadline exhausted before admission "
                f"(waited {t - req.t_submit} > ttft {cls.ttft} steps)")
        need_now = view.blocks(len(req.prompt) + len(req.out_tokens))
        if view.shared_blocks is not None:
            # a prefix-cache hit maps blocks instead of allocating them:
            # the modeled service demand shrinks by the shared span
            need_now -= view.shared_blocks(
                (req.prompt + req.out_tokens)[:-1])
        deficit = need_now - view.free_blocks
        if deficit <= 0:
            return ADMIT, None
        shape, scale = self.service_stats()
        if shape is None:
            return DEFER, None
        if self._fixed[0] is not None and view.spec_accept > 1.0:
            # speculative decoding emits spec_accept tokens per row per
            # step on average, so rows finish — and free blocks — that
            # much faster.  Scaling the Gamma *scale* multiplies the
            # mean freeing rate while keeping its shape (burstiness).
            # Only fixed priors are discounted: the windowed EWMA
            # estimate already observes the accelerated process.
            scale = scale * view.spec_accept
        d = latency_budget(shape, scale, cls.eps, float(deficit))
        if d > slack:
            return REJECT, (
                f"{cls.name}: effective-capacity admission test predicts "
                f"{d:.1f} steps to free {deficit} blocks > remaining TTFT "
                f"slack {slack} (eps={cls.eps})")
        return DEFER, None


POLICIES = {
    "fifo": SchedulerPolicy,
    "edf": EDFPolicy,
    "edf_ec": EDFCapacityPolicy,
}
FIFOPolicy = SchedulerPolicy  # the base class IS the FIFO discipline


def make_policy(policy, **kw) -> SchedulerPolicy:
    """``None`` / name / instance -> a fresh policy object (policies
    hold per-engine state — virtual queues, service estimates — so
    engines must never share one)."""
    if policy is None:
        return FIFOPolicy()
    if isinstance(policy, SchedulerPolicy):
        return policy
    try:
        return POLICIES[policy](**kw)
    except KeyError:
        raise ValueError(f"unknown scheduler policy {policy!r}; "
                         f"known: {sorted(POLICIES)}") from None
