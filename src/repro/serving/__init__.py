from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.pipeline import (  # noqa: F401
    PLACEMENT_STRATEGIES, PipelinedEngine, place_stages)
