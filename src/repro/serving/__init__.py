from repro.serving.engine import (  # noqa: F401
    PagedServingEngine, Request, ServingEngine)
from repro.serving.pipeline import (  # noqa: F401
    PLACEMENT_STRATEGIES, PagedPipelinedEngine, PipelinedEngine,
    place_stages)
from repro.serving.scheduler import (  # noqa: F401
    POLICIES, QOS_CLASSES, EDFCapacityPolicy, EDFPolicy, FIFOPolicy,
    QoSClass, SchedulerPolicy, get_qos, goodput, make_policy,
    per_class_stats, slo_met)
from repro.serving.speculative import (  # noqa: F401
    ModelDraft, NgramDraft, SpecConfig, spec_supported)
