from repro.serving.engine import (  # noqa: F401
    PagedServingEngine, Request, ServingEngine)
from repro.serving.pipeline import (  # noqa: F401
    PLACEMENT_STRATEGIES, PagedPipelinedEngine, PipelinedEngine,
    place_stages)
