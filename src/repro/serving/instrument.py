"""Dispatch counting for the serving engines' jitted callables.

The hot-loop contract (SERVING.md §The decode hot loop) is quantitative:
steady-state decode must cost at most ``1/K`` jit dispatches and host
syncs per generated token.  That claim rots silently — a stray
``np.asarray`` or an accidentally un-fused call re-introduces per-token
overhead without failing any parity test.  This module makes it
testable: every engine keeps its jitted programs in a ``_jits`` dict
(name -> callable) and always invokes them through the dict, so
:func:`instrument` can swap in counting wrappers without touching
engine code — including programs compiled *after* instrumentation (the
per-K macro-step jits are built lazily).

    eng = PagedServingEngine(cfg, decode_steps=8)
    counts = instrument(eng)
    ...
    counts.decode_dispatches / eng.tokens_generated   # <= 1/K + prefill

Counter keys are the ``_jits`` names (``decode{k}``, ``prefill``,
``reset``); pipelined engines' per-stage programs are prefixed
``s{i}.``.  tests/test_engine_macro.py pins the dispatches-per-token
regression; benchmarks/engine_bench.py reports the same numbers per
engine/K cell.
"""
from __future__ import annotations

from collections import Counter


class DispatchCounter(dict):
    """A ``_jits`` dict whose entries are wrapped to count invocations.

    Replaces an engine's (or stage's) ``_jits`` mapping in place-of:
    existing entries are re-wrapped on construction, and entries added
    later (lazily compiled macro-step programs) are wrapped by
    ``__setitem__`` as they appear.  ``counts`` maps jit name ->
    invocation count; one invocation == one jit dispatch (the wrapped
    callables are the engines' compiled programs).
    """

    def __init__(self, base: dict, counts: Counter, prefix: str = "",
                 raw: dict = None):
        super().__init__()
        self.counts = counts
        self.prefix = prefix
        self.raw = {} if raw is None else raw
        for name, fn in base.items():
            self[name] = fn

    def __setitem__(self, name, fn):
        key = self.prefix + name
        self.raw[key] = fn

        def counted(*args, _fn=fn, _key=key, **kw):
            self.counts[_key] += 1
            return _fn(*args, **kw)

        dict.__setitem__(self, name, counted)


class EngineCounts:
    """Per-engine dispatch tallies with the derived hot-loop ratios."""

    def __init__(self, engine):
        self.engine = engine
        self.counts: Counter = Counter()
        self.raw: dict = {}  # jit name -> underlying (unwrapped) callable

    @property
    def decode_dispatches(self) -> int:
        return sum(n for name, n in self.counts.items()
                   if name.rsplit(".", 1)[-1].startswith("decode"))

    @property
    def prefill_dispatches(self) -> int:
        return sum(n for name, n in self.counts.items()
                   if name.rsplit(".", 1)[-1] == "prefill")

    @property
    def total_dispatches(self) -> int:
        return sum(self.counts.values())

    def per_token(self, kind: str = "decode") -> float:
        """Dispatches per generated token (``decode``/``prefill``/
        ``total``)."""
        n = getattr(self, f"{kind}_dispatches")
        return n / max(self.engine.tokens_generated, 1)

    def compiled_programs(self) -> int:
        """Total programs XLA has compiled for the engine's jits: the
        sum of jax's per-callable compilation-cache sizes over every
        (unwrapped) ``_jits`` entry.  Dispatch counts say how often the
        hot loop *calls* its programs; this says how many distinct
        programs those calls traced — the number that silently explodes
        when a shape or a captured Python value stops being stable.
        Entries without a compilation cache (e.g. a FakeEngine's plain
        callables, or an unexpectedly old jax) contribute zero, so a
        result of 0 means 'nothing measurable', not 'no compiles'."""
        return sum(fn._cache_size() for fn in self.raw.values()
                   if hasattr(fn, "_cache_size"))


def instrument(engine) -> EngineCounts:
    """Wrap ``engine``'s jitted callables (and its pipeline stages', if
    any) with dispatch counters.  Counting starts now: tallies cover
    only calls made after instrumentation."""
    ec = EngineCounts(engine)
    engine._jits = DispatchCounter(engine._jits, ec.counts, raw=ec.raw)
    for i, st in enumerate(getattr(engine, "stages", [])):
        st._jits = DispatchCounter(st._jits, ec.counts, prefix=f"s{i}.",
                                   raw=ec.raw)
    return ec
