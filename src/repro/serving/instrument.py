"""Dispatch counting for the serving engines' jitted callables.

The hot-loop contract (SERVING.md §The decode hot loop) is quantitative:
steady-state decode must cost at most ``1/K`` jit dispatches and host
syncs per generated token.  That claim rots silently — a stray
``np.asarray`` or an accidentally un-fused call re-introduces per-token
overhead without failing any parity test.  This module makes it
testable: every engine keeps its jitted programs in a ``_jits`` dict
(name -> callable) and always invokes them through the dict, so
:func:`instrument` can swap in counting wrappers without touching
engine code — including programs compiled *after* instrumentation (the
per-K macro-step jits are built lazily).

    eng = PagedServingEngine(cfg, decode_steps=8)
    counts = instrument(eng)
    ...
    counts.decode_dispatches / eng.tokens_generated   # <= 1/K + prefill

Counter keys are the ``_jits`` names (``decode{k}``, ``verify{s}``,
``prefill``, ``reset``); pipelined engines' per-stage programs are
prefixed ``s{i}.`` and a ModelDraft provider's programs ``draft.``.
tests/test_engine_macro.py pins the dispatches-per-token regression;
benchmarks/engine_bench.py and benchmarks/spec_bench.py report the
same numbers per engine/K cell.
"""
from __future__ import annotations

from collections import Counter


class DispatchCounter(dict):
    """A ``_jits`` dict whose entries are wrapped to count invocations.

    Replaces an engine's (or stage's) ``_jits`` mapping in place-of:
    existing entries are re-wrapped on construction, and entries added
    later (lazily compiled macro-step programs) are wrapped by
    ``__setitem__`` as they appear.  ``counts`` maps jit name ->
    invocation count; one invocation == one jit dispatch (the wrapped
    callables are the engines' compiled programs).
    """

    def __init__(self, base: dict, counts: Counter, prefix: str = "",
                 raw: dict = None):
        super().__init__()
        self.counts = counts
        self.prefix = prefix
        self.raw = {} if raw is None else raw
        for name, fn in base.items():
            self[name] = fn

    def __setitem__(self, name, fn):
        key = self.prefix + name
        self.raw[key] = fn

        def counted(*args, _fn=fn, _key=key, **kw):
            self.counts[_key] += 1
            return _fn(*args, **kw)

        dict.__setitem__(self, name, counted)


class EngineCounts:
    """Per-engine dispatch tallies with the derived hot-loop ratios."""

    def __init__(self, engine):
        self.engine = engine
        self.counts: Counter = Counter()
        self.raw: dict = {}  # jit name -> underlying (unwrapped) callable

    @property
    def decode_dispatches(self) -> int:
        return sum(n for name, n in self.counts.items()
                   if name.rsplit(".", 1)[-1].startswith("decode"))

    @property
    def prefill_dispatches(self) -> int:
        return sum(n for name, n in self.counts.items()
                   if name.rsplit(".", 1)[-1] == "prefill")

    @property
    def verify_dispatches(self) -> int:
        """Fused draft-verify rounds (``verify{K+1}`` programs) —
        deliberately NOT counted as decode dispatches: the hot-loop
        ratio tests pin ``decode_dispatches`` to the plain macro-step
        scan, and a speculative engine's analogue is
        ``verify_dispatches / tokens_generated`` (between 1 and
        1/(K+1))."""
        return sum(n for name, n in self.counts.items()
                   if name.rsplit(".", 1)[-1].startswith("verify"))

    @property
    def draft_dispatches(self) -> int:
        """Draft-provider jit dispatches (``draft.*`` — a ModelDraft's
        prefill chunks and proposal scans; 0 for host-only drafts)."""
        return sum(n for name, n in self.counts.items()
                   if name.startswith("draft."))

    @property
    def total_dispatches(self) -> int:
        return sum(self.counts.values())

    def per_token(self, kind: str = "decode") -> float:
        """Dispatches per generated token (``decode``/``prefill``/
        ``total``)."""
        n = getattr(self, f"{kind}_dispatches")
        return n / max(self.engine.tokens_generated, 1)

    def compiled_programs(self) -> int:
        """Total programs XLA has compiled for the engine's jits: the
        sum of jax's per-callable compilation-cache sizes over every
        (unwrapped) ``_jits`` entry.  Dispatch counts say how often the
        hot loop *calls* its programs; this says how many distinct
        programs those calls traced — the number that silently explodes
        when a shape or a captured Python value stops being stable.
        Caveat: jax shares executable caches by underlying-function
        identity, so jits over module-level functions (``reset``) can
        see other engines' compiles — absolute assertions need a cold
        cache (``jax.clear_caches()``), as test_engine_macro.py does.
        Entries without a compilation cache (e.g. a FakeEngine's plain
        callables, or an unexpectedly old jax) contribute zero, so a
        result of 0 means 'nothing measurable', not 'no compiles'."""
        return sum(fn._cache_size() for fn in self.raw.values()
                   if hasattr(fn, "_cache_size"))


def instrument(engine) -> EngineCounts:
    """Wrap ``engine``'s jitted callables (and its pipeline stages', if
    any) with dispatch counters.  Counting starts now: tallies cover
    only calls made after instrumentation."""
    ec = EngineCounts(engine)
    engine._jits = DispatchCounter(engine._jits, ec.counts, raw=ec.raw)
    for i, st in enumerate(getattr(engine, "stages", [])):
        st._jits = DispatchCounter(st._jits, ec.counts, prefix=f"s{i}.",
                                   raw=ec.raw)
    spec = getattr(engine, "spec", None)
    if spec is not None and hasattr(spec.provider, "_jits"):
        spec.provider._jits = DispatchCounter(
            spec.provider._jits, ec.counts, prefix="draft.", raw=ec.raw)
    return ec
