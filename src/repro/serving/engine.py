"""Serving engines: dense slot-based and paged continuous batching.

Two admission disciplines share the chunked-prefill + batched greedy
decode machinery (SERVING.md walks the full request lifecycle):

* **Dense** (:class:`_SlotEngine` → :class:`ServingEngine`) — a fixed
  decode batch with one full ``cache_len`` KV row per slot; a request
  is admitted only when a whole slot frees, so memory is reserved
  worst-case and mixed-length workloads strand most of it.
* **Paged** (:class:`_PagedEngine` → :class:`PagedServingEngine`) — a
  block pool + per-request block tables
  (:class:`repro.models.kvcache.PagedCache`); admission is token-level
  (admit whenever enough free blocks exist), blocks are allocated as
  sequences grow and freed on completion, and when the pool is
  exhausted the newest request is **preempted by recompute**: its
  blocks are freed and it re-queues with its generated prefix, which
  re-prefills on re-admission — greedy decode makes the continuation
  token-identical, so preemption is invisible in outputs.

Cache layout invariants both engines rely on (see also
`src/repro/models/kvcache.py`): prefill/decode touch only the admitted
request's cache rows/blocks; stale attention KV is masked by position
but SSM recurrent/conv state is **not**, so the request's SSM state row
(and its cross-KV blocks, which are read unmasked) must be zeroed at
admission; chunked prefill processes ``prefill_chunk`` prompt tokens
per jitted call with power-of-two tails (:func:`chunk_sizes`) to bound
compiled program shapes.

Both state machines live here and are shared with the pipeline-parallel
executors (serving/pipeline.py); subclasses supply
``_reset_row`` / ``_prefill_row`` / ``_forward``.

Engine time is a **step counter** (one :meth:`step` = one decode
iteration): ``Request.t_submit`` / ``t_admit`` / ``t_done`` are stamped
in those units, so queueing delay (``t_admit - t_submit``) and
completion latency (``t_done - t_submit``) are comparable across
engines (benchmarks/paged_bench.py reports both).  Requests that can
never be served (prompt + max_new_tokens over capacity) are rejected
with ``Request.error`` set — they land in ``engine.rejected``, never
killing the engine.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.kvcache import PagedCache, paged_reset_row


def chunk_sizes(n: int, chunk: int) -> List[int]:
    """Split a prefill of n tokens into jit-friendly chunk lengths:
    full ``chunk``-sized pieces, then a power-of-two decomposition of
    the remainder — so at most log2(chunk) distinct program shapes ever
    compile, whatever prompt lengths arrive."""
    out = [chunk] * (n // chunk)
    rem, bit = n % chunk, 1
    tail: List[int] = []
    while rem:
        if rem & 1:
            tail.append(bit)
        bit <<= 1
        rem >>= 1
    return out + tail[::-1]


def reset_cache_row(caches, slot):
    """Zero batch row ``slot`` of a cache pytree (leaves are
    (n_layers, batch, ...)).  Jit this once per engine."""
    return jax.tree.map(lambda a: a.at[:, slot].set(0), caches)


@dataclass
class Request:
    """One generation request.  ``t_*`` are engine step-counter stamps
    (:meth:`_SlotEngine.step` iterations): ``t_submit`` on submit,
    ``t_admit`` on *first* admission (preemption keeps the original),
    ``t_done`` on completion or rejection.  ``error`` is set instead of
    raising when the request can never fit the engine's cache."""
    id: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    t_submit: int = 0
    t_admit: Optional[int] = None
    t_done: Optional[int] = None
    error: Optional[str] = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class _EngineBase:
    """Queue + step-clock machinery shared by the slot and paged
    engines: submission/rejection bookkeeping, the greedy decode tail,
    and the run loop.  Subclasses own admission and the request store
    (dense slots or paged rows) and implement ``step`` / ``_idle``."""

    MAX_STEPS = 512

    def __init__(self, cfg, *, prefill_chunk: int):
        self.cfg = cfg
        self.prefill_chunk = max(1, prefill_chunk)
        self.queue: List[Request] = []
        self.rejected: List[Request] = []
        self.tokens_generated = 0
        self.t = 0  # step counter (the engine clock for Request.t_*)

    def submit(self, req: Request):
        req.t_submit = self.t
        self.queue.append(req)

    def _reject(self, req: Request, msg: str):
        """Fail one request without killing the engine (an oversized
        request used to trip a bare ``assert`` — stripped under
        ``python -O``, and fatal to every co-batched request)."""
        req.error = msg
        req.t_done = self.t
        self.rejected.append(req)

    def _prefill_chunks(self, row: int, toks: List[int]):
        """Chunked prefill of one admitted request through the
        ``_prefill_row`` hook."""
        i = 0
        for c in chunk_sizes(len(toks), self.prefill_chunk):
            self._prefill_row(row, np.asarray(toks[i:i + c],
                                              dtype=np.int32), i)
            i += c

    def _next_tokens(self, width: int, active: List[int],
                     store: List[Optional[Request]]) -> np.ndarray:
        """Next decode input per active request: last prompt token
        before any generation, else its latest output token."""
        tokens = np.zeros((width, 1), dtype=np.int32)
        for i in active:
            req = store[i]
            tokens[i, 0] = (req.prompt[-1] if not req.out_tokens
                            else req.out_tokens[-1])
        return tokens

    def _greedy(self, logits) -> np.ndarray:
        """Greedy next-token ids over the logical (un-padded) vocab."""
        return np.asarray(
            jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1))[:, 0]

    def step(self) -> List[Request]:  # pragma: no cover - interface
        raise NotImplementedError

    def _idle(self) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def run(self, max_steps: Optional[int] = None) -> List[Request]:
        max_steps = self.MAX_STEPS if max_steps is None else max_steps
        done = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and self._idle():
                break
        return done

    # ------------------------------------------------------------------
    def _reset_row(self, row: int):  # pragma: no cover - interface
        raise NotImplementedError

    def _prefill_row(self, row: int, toks: np.ndarray, pos0: int):
        raise NotImplementedError  # pragma: no cover - interface


class _SlotEngine(_EngineBase):
    """Slot state machine: admission (chunked prefill), batched greedy
    decode, finish bookkeeping.  Forward passes are delegated to the
    subclass hooks:

    * ``_reset_row(slot)`` — clear one cache row before reuse;
    * ``_prefill_row(slot, toks, pos0)`` — process a prompt chunk
      (1, C) at absolute positions pos0.. for one slot;
    * ``_forward(tokens, pos, n_active)`` — one decode step for the
      whole batch, returning logits (B, 1, V_padded).
    """

    def __init__(self, cfg, *, max_batch: int, cache_len: int,
                 prefill_chunk: int):
        super().__init__(cfg, prefill_chunk=prefill_chunk)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.pos = np.zeros(max_batch, dtype=np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _idle(self) -> bool:
        return all(s is None for s in self.slots)

    def _admit(self):
        """Prefill queued requests into free slots: ``prefill_chunk``
        prompt tokens per jitted call (the final prompt token is fed as
        the first decode input in :meth:`step`)."""
        free = self._free_slots()
        while free and self.queue:
            req = self.queue.pop(0)
            # admission must leave max_new_tokens of cache headroom: the
            # decode loop stops a slot at pos >= cache_len - 1, so a
            # prompt of exactly cache_len would otherwise finish after a
            # SINGLE decode step, silently truncating the request
            if len(req.prompt) + req.max_new_tokens > self.cache_len:
                self._reject(
                    req, f"prompt of {len(req.prompt)} + max_new_tokens "
                         f"{req.max_new_tokens} exceeds cache_len "
                         f"{self.cache_len}")
                continue
            slot = free.pop(0)
            req.t_admit = self.t
            self.slots[slot] = req
            self._reset_row(slot)
            toks = req.prompt[:-1]
            self._prefill_chunks(slot, toks)
            self.pos[slot] = len(toks)

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """One engine iteration: admit + batched decode.  Returns
        finished requests."""
        self.t += 1
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        tokens = self._next_tokens(self.max_batch, active, self.slots)
        # self.pos is snapshotted before handing to jax: jnp.asarray
        # aliases numpy buffers on CPU and the jitted forward dispatches
        # asynchronously, so the += below must not race it
        logits = self._forward(tokens, self.pos.copy(), len(active))
        nxt = self._greedy(logits)
        finished = []
        for i in active:
            req = self.slots[i]
            req.out_tokens.append(int(nxt[i]))
            self.tokens_generated += 1
            self.pos[i] += 1
            if req.done or self.pos[i] >= self.cache_len - 1:
                req.t_done = self.t
                finished.append(req)
                self.slots[i] = None
        return finished

    # ------------------------------------------------------------------
    def _forward(self, tokens: np.ndarray, pos: np.ndarray,
                 n_active: int):
        raise NotImplementedError  # pragma: no cover - interface


class _PagedEngine(_EngineBase):
    """Continuous-batching scheduler over a paged KV cache.

    The serving-side analogue of the paper's light-service online
    controller (SERVING.md maps the correspondence): instead of
    admitting work only when a whole dense slot frees, every scheduler
    step greedily admits queued requests while the block pool has
    room (token-level admission), grows running requests block-by-
    block, and resolves pool exhaustion by preempting the most
    recently admitted request (recompute on re-admission keeps greedy
    outputs token-identical).

    Decode rows (``max_rows``) bound *batch width* only; memory is
    bounded by the block pool, so with mixed-length requests the same
    cache memory sustains far more concurrent sequences than the dense
    engines (benchmarks/paged_bench.py measures this).

    Subclasses supply ``_reset_row`` / ``_prefill_row`` / ``_forward``
    (same contract as :class:`_SlotEngine`, with rows instead of
    slots).
    """

    MAX_STEPS = 4096  # preemption churn can stretch a busy run

    def __init__(self, cfg, *, max_rows: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefill_chunk: int = 16, watermark_blocks: int = 0):
        super().__init__(cfg, prefill_chunk=prefill_chunk)
        self.max_rows = max_rows
        self.max_len = max_len
        self.pc = PagedCache(cfg, max_rows=max_rows, max_len=max_len,
                             block_size=block_size, num_blocks=num_blocks,
                             watermark_blocks=watermark_blocks)
        self.pos = np.zeros(max_rows, dtype=np.int32)
        self.rows: List[Optional[Request]] = [None] * max_rows
        self._admit_order: List[int] = []   # rows, oldest admission first
        self.n_preemptions = 0

    def _free_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.rows) if r is None]

    def _idle(self) -> bool:
        return all(r is None for r in self.rows)

    def _admit(self):
        """Token-level admission: FIFO head admits whenever a decode row
        is free and the pool holds its blocks (prompt + already-decoded
        prefix after a preemption).  Head-of-line order is kept — a
        blocked head waits rather than being overtaken, so admission
        order (and with it preemption priority) is deterministic."""
        free = self._free_rows()
        while free and self.queue:
            req = self.queue[0]
            if (len(req.prompt) + req.max_new_tokens > self.max_len
                    or not self.pc.fits(
                        len(req.prompt) + req.max_new_tokens)):
                self.queue.pop(0)
                self._reject(
                    req, f"prompt of {len(req.prompt)} + max_new_tokens "
                         f"{req.max_new_tokens} exceeds capacity "
                         f"(max_len {self.max_len}, "
                         f"{self.pc.num_blocks} blocks)")
                continue
            total = len(req.prompt) + len(req.out_tokens)
            wm = (None if any(r is not None for r in self.rows) else 0)
            if not self.pc.can_admit(total, watermark=wm):
                break
            self.queue.pop(0)
            row = free.pop(0)
            if not self.pc.admit(row, total, watermark=wm):
                # can_admit above said yes; a refusal here is a ledger
                # bug and must not be silently skipped (nor live in an
                # assert — ``python -O`` would strip the allocation)
                raise RuntimeError(
                    f"ledger refused admission it just approved "
                    f"(row {row}, {total} tokens)")
            if req.t_admit is None:
                req.t_admit = self.t
            self.rows[row] = req
            self._admit_order.append(row)
            self._reset_row(row)
            toks = (req.prompt + req.out_tokens)[:-1]
            self._prefill_chunks(row, toks)
            self.pos[row] = len(toks)

    def _preempt(self, row: int):
        """Preempt-by-recompute: free the row's blocks and put the
        request back at the head of the queue carrying its generated
        prefix; re-admission re-prefills prompt+prefix, and greedy
        decode continues token-identically."""
        req = self.rows[row]
        self.pc.release(row)
        self.rows[row] = None
        self._admit_order.remove(row)
        self.queue.insert(0, req)
        self.n_preemptions += 1

    def _grow(self):
        """Ensure every active row owns the block its next decode token
        writes into; on pool exhaustion preempt newest-admitted rows
        until the write fits (oldest rows are served first, so the
        oldest request always makes progress)."""
        for row in list(self._admit_order):
            if self.rows[row] is None:
                continue
            while not self.pc.ensure(row, int(self.pos[row])):
                victim = next(r for r in reversed(self._admit_order)
                              if self.rows[r] is not None)
                self._preempt(victim)
                if victim == row:
                    break

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """One scheduler iteration: admit + grow/preempt + batched
        decode.  Returns finished requests."""
        self.t += 1
        self._admit()
        self._grow()
        active = [i for i, r in enumerate(self.rows) if r is not None]
        if not active:
            return []
        tokens = self._next_tokens(self.max_rows, active, self.rows)
        # pos snapshotted for the same jnp.asarray-aliasing reason as
        # the slot engine
        logits = self._forward(tokens, self.pos.copy())
        nxt = self._greedy(logits)
        finished = []
        for i in active:
            req = self.rows[i]
            req.out_tokens.append(int(nxt[i]))
            self.tokens_generated += 1
            self.pos[i] += 1
            if req.done or self.pos[i] >= self.max_len - 1:
                req.t_done = self.t
                finished.append(req)
                self.rows[i] = None
                self._admit_order.remove(i)
                self.pc.release(i)
        return finished

    @property
    def active_rows(self) -> int:
        return sum(1 for r in self.rows if r is not None)

    # ------------------------------------------------------------------
    def _forward(self, tokens: np.ndarray, pos: np.ndarray):
        raise NotImplementedError  # pragma: no cover - interface


class ServingEngine(_SlotEngine):
    """Monolithic engine: one jitted decode/prefill over the full model."""

    def __init__(self, cfg, params=None, *, max_batch: int = 4,
                 cache_len: int = 128, seed: int = 0,
                 prefill_chunk: int = 16):
        super().__init__(cfg, max_batch=max_batch, cache_len=cache_len,
                         prefill_chunk=prefill_chunk)
        self.model = build_model(cfg)
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.caches = self.model.init_cache(max_batch, cache_len)
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill_chunk)
        self._reset = jax.jit(reset_cache_row)

    def _reset_row(self, slot: int):
        self.caches = self._reset(self.caches, jnp.int32(slot))

    def _prefill_row(self, slot: int, toks: np.ndarray, pos0: int):
        _, self.caches = self._prefill(
            self.params, self.caches, jnp.asarray(toks[None]),
            jnp.int32(pos0), jnp.int32(slot))

    def _forward(self, tokens: np.ndarray, pos: np.ndarray,
                 n_active: int):
        logits, self.caches = self._decode(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos)})
        return logits


class PagedServingEngine(_PagedEngine):
    """Monolithic paged engine: the continuous scheduler over one
    jitted paged decode/prefill (``Model.paged_decode_step`` /
    ``paged_prefill_chunk``).  Greedy outputs are token-identical to
    :class:`ServingEngine` at equal ``max_len``/``cache_len``
    (tests/test_paged.py)."""

    def __init__(self, cfg, params=None, *, max_rows: int = 8,
                 max_len: int = 128, block_size: int = 16,
                 num_blocks: Optional[int] = None, seed: int = 0,
                 prefill_chunk: int = 16, watermark_blocks: int = 0):
        super().__init__(cfg, max_rows=max_rows, max_len=max_len,
                         block_size=block_size, num_blocks=num_blocks,
                         prefill_chunk=prefill_chunk,
                         watermark_blocks=watermark_blocks)
        self.model = build_model(cfg)
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.caches = self.pc.struct(self.model.dtype)
        self._decode = jax.jit(self.model.paged_decode_step)
        self._prefill = jax.jit(self.model.paged_prefill_chunk)
        segs = self.model.segments
        self._reset = jax.jit(
            lambda caches, row, xids: paged_reset_row(caches, segs, row,
                                                      xids))

    def _reset_row(self, row: int):
        xids = jnp.asarray(self.pc.cross_tables[row].copy())
        self.caches = self._reset(self.caches, jnp.int32(row), xids)

    def _prefill_row(self, row: int, toks: np.ndarray, pos0: int):
        _, self.caches = self._prefill(
            self.params, self.caches, jnp.asarray(toks[None]),
            jnp.int32(pos0), jnp.int32(row), self.pc.meta(row=row))

    def _forward(self, tokens: np.ndarray, pos: np.ndarray):
        logits, self.caches = self._decode(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos)},
            self.pc.meta())
        return logits
