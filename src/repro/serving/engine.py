"""Serving engines: dense slot-based and paged continuous batching.

Two admission disciplines share the chunked-prefill + batched greedy
decode machinery (SERVING.md walks the full request lifecycle):

* **Dense** (:class:`_SlotEngine` → :class:`ServingEngine`) — a fixed
  decode batch with one full ``cache_len`` KV row per slot; a request
  is admitted only when a whole slot frees, so memory is reserved
  worst-case and mixed-length workloads strand most of it.
* **Paged** (:class:`_PagedEngine` → :class:`PagedServingEngine`) — a
  block pool + per-request block tables
  (:class:`repro.models.kvcache.PagedCache`); admission is token-level
  (admit whenever enough free blocks exist), blocks are allocated as
  sequences grow and freed on completion, and when the pool is
  exhausted the newest request is **preempted by recompute**: its
  blocks are freed and it re-queues with its generated prefix, which
  re-prefills on re-admission — greedy decode makes the continuation
  token-identical, so preemption is invisible in outputs.

Cache layout invariants both engines rely on (see also
`src/repro/models/kvcache.py`): prefill/decode touch only the admitted
request's cache rows/blocks; stale attention KV is masked by position
but SSM recurrent/conv state is **not**, so the request's SSM state row
(and its cross-KV blocks, which are read unmasked) must be zeroed at
admission; chunked prefill processes ``prefill_chunk`` prompt tokens
per jitted call with power-of-two tails (:func:`chunk_sizes`) to bound
compiled program shapes.

Both state machines live here and are shared with the pipeline-parallel
executors (serving/pipeline.py); subclasses supply
``_reset_row`` / ``_prefill_row`` / ``_forward_steps``.

The decode hot loop is **device-resident** (SERVING.md §The decode hot
loop): every engine iteration runs one fused *macro-step* — a single
jitted ``lax.scan`` of up to ``decode_steps`` (K) greedy decode
iterations (``Model.decode_steps``) that does argmax-over-logical-
vocab, token feedback, per-row ``pos`` bumps, and per-row done masking
on device, returning only ``(rows, K)`` int32 token ids.  The host
syncs once per macro-step instead of once per token and never sees
logits; admission, block growth, and preemption re-enter only at
macro-step boundaries, with each row's in-scan step *budget* clamped so
``max_new_tokens``, cache headroom, and block coverage can never be
violated mid-scan.  Greedy token streams are identical for every K —
outside the pre-existing MoE co-batch carve-out (SERVING.md): under
expert-capacity pressure any change in admission *timing* (macro
boundaries included) changes what a request is co-batched with.
All decode/prefill/reset jits **donate** their cache argument — the
engine treats caches as linear state (every call rebinds
``self.caches`` to the returned pytree and never touches the donated
input again), so XLA reuses the cache buffers in place across steps.
Jitted callables live in ``self._jits`` (name -> callable) so
`serving/instrument.py` can count dispatches without touching engine
code.

Engine time is a **step counter** (one :meth:`step` = one decode
iteration): ``Request.t_submit`` / ``t_admit`` / ``t_done`` are stamped
in those units, so queueing delay (``t_admit - t_submit``) and
completion latency (``t_done - t_submit``) are comparable across
engines (benchmarks/paged_bench.py reports both).  Requests that can
never be served (prompt + max_new_tokens over capacity) are rejected
with ``Request.error`` set — they land in ``engine.rejected``, never
killing the engine.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model, quantize_params
from repro.models.kvcache import (PagedCache, paged_copy_blocks,
                                  paged_reset_row)
from repro.serving.scheduler import (DEFER, REJECT, CapacityView,
                                     make_policy)
from repro.serving.speculative import SpecConfig, spec_supported


def chunk_sizes(n: int, chunk: int) -> List[int]:
    """Split a prefill of n tokens into jit-friendly chunk lengths:
    full ``chunk``-sized pieces, then a power-of-two decomposition of
    the remainder — so at most log2(chunk) distinct program shapes ever
    compile, whatever prompt lengths arrive."""
    out = [chunk] * (n // chunk)
    rem, bit = n % chunk, 1
    tail: List[int] = []
    while rem:
        if rem & 1:
            tail.append(bit)
        bit <<= 1
        rem >>= 1
    return out + tail[::-1]


def reset_cache_row(caches, slot):
    """Zero batch row ``slot`` of a cache pytree (leaves are
    (n_layers, batch, ...)).  Jit this once per engine."""
    return jax.tree.map(lambda a: a.at[:, slot].set(0), caches)


@dataclass
class Request:
    """One generation request.  ``t_*`` are engine step-counter stamps
    (:meth:`_SlotEngine.step` iterations): ``t_submit`` on submit
    (stamped once — a resubmitted / resumed request keeps the
    original), ``t_admit`` on *first* admission (preemption keeps the
    original), ``t_first`` at the device step the first output token
    was produced (the TTFT stamp), ``t_done`` on completion or
    rejection.  ``qos`` names a :data:`repro.serving.scheduler.
    QOS_CLASSES` tier — deadline-driven policies read its TTFT/TPOT
    budgets; the default FIFO policy ignores it.  ``n_preempted``
    counts preempt-by-recompute evictions (policies with
    ``max_preemptions`` bound it).  ``error`` is set instead of
    raising when the request can never fit the engine's cache (or a
    policy's admission test rejects it)."""
    id: int
    prompt: List[int]
    max_new_tokens: int = 16
    qos: str = "standard"
    out_tokens: List[int] = field(default_factory=list)
    t_submit: Optional[int] = None
    t_admit: Optional[int] = None
    t_first: Optional[int] = None
    t_done: Optional[int] = None
    n_preempted: int = 0
    error: Optional[str] = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class _EngineBase:
    """Queue + step-clock machinery shared by the slot and paged
    engines: submission/rejection bookkeeping, macro-step sizing, and
    the run loop.  Subclasses own admission and the request store
    (dense slots or paged rows) and implement ``step`` / ``_idle`` /
    ``_in_flight``."""

    MAX_STEPS = 512

    def __init__(self, cfg, *, prefill_chunk: int, decode_steps: int = 1,
                 policy=None, speculative=None):
        self.cfg = cfg
        self.prefill_chunk = max(1, prefill_chunk)
        self.decode_k = max(1, decode_steps)  # macro-step K
        # pluggable scheduling discipline (serving/scheduler.py);
        # default FIFO reproduces the historical admit/preempt order
        # bit-for-bit (tests/golden_decode.json)
        self.policy = make_policy(policy)
        # draft-verify speculative decoding (serving/speculative.py):
        # auto-gated off on archs whose cache cannot positionally roll
        # back (SSM/SWA/cross/MoE), exactly like prefix sharing
        self.spec = SpecConfig.make(speculative)
        self.spec_gated_off = (self.spec is not None
                               and not spec_supported(cfg))
        if self.spec_gated_off:
            self.spec = None
        self.spec_rounds = 0     # verify rounds run
        self.spec_drafted = 0    # draft tokens proposed (live rows)
        self.spec_accepted = 0   # draft tokens emitted as matches
        self.spec_emitted = 0    # tokens emitted by verify rounds
        self._spec_row_rounds = 0  # live (row, round) pairs
        self.queue: List[Request] = []
        self.rejected: List[Request] = []
        self.unfinished: List[Request] = []  # in flight at last run() exit
        self.tokens_generated = 0
        self.t = 0  # step counter (the engine clock for Request.t_*)
        # jitted callables, keyed by name, always invoked through this
        # dict (late binding lets serving/instrument.py count dispatches)
        self._jits = {}
        self.n_host_syncs = 0      # device->host materializations (decode)
        self.max_macro_tokens = 0  # most tokens emitted by one macro-step
        self.prefill_tokens = 0    # tokens actually prefilled (a prefix
        #                            hit shrinks this: the engine's
        #                            admission-cost / t_first budget)

    def submit(self, req: Request):
        if req.t_submit is None:  # resubmission keeps the original stamp
            req.t_submit = self.t
        self.queue.append(req)
        self.policy.on_submit(req, self.t)

    def _reject(self, req: Request, msg: str):
        """Fail one request without killing the engine (an oversized
        request used to trip a bare ``assert`` — stripped under
        ``python -O``, and fatal to every co-batched request)."""
        req.error = msg
        req.t_done = self.t
        self.rejected.append(req)

    def _prefill_chunks(self, row: int, toks: List[int], pos0: int = 0):
        """Chunked prefill of one admitted request through the
        ``_prefill_row`` hook.  ``pos0`` offsets the absolute positions
        — a prefix-cache hit prefills only the tail beyond the shared
        span (the skipped span never costs a prefill dispatch)."""
        i = 0
        for c in chunk_sizes(len(toks), self.prefill_chunk):
            self._prefill_row(row, np.asarray(toks[i:i + c],
                                              dtype=np.int32), pos0 + i)
            i += c
        self.prefill_tokens += len(toks)

    def _next_tokens(self, width: int, active: List[int],
                     store: List[Optional[Request]]) -> np.ndarray:
        """Next decode input per active request: last prompt token
        before any generation, else its latest output token."""
        tokens = np.zeros((width, 1), dtype=np.int32)
        for i in active:
            req = store[i]
            tokens[i, 0] = (req.prompt[-1] if not req.out_tokens
                            else req.out_tokens[-1])
        return tokens

    def _k_eff(self, kmax: int) -> int:
        """Scan length for this macro-step: the smallest power of two
        >= the largest row budget, capped at ``decode_k`` — so at most
        log2(K) distinct scan programs ever compile (plus the raw
        ``decode_k`` program when K is not itself a power of two)."""
        k = 1
        while k < kmax and k * 2 <= self.decode_k:
            k *= 2
        return k if k >= kmax else self.decode_k

    def _macro_tail(self, store, budgets: np.ndarray, active: List[int],
                    max_len: int, t0: int,
                    k_cap: Optional[int] = None) -> List[tuple]:
        """Run one fused macro-step and do the host-side bookkeeping:
        slice each row's valid token prefix (its budget), bump ``pos``,
        stamp finishers at the device step they actually completed.
        Returns finished ``(row, request)`` pairs (the request still
        holds its row — the caller frees slots/blocks).

        ``k_cap`` bounds the scan length (paged engines: the smallest
        *block-clipped* budget).  A row masked mid-scan keeps running
        the decode compute, which advances its SSM recurrent state —
        harmless for a row that is *finished* (reset before reuse), but
        fatal for one that must resume, since stale SSM state, unlike
        stale KV, is never position-masked.  Capping the scan so only
        finished rows ever mask keeps resume state exact."""
        k_eff = self._k_eff(int(budgets.max()))
        if k_cap is not None and k_eff > k_cap:
            k_eff = 1 << (k_cap.bit_length() - 1)  # largest pow2 <= cap
            budgets = np.minimum(budgets, k_eff)
        tokens = self._next_tokens(len(store), active, store)
        # pos is snapshotted before handing to jax: jnp.asarray aliases
        # numpy buffers on CPU and the jitted scan dispatches
        # asynchronously, so the += below must not race it
        out = self._forward_steps(tokens, self.pos.copy(), budgets, k_eff)
        self.n_host_syncs += 1
        self.max_macro_tokens = max(self.max_macro_tokens,
                                    int(budgets.sum()))
        finished = []
        for i in active:
            req = store[i]
            v = int(budgets[i])
            if v > 0 and req.t_first is None and not req.out_tokens:
                req.t_first = t0 + 1  # first token lands on device step 1
            req.out_tokens += [int(t) for t in out[i, :v]]
            self.tokens_generated += v
            self.pos[i] += v
            if req.done or self.pos[i] >= max_len - 1:
                req.t_done = t0 + v
                finished.append((i, req))
                self.policy.on_done(req, t0 + v)
        self.t = t0 + k_eff
        return finished

    def _decode_jit(self, k: int):
        """Lazily-compiled fused macro-step program for scan length
        ``k`` (monolithic engines — requires ``self.model``; the
        pipelined engines build their stage-chained equivalent in
        ``_NetShimMixin._macro_jit``)."""
        key = f"decode{k}"
        if key not in self._jits:
            self._jits[key] = jax.jit(
                functools.partial(self.model.decode_steps, k=k),
                donate_argnums=(1,))
        return self._jits[key]

    # ------------------------------------------------------------------
    # draft-verify speculative decoding (SERVING.md §Speculative
    # decoding; serving/speculative.py)
    # ------------------------------------------------------------------
    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens emitted as exact matches."""
        return self.spec_accepted / max(1, self.spec_drafted)

    def spec_accept_mean(self) -> float:
        """Expected tokens emitted per live row per verify round (the
        accepted length + 1 correction/bonus) — what EC admission sees
        as the speculative service speedup (CapacityView.spec_accept)."""
        if self._spec_row_rounds == 0:
            return 1.0
        return self.spec_emitted / self._spec_row_rounds

    def _verify_jit(self, s: int):
        """Lazily-compiled fused verify program for chunk width ``s`` =
        K+1 (monolithic engines — requires ``self.model``; the
        pipelined engines chain their stages in
        ``_NetShimMixin._verify_chain_jit``)."""
        key = f"verify{s}"
        if key not in self._jits:
            self._jits[key] = jax.jit(self.model.verify_steps,
                                      donate_argnums=(1,))
        return self._jits[key]

    def _spec_tail(self, store, budgets: np.ndarray, active: List[int],
                   max_len: int, t0: int) -> List[tuple]:
        """Run one draft-verify round and do the host-side bookkeeping
        (the speculative analogue of :meth:`_macro_tail`).

        Each live row proposes K draft tokens (``spec.provider``), the
        target scores all of them in one fused chunk dispatch
        (``_forward_verify``), and the row advances by its accepted
        length + 1 (correction/bonus), clamped to its budget.  Rollback
        of rejected tails is purely positional: ``self.pos`` advances
        only past emitted tokens, the paged ledger keeps its blocks
        (stale KV above ``pos`` is position-masked and overwritten
        before any future read), and no KV is rewritten.  One round ==
        one engine clock step, so ``t_first``/TPOT stamps reflect the
        speculative speedup; host syncs stay at one per round (between
        1 and 1/(K+1) per emitted token).
        """
        K = self.spec.k
        width = len(store)
        tokens = np.zeros((width, K + 1), dtype=np.int32)
        tokens[:, :1] = self._next_tokens(width, active, store)
        for i in active:
            req = store[i]
            tokens[i, 1:] = self.spec.provider.propose(
                i, req.prompt + req.out_tokens, K)
            self.spec_drafted += K
        out = self._forward_verify(tokens, self.pos.copy(), budgets)
        self.n_host_syncs += 1
        self.max_macro_tokens = max(self.max_macro_tokens,
                                    int(budgets.sum()))
        self.spec_rounds += 1
        finished = []
        for i in active:
            req = store[i]
            row = out[i]
            v = int((row >= 0).sum())  # accepted length + 1, <= budget
            if v > 0 and req.t_first is None and not req.out_tokens:
                req.t_first = t0 + 1  # the round is one device step
            emitted = [int(t) for t in row[:v]]
            # matched drafts ARE the emitted tokens; the correction
            # token (if emitted) differs from its draft by construction
            self.spec_accepted += sum(
                1 for j in range(min(v, K))
                if emitted[j] == int(tokens[i, 1 + j]))
            self.spec_emitted += v
            self._spec_row_rounds += 1
            req.out_tokens += emitted
            self.tokens_generated += v
            self.pos[i] += v
            if req.done or self.pos[i] >= max_len - 1:
                req.t_done = t0 + 1
                finished.append((i, req))
                self.policy.on_done(req, t0 + 1)
        self.t = t0 + 1
        return finished

    def _forward_verify(self, tokens: np.ndarray, pos: np.ndarray,
                        budgets: np.ndarray) -> np.ndarray:
        """One fused draft-verify round over the (rows, K+1) chunk
        ``[next input, K drafts]``.  Returns (rows, K+1) int32 emitted
        tokens, -1 in non-emitted slots."""
        raise NotImplementedError  # pragma: no cover - interface

    def step(self, k_cap: Optional[int] = None) -> List[Request]:
        raise NotImplementedError  # pragma: no cover - interface

    def _idle(self) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def _in_flight(self) -> List[Request]:  # pragma: no cover - interface
        raise NotImplementedError

    def run(self, max_steps: Optional[int] = None) -> List[Request]:
        """Drive the engine until drained or ``max_steps`` decode steps
        have executed (macro-steps are clamped to the remaining budget,
        so K > 1 never overshoots it).  Requests still in flight (or
        queued) when the step budget runs out are surfaced in
        :attr:`unfinished` — they keep ``t_done is None`` and still
        hold their rows/blocks, so a further ``run()`` resumes them
        (they are *not* silently dropped)."""
        max_steps = self.MAX_STEPS if max_steps is None else max_steps
        done = []
        t_end = self.t + max_steps
        while self.t < t_end:
            done += self.step(k_cap=t_end - self.t)
            if not self.queue and self._idle():
                break
        self.unfinished = self._in_flight() + list(self.queue)
        return done

    # ------------------------------------------------------------------
    def _reset_row(self, row: int):  # pragma: no cover - interface
        raise NotImplementedError

    def _prefill_row(self, row: int, toks: np.ndarray, pos0: int):
        raise NotImplementedError  # pragma: no cover - interface

    def _forward_steps(self, tokens: np.ndarray, pos: np.ndarray,
                       budgets: np.ndarray, k: int) -> np.ndarray:
        """One fused macro-step of ``k`` device decode iterations.
        Returns (rows, k) int32 token ids (row r valid to budgets[r])."""
        raise NotImplementedError  # pragma: no cover - interface


class _SlotEngine(_EngineBase):
    """Slot state machine: admission (chunked prefill), fused macro-step
    greedy decode, finish bookkeeping.  Forward passes are delegated to
    the subclass hooks:

    * ``_reset_row(slot)`` — clear one cache row before reuse;
    * ``_prefill_row(slot, toks, pos0)`` — process a prompt chunk
      (1, C) at absolute positions pos0.. for one slot;
    * ``_forward_steps(tokens, pos, budgets, k)`` — one fused macro-step
      of k decode iterations for the whole batch, returning (B, k) int32
      token ids (logits never leave the device).
    """

    def __init__(self, cfg, *, max_batch: int, cache_len: int,
                 prefill_chunk: int, decode_steps: int = 1, policy=None,
                 speculative=None):
        super().__init__(cfg, prefill_chunk=prefill_chunk,
                         decode_steps=decode_steps, policy=policy,
                         speculative=speculative)
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.pos = np.zeros(max_batch, dtype=np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _idle(self) -> bool:
        return all(s is None for s in self.slots)

    def _in_flight(self) -> List[Request]:
        return [s for s in self.slots if s is not None]

    def _capacity_view(self, free_slots: int) -> CapacityView:
        """Dense capacity in policy units: one slot = one full
        ``cache_len`` granule (slot admission IS paging with one huge
        block)."""
        return CapacityView(free_tokens=free_slots * self.cache_len,
                            total_tokens=self.max_batch * self.cache_len,
                            granule=self.cache_len,
                            spec_accept=self.spec_accept_mean())

    def _admit(self):
        """Prefill queued requests into free slots: ``prefill_chunk``
        prompt tokens per jitted call (the final prompt token is fed as
        the first decode input in :meth:`step`).  The policy chooses
        *which* queued request is tried next and may reject it up
        front; a deferred choice blocks admission (head-of-line — it is
        never overtaken)."""
        free = self._free_slots()
        while free and self.queue:
            req = self.policy.next_admission(self.queue, self.t)
            if req is None:
                break
            # admission must leave max_new_tokens of cache headroom: the
            # decode loop stops a slot at pos >= cache_len - 1, so a
            # prompt of exactly cache_len would otherwise finish after a
            # SINGLE decode step, silently truncating the request
            if len(req.prompt) + req.max_new_tokens > self.cache_len:
                self.queue.remove(req)
                self._reject(
                    req, f"prompt of {len(req.prompt)} + max_new_tokens "
                         f"{req.max_new_tokens} exceeds cache_len "
                         f"{self.cache_len}")
                continue
            verdict, msg = self.policy.admission_test(
                req, self.t, self._capacity_view(len(free)))
            if verdict == REJECT:
                self.queue.remove(req)
                self._reject(req, msg or "rejected by admission test")
                continue
            if verdict == DEFER:
                break
            slot = free.pop(0)
            self.queue.remove(req)
            if req.t_admit is None:
                req.t_admit = self.t
            self.slots[slot] = req
            self._reset_row(slot)
            toks = req.prompt[:-1]
            self._prefill_chunks(slot, toks)
            self.pos[slot] = len(toks)

    # ------------------------------------------------------------------
    def step(self, k_cap: Optional[int] = None) -> List[Request]:
        """One engine iteration: admit + one fused macro-step of up to
        ``decode_k`` batched decode iterations (``k_cap`` further bounds
        the device steps — the run loop's remaining budget).  Returns
        finished requests."""
        t0 = self.t
        self.t += 1  # admission/rejection stamps land on the first step
        self.policy.on_step(self.t, self.queue, self._in_flight())
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        # a speculative round emits up to K+1 tokens per row in ONE
        # device step, so its budget is token-denominated (drafts + the
        # correction/bonus token), not scan-step-denominated
        k = (self.spec.k + 1 if self.spec is not None
             else self.decode_k if k_cap is None
             else max(1, min(self.decode_k, k_cap)))
        # per-row step budget: never decode past max_new_tokens or the
        # cache-headroom stop (pos >= cache_len - 1) inside the scan
        budgets = np.zeros(self.max_batch, dtype=np.int32)
        for i in active:
            req = self.slots[i]
            budgets[i] = max(1, min(
                k, req.max_new_tokens - len(req.out_tokens),
                self.cache_len - 1 - int(self.pos[i])))
        if self.spec is not None:
            finished = self._spec_tail(self.slots, budgets, active,
                                       self.cache_len, t0)
        else:
            finished = self._macro_tail(self.slots, budgets, active,
                                        self.cache_len, t0, k_cap=k_cap)
        done = []
        for i, req in finished:
            self.slots[i] = None
            self.policy.on_free(1, self.t)  # one slot granule returned
            done.append(req)
        return done


class _PagedEngine(_EngineBase):
    """Continuous-batching scheduler over a paged KV cache.

    The serving-side analogue of the paper's light-service online
    controller (SERVING.md maps the correspondence): instead of
    admitting work only when a whole dense slot frees, every scheduler
    step greedily admits queued requests while the block pool has
    room (token-level admission), grows running requests block-by-
    block, and resolves pool exhaustion by preempting the most
    recently admitted request (recompute on re-admission keeps greedy
    outputs token-identical).

    Decode rows (``max_rows``) bound *batch width* only; memory is
    bounded by the block pool, so with mixed-length requests the same
    cache memory sustains far more concurrent sequences than the dense
    engines (benchmarks/paged_bench.py measures this).

    Subclasses supply ``_reset_row`` / ``_prefill_row`` / ``_forward``
    (same contract as :class:`_SlotEngine`, with rows instead of
    slots).
    """

    MAX_STEPS = 4096  # preemption churn can stretch a busy run

    def __init__(self, cfg, *, max_rows: int, max_len: int,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 prefill_chunk: int = 16, watermark_blocks: int = 0,
                 decode_steps: int = 1, policy=None,
                 prefix_sharing: bool = True, speculative=None):
        super().__init__(cfg, prefill_chunk=prefill_chunk,
                         decode_steps=decode_steps, policy=policy,
                         speculative=speculative)
        self.max_rows = max_rows
        self.max_len = max_len
        # prefix sharing defaults on: with no overlapping full-block
        # prefixes in flight it is a no-op (token streams are pinned
        # identical either way — tests/test_paged.py's ON-vs-OFF sweep),
        # and the ledger auto-gates it off on SWA/SSM/cross archs
        self.pc = PagedCache(cfg, max_rows=max_rows, max_len=max_len,
                             block_size=block_size, num_blocks=num_blocks,
                             watermark_blocks=watermark_blocks,
                             share_prefixes=prefix_sharing)
        self.pos = np.zeros(max_rows, dtype=np.int32)
        self.rows: List[Optional[Request]] = [None] * max_rows
        self._admit_order: List[int] = []   # rows, oldest admission first
        self.n_preemptions = 0

    def _free_rows(self) -> List[int]:
        return [i for i, r in enumerate(self.rows) if r is None]

    def _idle(self) -> bool:
        return all(r is None for r in self.rows)

    def _in_flight(self) -> List[Request]:
        return [r for r in self.rows if r is not None]

    def _capacity_view(self) -> CapacityView:
        """Block-pool capacity in policy units (the watermark reserve is
        the ledger's own business — ``can_admit`` still arbitrates the
        final allocation)."""
        bs = self.pc.block_size
        return CapacityView(free_tokens=self.pc.free_blocks * bs,
                            total_tokens=self.pc.num_blocks * bs,
                            granule=bs,
                            shared_blocks=self.pc.probe_hit,
                            spec_accept=self.spec_accept_mean())

    def _admit(self):
        """Token-level admission: the policy's choice admits whenever a
        decode row is free and the pool holds its blocks (prompt +
        already-decoded prefix after a preemption).  Head-of-line order
        is kept — a blocked or deferred choice waits rather than being
        overtaken, so admission order (and with it preemption
        priority) is deterministic.  A policy admission test may
        instead *reject* the choice up front (effective-capacity test:
        the pool cannot free its deficit within the class's TTFT slack
        — ``_reject`` path, class-specific error)."""
        free = self._free_rows()
        while free and self.queue:
            req = self.policy.next_admission(self.queue, self.t)
            if req is None:
                break
            if (len(req.prompt) + req.max_new_tokens > self.max_len
                    or not self.pc.fits(
                        len(req.prompt) + req.max_new_tokens)):
                self.queue.remove(req)
                self._reject(
                    req, f"prompt of {len(req.prompt)} + max_new_tokens "
                         f"{req.max_new_tokens} exceeds capacity "
                         f"(max_len {self.max_len}, "
                         f"{self.pc.num_blocks} blocks)")
                continue
            verdict, msg = self.policy.admission_test(
                req, self.t, self._capacity_view())
            if verdict == REJECT:
                self.queue.remove(req)
                self._reject(req, msg or "rejected by admission test")
                continue
            total = len(req.prompt) + len(req.out_tokens)
            toks = (req.prompt + req.out_tokens)[:-1]
            wm = (None if any(r is not None for r in self.rows) else 0)
            if verdict == DEFER or not self.pc.can_admit(total,
                                                         watermark=wm,
                                                         tokens=toks):
                break
            self.queue.remove(req)
            row = free.pop(0)
            if not self.pc.admit(row, total, watermark=wm, tokens=toks):
                # can_admit above said yes; a refusal here is a ledger
                # bug and must not be silently skipped (nor live in an
                # assert — ``python -O`` would strip the allocation)
                raise RuntimeError(
                    f"ledger refused admission it just approved "
                    f"(row {row}, {total} tokens)")
            if req.t_admit is None:
                req.t_admit = self.t
            self.rows[row] = req
            self._admit_order.append(row)
            self._reset_row(row)
            # a prefix hit maps the matched span's blocks into the
            # table already filled — prefill only the tail beyond it
            # (hit == len(toks) skips prefill entirely: decode input is
            # the last token itself, not a prefill output)
            hit = self.pc.hit_tokens(row)
            self._prefill_chunks(row, toks[hit:], pos0=hit)
            self.pos[row] = len(toks)

    def _preempt(self, row: int):
        """Preempt-by-recompute: free the row's blocks and put the
        request back at the head of the queue carrying its generated
        prefix; re-admission re-prefills prompt+prefix, and greedy
        decode continues token-identically.  A request bounced
        ``policy.max_preemptions`` times is *evicted* to
        ``engine.rejected`` instead of requeued — bounding recompute
        churn (and the ``n_preempted`` property invariant,
        tests/test_scheduler_props.py)."""
        req = self.rows[row]
        self.pc.release(row)
        self.rows[row] = None
        self._admit_order.remove(row)
        self.n_preemptions += 1
        req.n_preempted += 1
        cap = self.policy.max_preemptions
        if cap is not None and req.n_preempted >= cap:
            self._reject(
                req, f"{req.qos}: evicted after {req.n_preempted} "
                     f"preemptions (max_preemptions={cap})")
            return
        self.queue.insert(0, req)
        self.policy.on_preempt(req, self.t)

    def _grow(self, k: int) -> tuple:
        """Block-budgeted macro-step sizing.  For every active row (in
        admission order): guarantee the block its *next* decode token
        writes into, preempting newest-admitted rows on pool exhaustion
        exactly as the per-token scheduler did (oldest rows are served
        first, so the oldest request always makes progress); then grow
        opportunistically — without preempting anyone — up to ``k``
        steps of coverage.  Returns ``(budgets, clip)``: the per-row
        step budgets (a row's in-scan writes [pos, pos + budget) are
        fully covered by blocks it owns, so the scan never needs the
        ledger) and the smallest *block-clipped* budget (None if no row
        was clipped) — the macro-step must not run longer than that,
        because a clipped row has to resume and a masked scan step
        would advance its SSM state (see :meth:`_macro_tail`)."""
        budgets = np.zeros(self.max_rows, dtype=np.int32)
        clip: Optional[int] = None
        for row in list(self._admit_order):
            req = self.rows[row]
            if req is None:
                continue
            pos = int(self.pos[row])
            while not self.pc.ensure(row, pos):
                # victim choice is the policy's (FIFO: newest admission,
                # the historical LIFO; EDF: most slack, TTFT-protected
                # rows exempt).  ``None`` — every candidate protected —
                # falls back to the needy row preempting itself, the
                # same terminating self-preempt the LIFO rule had when
                # the needy row was the newest.
                cands = [(r, self.rows[r]) for r in self._admit_order
                         if self.rows[r] is not None]
                victim = self.policy.select_victim(cands, self.t,
                                                   needy=row)
                if victim is None:
                    victim = row
                self._preempt(victim)
                if victim == row:
                    break
            if self.rows[row] is None:  # preempted itself
                continue
            want = max(1, min(k, req.max_new_tokens - len(req.out_tokens),
                              self.max_len - 1 - pos))
            steps = 1
            while steps < want and self.pc.ensure(row, pos + steps):
                steps += 1
            if steps < want:  # pool-limited: this row must resume
                clip = steps if clip is None else min(clip, steps)
            budgets[row] = steps
        return budgets, clip

    # ------------------------------------------------------------------
    def step(self, k_cap: Optional[int] = None) -> List[Request]:
        """One scheduler iteration: admit + grow/preempt + one fused
        macro-step of up to ``decode_k`` decode iterations (``k_cap``
        further bounds the device steps — the run loop's remaining
        budget).  Returns finished requests."""
        t0 = self.t
        self.t += 1  # admission/rejection stamps land on the first step
        self.policy.on_step(self.t, self.queue, self._in_flight())
        self._admit()
        # a speculative round's budget is token-denominated (see
        # _SlotEngine.step): _grow covers up to K+1 writes per row
        k = (self.spec.k + 1 if self.spec is not None
             else self.decode_k if k_cap is None
             else max(1, min(self.decode_k, k_cap)))
        budgets, clip = self._grow(k)
        # any copy-on-write the ledger queued (a row about to write a
        # still-shared block) must hit the device pools before the scan
        # reads or writes the fresh copies
        pairs = self.pc.take_pending_copies()
        if pairs:
            self._apply_cow(pairs)
        active = [i for i, r in enumerate(self.rows) if r is not None]
        if not active:
            return []
        if self.spec is not None:
            # clip needs no special handling: emission clamps to the
            # block-covered budget, the verify chunk's writes beyond it
            # land in the scratch block (never read below the accepted
            # length), and the SSM-resume hazard clip guards against
            # cannot occur — speculation is gated to pure-attention archs
            finished = self._spec_tail(self.rows, budgets, active,
                                       self.max_len, t0)
        else:
            caps = [c for c in (clip, k_cap) if c is not None]
            cap = min(caps) if caps else None
            finished = self._macro_tail(self.rows, budgets, active,
                                        self.max_len, t0, k_cap=cap)
        done = []
        for i, req in finished:
            self.rows[i] = None
            self._admit_order.remove(i)
            fb0 = self.pc.free_blocks
            self.pc.release(i)
            # completion releases feed the EC policy's service model
            # (preemption frees are churn, not service — not counted)
            self.policy.on_free(self.pc.free_blocks - fb0, self.t)
            done.append(req)
        return done

    def _apply_cow(self, pairs: List[tuple]):
        """Apply queued COW pool copies ``[(src, dst), ...]`` to the
        device-side caches.  The base implementation is a host no-op —
        enough for ledgers without device pools (``FakeEngine``'s
        integer recurrence keeps no per-position state); real engines
        override with a jitted :func:`paged_copy_blocks`."""

    @property
    def active_rows(self) -> int:
        return sum(1 for r in self.rows if r is not None)


class ServingEngine(_SlotEngine):
    """Monolithic engine: one jitted macro-step/prefill over the full
    model.  All cache-carrying jits donate the cache argument (the
    engine rebinds ``self.caches`` to the output every call)."""

    def __init__(self, cfg, params=None, *, max_batch: int = 4,
                 cache_len: int = 128, seed: int = 0,
                 prefill_chunk: int = 16, decode_steps: int = 1,
                 policy=None, speculative=None, quantization=None):
        super().__init__(cfg, max_batch=max_batch, cache_len=cache_len,
                         prefill_chunk=prefill_chunk,
                         decode_steps=decode_steps, policy=policy,
                         speculative=speculative)
        self.model = build_model(cfg, qformat=quantization)
        self.quantization = self.model.qformat
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        # pack projection weights once at construction; the packed
        # leaves enter every jit as static-shaped non-donated operands
        # (weights are not linear state — only caches donate), so no
        # recompile churn and the donation contract is untouched
        self.params = quantize_params(self.params, self.quantization)
        self.caches = self.model.init_cache(max_batch, cache_len)
        self._jits["prefill"] = jax.jit(self.model.prefill_chunk,
                                        donate_argnums=(1,))
        self._jits["reset"] = jax.jit(reset_cache_row, donate_argnums=(0,))

    def _reset_row(self, slot: int):
        self.caches = self._jits["reset"](self.caches, jnp.int32(slot))

    def _prefill_row(self, slot: int, toks: np.ndarray, pos0: int):
        _, self.caches = self._jits["prefill"](
            self.params, self.caches, jnp.asarray(toks[None]),
            jnp.int32(pos0), jnp.int32(slot))

    def _forward_steps(self, tokens: np.ndarray, pos: np.ndarray,
                       budgets: np.ndarray, k: int) -> np.ndarray:
        toks, self.caches = self._decode_jit(k)(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos),
             "budget": jnp.asarray(budgets)})
        # reprolint: disable-next=host-sync -- the ONE deliberate sync
        # per macro-step (counted in n_host_syncs; <= 1/K per token)
        return np.asarray(toks)

    def _forward_verify(self, tokens: np.ndarray, pos: np.ndarray,
                        budgets: np.ndarray) -> np.ndarray:
        emit, self.caches = self._verify_jit(tokens.shape[1])(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos),
             "budget": jnp.asarray(budgets)})
        # reprolint: disable-next=host-sync -- the ONE deliberate sync
        # per verify round (counted in n_host_syncs; <= 1 per token)
        return np.asarray(emit)


class PagedServingEngine(_PagedEngine):
    """Monolithic paged engine: the continuous scheduler over one
    jitted paged macro-step/prefill (``Model.decode_steps`` with block
    tables / ``paged_prefill_chunk``).  Greedy outputs are
    token-identical to :class:`ServingEngine` at equal
    ``max_len``/``cache_len`` for every ``decode_steps``
    (tests/test_paged.py).  Block tables ride device-side through
    ``PagedCache.meta``'s incremental snapshot — re-uploaded only when
    the ledger changed."""

    def __init__(self, cfg, params=None, *, max_rows: int = 8,
                 max_len: int = 128, block_size: int = 16,
                 num_blocks: Optional[int] = None, seed: int = 0,
                 prefill_chunk: int = 16, watermark_blocks: int = 0,
                 decode_steps: int = 1, policy=None,
                 prefix_sharing: bool = True, speculative=None,
                 quantization=None):
        super().__init__(cfg, max_rows=max_rows, max_len=max_len,
                         block_size=block_size, num_blocks=num_blocks,
                         prefill_chunk=prefill_chunk,
                         watermark_blocks=watermark_blocks,
                         decode_steps=decode_steps, policy=policy,
                         prefix_sharing=prefix_sharing,
                         speculative=speculative)
        self.model = build_model(cfg, qformat=quantization)
        self.quantization = self.model.qformat
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        # packed at construction; static non-donated jit operands (see
        # ServingEngine — same contract, reprolint quant-static-weights)
        self.params = quantize_params(self.params, self.quantization)
        self.caches = self.pc.struct(self.model.dtype)
        self._jits["prefill"] = jax.jit(self.model.paged_prefill_chunk,
                                        donate_argnums=(1,))
        segs = self.model.segments
        self._jits["reset"] = jax.jit(
            lambda caches, row, xids: paged_reset_row(caches, segs, row,
                                                      xids),
            donate_argnums=(0,))
        has_swa = self.pc.has_swa
        self._jits["cow"] = jax.jit(
            lambda caches, src, dst: paged_copy_blocks(
                caches, segs, src, dst, has_swa=has_swa),
            donate_argnums=(0,))

    def _apply_cow(self, pairs):
        src = jnp.asarray([s for s, _ in pairs], jnp.int32)
        dst = jnp.asarray([d for _, d in pairs], jnp.int32)
        self.caches = self._jits["cow"](self.caches, src, dst)

    def _reset_row(self, row: int):
        xids = jnp.asarray(self.pc.cross_tables[row].copy())
        self.caches = self._jits["reset"](self.caches, jnp.int32(row), xids)

    def _prefill_row(self, row: int, toks: np.ndarray, pos0: int):
        _, self.caches = self._jits["prefill"](
            self.params, self.caches, jnp.asarray(toks[None]),
            jnp.int32(pos0), jnp.int32(row), self.pc.meta(row=row))

    def _forward_steps(self, tokens: np.ndarray, pos: np.ndarray,
                       budgets: np.ndarray, k: int) -> np.ndarray:
        toks, self.caches = self._decode_jit(k)(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos),
             "budget": jnp.asarray(budgets)},
            self.pc.meta())
        # reprolint: disable-next=host-sync -- the ONE deliberate sync
        # per macro-step (counted in n_host_syncs; <= 1/K per token)
        return np.asarray(toks)

    def _forward_verify(self, tokens: np.ndarray, pos: np.ndarray,
                        budgets: np.ndarray) -> np.ndarray:
        emit, self.caches = self._verify_jit(tokens.shape[1])(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos),
             "budget": jnp.asarray(budgets)},
            self.pc.meta())
        # reprolint: disable-next=host-sync -- the ONE deliberate sync
        # per verify round (counted in n_host_syncs; <= 1 per token)
        return np.asarray(emit)
