"""Continuous-batching serving engine (slot-based, vLLM-style simplified).

Fixed-size decode batch with per-slot KV caches; prefill admits new
requests into free slots (their prompt KVs are written at the right
positions), then all active slots decode together.  Greedy or top-k
sampling on the logical (un-padded) vocab.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclass
class Request:
    id: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    t_admit: float = 0.0
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class ServingEngine:
    def __init__(self, cfg, params=None, *, max_batch: int = 4,
                 cache_len: int = 128, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_batch = max_batch
        self.cache_len = cache_len
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.caches = self.model.init_cache(max_batch, cache_len)
        self.pos = np.zeros(max_batch, dtype=np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self._decode = jax.jit(self.model.decode_step)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self):
        """Prefill queued requests into free slots, token by token via
        decode_step (prompt processing; keeps one compiled program)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            self.slots[slot] = req
            self.pos[slot] = 0
            for t in req.prompt[:-1]:
                self._step_one(slot, t)
            self._last_token = {slot: req.prompt[-1]}

    def _step_one(self, slot: int, token: int):
        tok = jnp.zeros((self.max_batch, 1), jnp.int32
                        ).at[slot, 0].set(token)
        # jnp.asarray aliases numpy buffers on CPU and the jitted decode
        # dispatches asynchronously, so hand it a snapshot: mutating
        # self.pos below must not race the pending computation
        pos = jnp.asarray(self.pos.copy())
        _, self.caches = self._decode(self.params, self.caches,
                                      {"token": tok, "pos": pos})
        self.pos[slot] += 1

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """One engine iteration: admit + batched decode.  Returns
        finished requests."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        tokens = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i in active:
            req = self.slots[i]
            last = (req.prompt[-1] if not req.out_tokens
                    else req.out_tokens[-1])
            tokens[i, 0] = last
        logits, self.caches = self._decode(
            self.params, self.caches,
            {"token": jnp.asarray(tokens),
             "pos": jnp.asarray(self.pos.copy())})  # snapshot, see above
        nxt = np.asarray(
            jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1))[:, 0]
        finished = []
        for i in active:
            req = self.slots[i]
            req.out_tokens.append(int(nxt[i]))
            self.pos[i] += 1
            if req.done or self.pos[i] >= self.cache_len - 1:
                finished.append(req)
                self.slots[i] = None
        return finished

    def run(self, max_steps: int = 512) -> List[Request]:
        done = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return done
