"""Continuous-batching serving engine (slot-based, vLLM-style simplified).

Fixed-size decode batch with per-slot KV caches; prefill admits new
requests into free slots via **chunked batched prefill** — one jitted
call per ``prefill_chunk`` prompt tokens (``prefill_chunk=1`` recovers
token-by-token admission; see benchmarks/pipeline_bench.py for the
wall-clock gap).  Each chunk touches only the admitted slot's cache
row, and the row is zeroed on admission (stale KV is masked by
position, but SSM recurrent/conv state from a slot's previous occupant
is not), so co-batched and successive requests are fully isolated.
After admission all active slots decode together, greedy on the
logical (un-padded) vocab.

:class:`_SlotEngine` holds the slot state machine shared with the
pipeline-parallel executor (serving/pipeline.py); subclasses supply
``_reset_row`` / ``_prefill_row`` / ``_forward``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


def chunk_sizes(n: int, chunk: int) -> List[int]:
    """Split a prefill of n tokens into jit-friendly chunk lengths:
    full ``chunk``-sized pieces, then a power-of-two decomposition of
    the remainder — so at most log2(chunk) distinct program shapes ever
    compile, whatever prompt lengths arrive."""
    out = [chunk] * (n // chunk)
    rem, bit = n % chunk, 1
    tail: List[int] = []
    while rem:
        if rem & 1:
            tail.append(bit)
        bit <<= 1
        rem >>= 1
    return out + tail[::-1]


def reset_cache_row(caches, slot):
    """Zero batch row ``slot`` of a cache pytree (leaves are
    (n_layers, batch, ...)).  Jit this once per engine."""
    return jax.tree.map(lambda a: a.at[:, slot].set(0), caches)


@dataclass
class Request:
    id: int
    prompt: List[int]
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    t_admit: float = 0.0
    t_done: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class _SlotEngine:
    """Slot state machine: admission (chunked prefill), batched greedy
    decode, finish bookkeeping.  Forward passes are delegated to the
    subclass hooks:

    * ``_reset_row(slot)`` — clear one cache row before reuse;
    * ``_prefill_row(slot, toks, pos0)`` — process a prompt chunk
      (1, C) at absolute positions pos0.. for one slot;
    * ``_forward(tokens, pos, n_active)`` — one decode step for the
      whole batch, returning logits (B, 1, V_padded).
    """

    def __init__(self, cfg, *, max_batch: int, cache_len: int,
                 prefill_chunk: int):
        self.cfg = cfg
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.prefill_chunk = max(1, prefill_chunk)
        self.pos = np.zeros(max_batch, dtype=np.int32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.tokens_generated = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self):
        """Prefill queued requests into free slots: ``prefill_chunk``
        prompt tokens per jitted call (the final prompt token is fed as
        the first decode input in :meth:`step`)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            # admission must leave max_new_tokens of cache headroom: the
            # decode loop stops a slot at pos >= cache_len - 1, so a
            # prompt of exactly cache_len used to pass the old
            # prompt-only assert and then finish after a SINGLE decode
            # step, silently truncating the request
            assert len(req.prompt) + req.max_new_tokens <= self.cache_len, \
                (f"prompt of {len(req.prompt)} + max_new_tokens "
                 f"{req.max_new_tokens} exceeds cache_len {self.cache_len}")
            self.slots[slot] = req
            self._reset_row(slot)
            toks = req.prompt[:-1]
            i = 0
            for c in chunk_sizes(len(toks), self.prefill_chunk):
                self._prefill_row(
                    slot, np.asarray(toks[i:i + c], dtype=np.int32), i)
                i += c
            self.pos[slot] = len(toks)

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """One engine iteration: admit + batched decode.  Returns
        finished requests."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return []
        tokens = np.zeros((self.max_batch, 1), dtype=np.int32)
        for i in active:
            req = self.slots[i]
            tokens[i, 0] = (req.prompt[-1] if not req.out_tokens
                            else req.out_tokens[-1])
        # self.pos is snapshotted before handing to jax: jnp.asarray
        # aliases numpy buffers on CPU and the jitted forward dispatches
        # asynchronously, so the += below must not race it
        logits = self._forward(tokens, self.pos.copy(), len(active))
        nxt = np.asarray(
            jnp.argmax(logits[..., :self.cfg.vocab_size], axis=-1))[:, 0]
        finished = []
        for i in active:
            req = self.slots[i]
            req.out_tokens.append(int(nxt[i]))
            self.tokens_generated += 1
            self.pos[i] += 1
            if req.done or self.pos[i] >= self.cache_len - 1:
                finished.append(req)
                self.slots[i] = None
        return finished

    def run(self, max_steps: int = 512) -> List[Request]:
        done = []
        for _ in range(max_steps):
            done += self.step()
            if not self.queue and all(s is None for s in self.slots):
                break
        return done

    # ------------------------------------------------------------------
    def _reset_row(self, slot: int):  # pragma: no cover - interface
        raise NotImplementedError

    def _prefill_row(self, slot: int, toks: np.ndarray, pos0: int):
        raise NotImplementedError  # pragma: no cover - interface

    def _forward(self, tokens: np.ndarray, pos: np.ndarray,
                 n_active: int):
        raise NotImplementedError  # pragma: no cover - interface


class ServingEngine(_SlotEngine):
    """Monolithic engine: one jitted decode/prefill over the full model."""

    def __init__(self, cfg, params=None, *, max_batch: int = 4,
                 cache_len: int = 128, seed: int = 0,
                 prefill_chunk: int = 16):
        super().__init__(cfg, max_batch=max_batch, cache_len=cache_len,
                         prefill_chunk=prefill_chunk)
        self.model = build_model(cfg)
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.caches = self.model.init_cache(max_batch, cache_len)
        self._decode = jax.jit(self.model.decode_step)
        self._prefill = jax.jit(self.model.prefill_chunk)
        self._reset = jax.jit(reset_cache_row)

    def _reset_row(self, slot: int):
        self.caches = self._reset(self.caches, jnp.int32(slot))

    def _prefill_row(self, slot: int, toks: np.ndarray, pos0: int):
        _, self.caches = self._prefill(
            self.params, self.caches, jnp.asarray(toks[None]),
            jnp.int32(pos0), jnp.int32(slot))

    def _forward(self, tokens: np.ndarray, pos: np.ndarray,
                 n_active: int):
        logits, self.caches = self._decode(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos)})
        return logits
