"""Pipeline-parallel microservice serving executors (dense + paged).

`microservice.partition.decompose` turns a model into light services
plus N core stages over contiguous layer ranges; until now those specs
only fed the *planning* side (static IP + Lyapunov controller) while
``ServingEngine`` executed every model monolithically.  This module
closes the profile→place→execute loop:

  1. each core stage becomes a sub-executor owning **only** its layer
     range's parameter slice and cache slice
     (:meth:`repro.models.model.Model.stage_params` /
     ``init_cache(layers=...)`` — or, for the paged executor, the layer
     range's slice of the shared block pools,
     :meth:`repro.models.kvcache.PagedCache.struct`);
  2. activations hand off between stages through a network shim whose
     per-hop latency/bandwidth comes from a ``core.network.EdgeNetwork``
     and a stage→node placement — a ``static_placement`` solution
     directly determines where each stage "runs" and what transfer cost
     it pays;
  3. measured per-stage latencies (:meth:`PipelinedEngine.profile`)
     feed back into ``partition.to_application``, so the placement is
     re-derived from the *executed* pipeline, not FLOP estimates.

Stage compute is real (jitted JAX, token-identical to the monolithic
engine — composition of ``run_stages`` over consecutive ranges
reproduces the forward op-for-op); the network is simulated (hop delays
are accounted, not slept).  Chunked prefill and profiling run one
jitted program per stage; the decode hot loop chains every stage inside
one fused, donated macro-step scan (``_NetShimMixin._macro_jit``,
SERVING.md §The decode hot loop) while the per-hop accounting stays
per device step.  Light services are accounted at fixed homes:
tokenize/detokenize at the entry node, sample co-located with the exit
stage.

Cache layout invariants: every stage's cache slice is indexed by the
same request identity — dense engines by batch slot (each stage holds
that slot's rows for its layers), paged engines by the *engine-level*
block tables (one :class:`~repro.models.kvcache.PagedCache` ledger
governs every stage's pools, so block id ``b`` addresses the same
logical tokens in each stage's layer slice).  Admission zeroes the
request's SSM state rows and cross blocks in **every** stage.

Enc-dec configs: the ``encoder`` core stage is planning-only here, as in
``ServingEngine`` (token requests carry no frontend; decoder cross-attn
reads the zero-initialised cache), so the executor chains decoder
stages only.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import static_placement as sp
from repro.core.network import resource_index
from repro.core.qos import qos_scores
from repro.microservice.partition import (StageSpec, decompose,
                                          profile_stage_ms, to_application)
from repro.models import build_model, bytes_per_param, quantize_params
from repro.models.kvcache import (PagedCache, paged_copy_blocks,
                                  paged_reset_row)
from repro.models.model import (greedy_scan_update, greedy_verify_update,
                                row_isolated, ssm_row_isolated)
from repro.models.transformer import segment_range
from repro.serving.engine import (_PagedEngine, _SlotEngine,
                                  reset_cache_row)

PLACEMENT_STRATEGIES = ("static_ip", "colocate", "round_robin", "random")


def place_stages(app, net, strategy: str = "static_ip", *, kappa: int = 2,
                 xi: float = sp.XI_DEFAULT, horizon_slots: int = 100,
                 rng: Optional[np.random.Generator] = None,
                 bytes_per_param: Optional[float] = None
                 ) -> Dict[str, int]:
    """Map each core service of ``app`` to a network node.

    ``static_ip`` solves the paper's sparsity-constrained integer
    program (eq. 14, C4–C6) over QoS scores and picks each stage's
    most-instantiated site; the rest are baselines for the bench.
    """
    core = app.core_ids
    es = [int(v) for v in np.flatnonzero(net.is_es)]
    es = es or list(range(net.n_nodes))
    if strategy == "static_ip":
        z, q = qos_scores(app, net)
        prob = sp.build_problem(app, net, z, q, kappa=kappa, xi=xi,
                                horizon_slots=horizon_slots,
                                bytes_per_param=bytes_per_param)
        x = sp.solve(prob)
        return {app.ms(m).name: (int(np.argmax(x[m])) if x[m].sum() > 0
                                 else es[0]) for m in core}
    if strategy == "colocate":
        # fattest GPU among ESs — by the named resource column, falling
        # back to total capacity when R is narrower than Table I's
        # [CPU, RAM, GPU, VRAM] layout
        gpu = resource_index("gpu")
        if net.R.shape[1] > gpu:
            score = net.R[es, gpu]
        else:
            score = net.R[es].sum(axis=1)
        v = es[int(np.argmax(score))]
        return {app.ms(m).name: v for m in core}
    if strategy == "round_robin":
        return {app.ms(m).name: es[i % len(es)] for i, m in enumerate(core)}
    if strategy == "random":
        rng = rng if rng is not None else np.random.default_rng(0)
        return {app.ms(m).name: int(rng.choice(es)) for m in core}
    raise ValueError(f"unknown placement strategy {strategy!r}; "
                     f"known: {PLACEMENT_STRATEGIES}")


class _CoreStage:
    """One sub-executor: layers [lo, hi), its param/cache slices, and
    jitted chunked-prefill / row-reset / per-stage decode programs.

    With ``paged`` set (a :class:`~repro.models.kvcache.PagedCache`),
    the stage's caches are its layer slice of the shared block pools
    and every jitted program takes the engine's block-table metadata.

    The prefill/reset jits donate their cache argument (the stage
    rebinds ``self.caches`` each call).  The per-stage ``decode`` jit is
    the *profiling* program (``PipelinedEngine.profile`` measures one
    stage at a time) and deliberately does NOT donate — profiling must
    not consume the live serving caches.  The serving decode path runs
    through the engine's fused macro-step instead
    (``_NetShimMixin._macro_jit``), which chains every stage inside one
    scan and donates the whole cache list.
    """

    def __init__(self, model, params, spec: StageSpec, *, entry: bool,
                 exit_head: bool, max_batch: int, cache_len: int,
                 paged: Optional[PagedCache] = None):
        self.spec = spec
        self.name = spec.name
        self.lo, self.hi = spec.layer_range
        self.node: int = 0
        self.paged = paged
        self.params = model.stage_params(params, self.lo, self.hi,
                                         entry=entry, exit_head=exit_head)
        # admission discards prompt logits, so prefill skips the head
        self.prefill_params = {k: v for k, v in self.params.items()
                               if k not in ("lm_head", "final_norm")}
        lo, hi = self.lo, self.hi
        segs = segment_range(model.cfg, lo, hi)

        self._jits = {}
        if paged is None:
            self.caches = model.init_cache(max_batch, cache_len,
                                           layers=(lo, hi))

            def _decode(p, caches, x, pos):
                y, new_caches, _ = model.run_stages(
                    p, x, lo, hi, mode="decode", pos=pos, caches=caches)
                return y, new_caches

            def _prefill(p, caches, x, pos0, slot):
                def run(row):
                    y, new_row, _ = model.run_stages(
                        p, x, lo, hi, mode="chunk",
                        pos=jnp.reshape(pos0, (1,)).astype(jnp.int32),
                        caches=row)
                    return y, new_row
                return row_isolated(run, caches, slot)

            self._jits["reset"] = jax.jit(reset_cache_row,
                                          donate_argnums=(0,))
        else:
            self.caches = paged.struct(model.dtype, layers=(lo, hi))

            def _decode(p, caches, x, pos, pmeta):
                y, new_caches, _ = model.run_stages(
                    p, x, lo, hi, mode="decode", pos=pos, caches=caches,
                    paged=pmeta)
                return y, new_caches

            def _prefill(p, caches, x, pos0, row, pmeta):
                def run(c):
                    y, new_c, _ = model.run_stages(
                        p, x, lo, hi, mode="chunk",
                        pos=jnp.reshape(pos0, (1,)).astype(jnp.int32),
                        caches=c, paged=pmeta)
                    return y, new_c
                return ssm_row_isolated(run, segs, caches, row)

            self._jits["reset"] = jax.jit(
                lambda caches, row, xids: paged_reset_row(caches, segs,
                                                          row, xids),
                donate_argnums=(0,))
            has_swa = paged.has_swa
            self._jits["cow"] = jax.jit(
                lambda caches, src, dst: paged_copy_blocks(
                    caches, segs, src, dst, has_swa=has_swa),
                donate_argnums=(0,))

        # reprolint: disable-next=jit-donation -- profile-only jit:
        # profile() must not consume the live serving caches (PR 5)
        self._jits["decode"] = jax.jit(_decode)
        self._jits["prefill"] = jax.jit(_prefill, donate_argnums=(1,))

    def prefill(self, x, pos0, slot, pmeta=None):
        args = (() if self.paged is None else (pmeta,))
        x, self.caches = self._jits["prefill"](
            self.prefill_params, self.caches, x, pos0, slot, *args)
        return x

    def reset_row(self, slot, xids=None):
        args = (() if self.paged is None else (xids,))
        self.caches = self._jits["reset"](self.caches, slot, *args)

    def copy_blocks(self, src, dst):
        """COW pool copies on this stage's layer slice of the pools."""
        self.caches = self._jits["cow"](self.caches, src, dst)


class _NetShimMixin:
    """Placement, profiling, and simulated-network accounting shared by
    the dense and paged pipelined engines (the profile→place→execute
    loop).  Simulated-network stats accumulate in :attr:`transfer_ms` /
    :attr:`transfer_mb` / :attr:`hops` (keyed ``(src_node, dst_node)``).
    """

    def _init_stages_and_net(self, cfg, params, *, n_stages, max_batch,
                             cache_len, seed, net, placement, entry_node,
                             paged: Optional[PagedCache] = None,
                             quantization=None):
        assert 1 <= n_stages <= cfg.n_layers, (n_stages, cfg.n_layers)
        self.model = build_model(cfg, qformat=quantization)
        self.quantization = self.model.qformat
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        # pack projection weights BEFORE stage construction so every
        # stage's slice_blocks slice carries the packed leaves; static
        # non-donated jit operands, same contract as the monolithic
        # engines (reprolint quant-static-weights)
        self.params = quantize_params(self.params, self.quantization)
        self.batch_width = max_batch

        # stage service sizes reflect the *resident* weight format, so
        # profile->place->execute sees the quantized footprint
        self.stage_specs: List[StageSpec] = decompose(
            cfg, n_core_stages=n_stages,
            bytes_per_param=bytes_per_param(self.quantization))
        decoder = [s for s in self.stage_specs
                   if s.kind == "core" and s.name != "encoder"]
        self.stages = [
            _CoreStage(self.model, self.params, spec,
                       entry=(i == 0), exit_head=(i == len(decoder) - 1),
                       max_batch=max_batch, cache_len=cache_len,
                       paged=paged)
            for i, spec in enumerate(decoder)]

        self.net = net
        self.entry_node = (entry_node if entry_node is not None
                           else (int(net.user_ed[0]) if net is not None
                                 else 0))
        if placement:
            self.set_placement(placement)
        self._act_bytes = jnp.dtype(cfg.dtype).itemsize * cfg.d_model
        self.transfer_ms = 0.0
        self.transfer_mb = 0.0
        self.hops: Dict[tuple, dict] = {}

    # ------------------------------------------------------------------
    # placement / profiling (the profile→place→execute loop)
    # ------------------------------------------------------------------
    def set_placement(self, placement: Dict[str, int]):
        """Pin each stage to a node (unnamed stages keep their node)."""
        for st in self.stages:
            if st.name in placement:
                st.node = int(placement[st.name])

    @property
    def placement(self) -> Dict[str, int]:
        return {st.name: st.node for st in self.stages}

    def profile(self, iters: int = 3) -> Dict[str, float]:
        """Measured per-stage decode latency (ms) via
        ``partition.profile_stage_ms`` — feed to :meth:`to_application`.
        Uses the per-stage (non-donating) decode jits, so profiling
        leaves the live serving caches untouched."""
        out = {}
        pos = jnp.zeros((self.batch_width,), jnp.int32)
        meta = self.pc.meta() if hasattr(self, "pc") else None
        for i, st in enumerate(self.stages):
            if i == 0:
                x = jnp.zeros((self.batch_width, 1), jnp.int32)
            else:
                x = jnp.zeros((self.batch_width, 1, self.cfg.d_model),
                              jnp.dtype(self.cfg.dtype))
            if meta is None:
                fn = (lambda xx=x, ss=st:
                      ss._jits["decode"](ss.params, ss.caches, xx, pos)[0])
            else:
                fn = (lambda xx=x, ss=st:
                      ss._jits["decode"](ss.params, ss.caches, xx, pos,
                                         meta)[0])
            out[st.name] = profile_stage_ms(fn, iters=iters)
        return out

    def to_application(self, rng: np.random.Generator,
                       measured_ms: Optional[Dict[str, float]] = None,
                       **kwargs):
        """Bridge the executed pipeline back to the paper abstraction."""
        return to_application(self.cfg, self.stage_specs, rng,
                              measured_ms=measured_ms, **kwargs)

    # ------------------------------------------------------------------
    # fused macro-step: every stage chained inside one jitted scan
    # ------------------------------------------------------------------
    def _macro_jit(self, k: int):
        """Fused K-step decode across all stages: one ``lax.scan`` whose
        body chains the stage layer ranges (composition reproduces the
        monolithic forward op-for-op), then does argmax / token feedback
        / pos bump / budget masking on device — the pipelined analogue
        of ``Model.decode_steps``.  The per-stage cache list is the scan
        carry and is donated; the per-hop *network* accounting stays on
        the host (:meth:`_account_macro`), priced per device step as
        before — fusing the stages into one program changes where the
        Python process computes, not what the simulated network ships.
        """
        key = f"decode{k}"
        if key not in self._jits:
            model = self.model
            ranges = [(st.lo, st.hi) for st in self.stages]
            vocab = self.cfg.vocab_size

            def run(params_list, caches_list, tok, pos, budget,
                    pmeta=None):
                def body(carry, _):
                    caches_list, tok, pos, budget = carry
                    x = tok
                    new_list = []
                    for p, c, (lo, hi) in zip(params_list, caches_list,
                                              ranges):
                        x, nc, _ = model.run_stages(
                            p, x, lo, hi, mode="decode", pos=pos,
                            caches=c, paged=pmeta)
                        new_list.append(nc)
                    tok, pos, budget, emit = greedy_scan_update(
                        x, pos, budget, vocab)
                    return (new_list, tok, pos, budget), emit

                carry = (caches_list, tok, pos, budget)
                (caches_list, _, _, _), toks = jax.lax.scan(
                    body, carry, None, length=k)
                return jnp.transpose(toks), caches_list

            self._jits[key] = jax.jit(run, donate_argnums=(1,))
        return self._jits[key]

    def _run_macro(self, tokens: np.ndarray, pos: np.ndarray,
                   budgets: np.ndarray, k: int, pmeta=None) -> np.ndarray:
        """Invoke the fused macro-step, rebind every stage's caches
        (they were donated), and account the per-step network hops."""
        params_list = [st.params for st in self.stages]
        caches_list = [st.caches for st in self.stages]
        args = (() if pmeta is None else (pmeta,))
        toks, new_caches = self._macro_jit(k)(
            params_list, caches_list, jnp.asarray(tokens),
            jnp.asarray(pos), jnp.asarray(budgets), *args)
        for st, nc in zip(self.stages, new_caches):
            st.caches = nc
        self._account_macro(budgets, k)
        # reprolint: disable-next=host-sync -- the ONE deliberate sync
        # per macro-step (counted in n_host_syncs; <= 1/K per token)
        return np.asarray(toks)

    def _account_macro(self, budgets: np.ndarray, k: int):
        """Simulated-network accounting for one macro-step: device step
        i ships for the rows still live at that step (budget > i) — the
        same per-token hop pattern the per-token loop produced: token
        ids entry->stage0, activations between stages, the sampled
        token id back to the entry node for detokenize."""
        for i in range(k):
            n = int((budgets > i).sum())
            if n == 0:
                break
            self._ship(self.entry_node, self.stages[0].node, n * 4 / 1e6)
            for kk in range(len(self.stages)):
                self._ship_between(kk, n, self._act_bytes)
            self._ship(self.stages[-1].node, self.entry_node, n * 4 / 1e6)

    # ------------------------------------------------------------------
    # fused draft-verify round: every stage chained inside one jitted
    # chunk forward (the pipelined analogue of ``Model.verify_steps``)
    # ------------------------------------------------------------------
    def _verify_chain_jit(self, s: int):
        """Fused verification of an (B, S) draft chunk across all
        stages: one teacher-forced chunk forward chained through the
        stage layer ranges (composition reproduces the monolithic
        ``verify_steps`` op-for-op), then the greedy accept/emit mask
        on device.  Named ``_verify_chain_jit`` (not ``_verify_jit``)
        because ``_EngineBase._verify_jit`` wins the MRO and routes
        monolithic models — the engines' ``_forward_verify`` below
        calls this chain directly."""
        key = f"verify{s}"
        if key not in self._jits:
            model = self.model
            ranges = [(st.lo, st.hi) for st in self.stages]
            vocab = self.cfg.vocab_size

            def run(params_list, caches_list, tok, pos, budget,
                    pmeta=None):
                x = tok
                new_list = []
                for p, c, (lo, hi) in zip(params_list, caches_list,
                                          ranges):
                    x, nc, _ = model.run_stages(
                        p, x, lo, hi, mode="chunk", pos=pos,
                        caches=c, paged=pmeta)
                    new_list.append(nc)
                emit = greedy_verify_update(x, tok, budget, vocab)
                return emit, new_list

            self._jits[key] = jax.jit(run, donate_argnums=(1,))
        return self._jits[key]

    def _run_verify(self, tokens: np.ndarray, pos: np.ndarray,
                    budgets: np.ndarray, pmeta=None) -> np.ndarray:
        """Invoke the fused verify round, rebind every stage's caches
        (they were donated), and account the per-round network hops."""
        params_list = [st.params for st in self.stages]
        caches_list = [st.caches for st in self.stages]
        args = (() if pmeta is None else (pmeta,))
        emit, new_caches = self._verify_chain_jit(tokens.shape[1])(
            params_list, caches_list, jnp.asarray(tokens),
            jnp.asarray(pos), jnp.asarray(budgets), *args)
        for st, nc in zip(self.stages, new_caches):
            st.caches = nc
        self._account_verify(budgets, tokens.shape[1])
        # reprolint: disable-next=host-sync -- the ONE deliberate sync
        # per verify round (counted in n_host_syncs; <= 1 per token)
        return np.asarray(emit)

    def _account_verify(self, budgets: np.ndarray, s: int):
        """Simulated-network accounting for one verify round: every
        live row ships its whole (K+1)-token chunk at once — draft ids
        entry->stage0, chunk activations between stages, emitted ids
        back for detokenize.  One hop per round instead of one per
        token is the speculative latency win on the wire."""
        n = int((budgets > 0).sum())
        if n == 0:
            return
        self._ship(self.entry_node, self.stages[0].node, n * s * 4 / 1e6)
        for kk in range(len(self.stages)):
            self._ship_between(kk, n * s, self._act_bytes)
        self._ship(self.stages[-1].node, self.entry_node, n * s * 4 / 1e6)

    # ------------------------------------------------------------------
    # network shim
    # ------------------------------------------------------------------
    def _ship(self, src: int, dst: int, mb: float):
        if self.net is None or src == dst or mb <= 0.0:
            return
        ms = self.net.path_ms(src, dst, mb)
        self.transfer_ms += ms
        self.transfer_mb += mb
        agg = self.hops.setdefault((src, dst),
                                   {"count": 0, "mb": 0.0, "ms": 0.0})
        agg["count"] += 1
        agg["mb"] += mb
        agg["ms"] += ms

    def _ship_between(self, k: int, n: int, per_token_bytes: float):
        if k + 1 < len(self.stages):
            self._ship(self.stages[k].node, self.stages[k + 1].node,
                       n * per_token_bytes / 1e6)


class PipelinedEngine(_SlotEngine, _NetShimMixin):
    """Continuous-batching engine whose forward pass is split across
    placed core stages.  API mirrors :class:`ServingEngine` (both share
    the :class:`_SlotEngine` state machine); greedy outputs are
    token-identical to it (tests/test_pipeline.py)."""

    def __init__(self, cfg, params=None, *, n_stages: int = 2,
                 max_batch: int = 4, cache_len: int = 128, seed: int = 0,
                 prefill_chunk: int = 16, net=None,
                 placement: Optional[Dict[str, int]] = None,
                 entry_node: Optional[int] = None, decode_steps: int = 1,
                 policy=None, speculative=None, quantization=None):
        super().__init__(cfg, max_batch=max_batch, cache_len=cache_len,
                         prefill_chunk=prefill_chunk,
                         decode_steps=decode_steps, policy=policy,
                         speculative=speculative)
        self._init_stages_and_net(cfg, params, n_stages=n_stages,
                                  max_batch=max_batch, cache_len=cache_len,
                                  seed=seed, net=net, placement=placement,
                                  entry_node=entry_node,
                                  quantization=quantization)

    # ------------------------------------------------------------------
    # _SlotEngine hooks
    # ------------------------------------------------------------------
    def _reset_row(self, slot: int):
        s = jnp.int32(slot)
        for st in self.stages:
            st.reset_row(s)

    def _prefill_row(self, slot: int, toks: np.ndarray, pos0: int):
        c = len(toks)
        x = jnp.asarray(toks[None])
        p0, sl = jnp.int32(pos0), jnp.int32(slot)
        self._ship(self.entry_node, self.stages[0].node, c * 4 / 1e6)
        for k, st in enumerate(self.stages):
            x = st.prefill(x, p0, sl)
            self._ship_between(k, c, self._act_bytes)

    def _forward_steps(self, tokens: np.ndarray, pos: np.ndarray,
                       budgets: np.ndarray, k: int) -> np.ndarray:
        return self._run_macro(tokens, pos, budgets, k)

    def _forward_verify(self, tokens: np.ndarray, pos: np.ndarray,
                        budgets: np.ndarray) -> np.ndarray:
        return self._run_verify(tokens, pos, budgets)


class PagedPipelinedEngine(_PagedEngine, _NetShimMixin):
    """Paged continuous-batching engine over placed core stages: the
    block-granular scheduler of :class:`_PagedEngine` with the stage
    executor + network shim of :class:`PipelinedEngine`.  One
    engine-level :class:`~repro.models.kvcache.PagedCache` ledger
    governs every stage's layer-sliced pools, so admission, growth,
    and preemption decisions apply to the whole pipeline at once.
    Greedy outputs are token-identical to the dense engines
    (tests/test_paged.py)."""

    def __init__(self, cfg, params=None, *, n_stages: int = 2,
                 max_rows: int = 8, max_len: int = 128,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 seed: int = 0, prefill_chunk: int = 16,
                 watermark_blocks: int = 0, net=None,
                 placement: Optional[Dict[str, int]] = None,
                 entry_node: Optional[int] = None, decode_steps: int = 1,
                 policy=None, prefix_sharing: bool = True,
                 speculative=None, quantization=None):
        super().__init__(cfg, max_rows=max_rows, max_len=max_len,
                         block_size=block_size, num_blocks=num_blocks,
                         prefill_chunk=prefill_chunk,
                         watermark_blocks=watermark_blocks,
                         decode_steps=decode_steps, policy=policy,
                         prefix_sharing=prefix_sharing,
                         speculative=speculative)
        self._init_stages_and_net(cfg, params, n_stages=n_stages,
                                  max_batch=max_rows, cache_len=max_len,
                                  seed=seed, net=net, placement=placement,
                                  entry_node=entry_node, paged=self.pc,
                                  quantization=quantization)

    # ------------------------------------------------------------------
    # _PagedEngine hooks
    # ------------------------------------------------------------------
    def _reset_row(self, row: int):
        r = jnp.int32(row)
        xids = jnp.asarray(self.pc.cross_tables[row].copy())
        for st in self.stages:
            st.reset_row(r, xids)

    def _prefill_row(self, row: int, toks: np.ndarray, pos0: int):
        c = len(toks)
        x = jnp.asarray(toks[None])
        p0, r = jnp.int32(pos0), jnp.int32(row)
        meta = self.pc.meta(row=row)
        self._ship(self.entry_node, self.stages[0].node, c * 4 / 1e6)
        for k, st in enumerate(self.stages):
            x = st.prefill(x, p0, r, meta)
            self._ship_between(k, c, self._act_bytes)

    def _apply_cow(self, pairs):
        src = jnp.asarray([s for s, _ in pairs], jnp.int32)
        dst = jnp.asarray([d for _, d in pairs], jnp.int32)
        for st in self.stages:
            st.copy_blocks(src, dst)

    def _forward_steps(self, tokens: np.ndarray, pos: np.ndarray,
                       budgets: np.ndarray, k: int) -> np.ndarray:
        return self._run_macro(tokens, pos, budgets, k,
                               pmeta=self.pc.meta())

    def _forward_verify(self, tokens: np.ndarray, pos: np.ndarray,
                        budgets: np.ndarray) -> np.ndarray:
        return self._run_verify(tokens, pos, budgets,
                                pmeta=self.pc.meta())
