"""Distributed flash-decode: seq-parallel KV cache via shard_map.

The KV cache shards along the *sequence* dim over the `model` mesh axis
(spec ``P(batch, None, "model", None)`` for (B, KV, S, D)).  Each device
runs a local flash-decode over its cache slice (the single-chip Pallas
kernel in repro.kernels.decode_attention is the on-device body), then the
partial softmax states (m, l, acc) combine with one tiny pmax + two psums
— O(B·H·D) bytes on the wire instead of all-gathering O(B·KV·S·D) cache.

This is the explicit form of §Perf iteration D1; under plain GSPMD the
same layout already compiles (launch/dryrun.py --layout seq), but the
shard_map version pins the communication schedule instead of hoping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _local_flash_decode(q, k, v, pos, *, s_start, scale):
    """q: (B,H,D); k/v: (B,KV,S_loc,D); pos: (B,).  Returns partial
    (acc: (B,H,D), m: (B,H,1), l: (B,H,1)) softmax state."""
    b, h, d = q.shape
    kv, s_loc = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, d).astype(jnp.float32)
    scores = jnp.einsum("bngd,bnsd->bngs", qg,
                        k.astype(jnp.float32)) * scale
    kpos = s_start + jnp.arange(s_loc)
    valid = kpos[None, :] <= pos[:, None]                    # (B,S_loc)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)              # (B,KV,G,1)
    p = jnp.exp(scores - m)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bngs,bnsd->bngd", p, v.astype(jnp.float32))
    return (acc.reshape(b, h, d), m.reshape(b, h, 1), l.reshape(b, h, 1))


def distributed_decode_attention(q, k_cache, v_cache, pos, mesh: Mesh,
                                 axis: str = "model",
                                 batch_axes=("data",), scale=None):
    """q: (B,H,D); caches: (B,KV,S,D) seq-sharded over `axis`;
    pos: (B,).  Returns (B,H,D)."""
    b, h, d = q.shape
    s = k_cache.shape[2]
    n_shards = mesh.shape[axis]
    s_loc = s // n_shards
    scale = d ** -0.5 if scale is None else scale
    ba = batch_axes if all(a in mesh.axis_names for a in batch_axes) else ()

    def body(q, k, v, pos):
        idx = jax.lax.axis_index(axis)
        acc, m, l = _local_flash_decode(
            q, k, v, pos, s_start=idx * s_loc, scale=scale)
        # combine partial softmax states across the seq shards
        m_glob = jax.lax.pmax(m, axis)
        corr = jnp.exp(m - m_glob)
        acc = jax.lax.psum(acc * corr, axis)
        l = jax.lax.psum(l * corr, axis)
        return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(ba, None, None), P(ba, None, axis, None),
                  P(ba, None, axis, None), P(ba)),
        out_specs=P(ba, None, None),
        check_rep=False,
    )(q, k_cache, v_cache, pos)
