"""Draft-verify speculative decoding for the serving engines.

Decode is memory-bandwidth-bound: one weight read per emitted token.
Speculative decoding breaks that coupling — a cheap *draft* proposes K
tokens per row, and the target model scores all K positions in **one**
teacher-forced chunk forward (:meth:`repro.models.model.Model.
verify_steps`), accepting the longest exactly-matching greedy prefix
plus one correction/bonus token.  Greedy verification is *exact*: the
emitted stream is byte-identical to plain greedy decode for any draft,
any K, and any acceptance pattern (SERVING.md §Speculative decoding) —
the drafts only change how many weight reads the stream costs.  This
is the paper's "agile light service assists heavyweight core service"
asymmetry applied to the token loop itself.

Two draft providers ship:

:class:`NgramDraft`
    Self-drafting n-gram lookup over the request's own history (host
    side, model-free, zero dispatches): match the longest recent
    n-gram suffix, propose what followed it last time.  Greedy smoke
    streams fall into short cycles, so acceptance is high exactly
    where the win matters (long generations).
:class:`ModelDraft`
    A second, smaller model (e.g. a smollm-360m config drafting for
    qwen2-72b) generating K greedy tokens against its own dense cache.
    Rollback and preemption-resume are handled by syncing the draft
    cache to the target history's common prefix — a pure position
    truncation, no KV rewrite, legal because the draft config is
    itself gated to pure-attention archs (stale KV above the
    truncation point is position-masked).

Arch gating: :func:`spec_supported` admits pure-attention decoder-only
configs.  SSM/SWA state cannot be positionally rolled back (recurrent
state and ring buffers have no "unwrite"), enc-dec/cross reads are
unmasked, and MoE chunk verification co-batches all K+1 positions
through expert-capacity routing (a different token mix than sequential
decode — the same carve-out prefix sharing has).  Engines auto-gate
``speculative=`` off on unsupported archs, mirroring
``PagedCache.sharing_supported``.

This module stays free of direct jax imports so the jax-free testbed
(`serving/testbed.py`) can use :class:`SpecConfig`/:class:`NgramDraft`;
:class:`ModelDraft` imports jax lazily on first use.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, List, Optional, Sequence

import numpy as np


def spec_supported(cfg) -> bool:
    """Can ``cfg`` run draft-verify speculative decoding?

    Pure-attention decoder-only configs only: every segment must be
    full attention (``swa`` with a zero window degrades to full
    attention and qualifies), no encoder-decoder, no MoE (chunk-mode
    verification routes all K+1 positions through expert capacity at
    once — not the sequential-decode token mix).
    """
    if getattr(cfg, "is_encoder_decoder", False):
        return False
    if getattr(cfg, "mlp_kind", "dense") == "moe":
        return False
    from repro.models.transformer import build_segments
    for seg in build_segments(cfg):
        if seg.kind == "attn":
            continue
        if seg.kind == "swa" and not cfg.window:
            continue
        return False
    return True


class NgramDraft:
    """Self-drafting n-gram proposer (host-side, model-free).

    ``propose`` finds the longest (up to ``n``) suffix of the history
    that occurred earlier, and proposes the token that followed its
    most recent earlier occurrence; proposals extend greedily (each
    accepted proposal joins the working history).  With no match the
    fallback repeats the last token.  Deterministic, stateless, and
    free — the floor any model-based draft has to beat.
    """

    def __init__(self, n: int = 3):
        self.n = max(1, int(n))

    def propose(self, row: int, history: Sequence[int],
                k: int) -> List[int]:
        hist = list(history)
        out: List[int] = []
        for _ in range(k):
            out.append(self._next(hist))
            hist.append(out[-1])
        return out

    def _next(self, hist: List[int]) -> int:
        if not hist:
            return 0
        for n in range(min(self.n, len(hist) - 1), 0, -1):
            suf = hist[-n:]
            for s in range(len(hist) - n - 1, -1, -1):
                if hist[s:s + n] == suf:
                    return hist[s + n]
        return hist[-1]


class ModelDraft:
    """A second, smaller model proposes K greedy tokens per row.

    The draft keeps one dense cache row per engine row plus a host-side
    shadow ``_fed[row]`` — the token list whose KV its cache holds.
    Each ``propose`` syncs the shadow to the target history's longest
    common prefix (acceptance rollback, preemption-resume, and row
    reuse all reduce to this truncation: stale draft KV above the
    common prefix is position-masked, never rewritten), teacher-forces
    the new history tail through chunked prefill, then runs a fused
    ``decode_steps`` scan for K proposals — one draft sync per round,
    counted in :attr:`n_host_syncs`.

    The draft config must itself pass :func:`spec_supported` (the
    truncation trick needs position-masked KV).  jax and the model
    stack are imported lazily so this module stays importable on
    jax-free hosts.
    """

    #: prefill chunking of teacher-forced history tails (pow2 tail
    #: decomposition bounds the compiled program shapes, as in
    #: serving/engine.py chunked admission)
    PREFILL_CHUNK = 16

    def __init__(self, cfg: Any = None, params: Any = None, *,
                 seed: int = 0, cache_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.seed = seed
        self.cache_len = cache_len
        self.model = None
        self.caches = None
        self._fed: List[List[int]] = []
        self._pos: Optional[np.ndarray] = None
        self._jits: dict = {}
        self.n_host_syncs = 0

    # ------------------------------------------------------------- lazy
    def _ensure(self, rows: int, length: int):
        """(Re)allocate the draft cache to cover ``rows`` rows and
        ``length`` positions; growth resets the shadow (rows simply
        re-prefill on their next propose)."""
        import jax
        from repro.configs import get_smoke_config
        from repro.models import build_model

        if self.model is None:
            cfg = self.cfg
            if cfg is None or isinstance(cfg, str):
                cfg = get_smoke_config(cfg or "smollm-360m")
            if not spec_supported(cfg):
                raise ValueError(
                    "draft config must be a pure-attention decoder-only "
                    "arch (spec_supported) — its cache rollback is a "
                    "position truncation")
            self.cfg = cfg
            self.model = build_model(cfg)
            if self.params is None:
                self.params = self.model.init(jax.random.PRNGKey(self.seed))
        if (self.caches is None or rows > len(self._fed)
                or length > self.cache_len):
            while self.cache_len < length:
                self.cache_len *= 2
            rows = max(rows, len(self._fed))
            self.caches = self.model.init_cache(rows, self.cache_len)
            self._fed = [[] for _ in range(rows)]
            self._pos = np.zeros(rows, dtype=np.int32)

    def _jit(self, key: str, fn, donate=(1,)):
        import jax
        if key not in self._jits:
            self._jits[key] = jax.jit(fn, donate_argnums=donate)
        return self._jits[key]

    # ---------------------------------------------------------- propose
    def propose(self, row: int, history: Sequence[int],
                k: int) -> List[int]:
        import functools

        import jax.numpy as jnp

        from repro.serving.engine import chunk_sizes

        history = list(history)
        self._ensure(row + 1, len(history) + k + 1)
        fed = self._fed[row]
        common = 0
        for a, b in zip(fed, history):
            if a != b:
                break
            common += 1
        # teacher-force the unseen history tail (all but the last token,
        # which seeds the proposal scan)
        delta = history[common:-1]
        i = 0
        for c in chunk_sizes(len(delta), self.PREFILL_CHUNK):
            fill = self._jit(f"draft_fill{c}", self.model.prefill_chunk)
            _, self.caches = fill(
                self.params, self.caches,
                jnp.asarray(np.asarray(delta[i:i + c],
                                       dtype=np.int32)[None]),
                jnp.int32(common + i), jnp.int32(row))
            i += c
        pos = self._pos
        pos[:] = [len(f) for f in self._fed]
        pos[row] = len(history) - 1
        tokens = np.zeros((len(self._fed), 1), dtype=np.int32)
        tokens[row, 0] = history[-1]
        budgets = np.zeros(len(self._fed), dtype=np.int32)
        budgets[row] = k
        step = self._jit(
            f"draft_step{k}",
            functools.partial(self.model.decode_steps, k=k))
        toks, self.caches = step(
            self.params, self.caches,
            {"token": jnp.asarray(tokens), "pos": jnp.asarray(pos.copy()),
             "budget": jnp.asarray(budgets)})
        out = [int(t) for t in np.asarray(toks)[row]]
        self.n_host_syncs += 1
        # the scan fed history[-1] then its own first k-1 proposals
        self._fed[row] = history + out[:-1]
        return out


@dataclass
class SpecConfig:
    """Speculative-decoding knob bundle (the engines' ``speculative=``).

    ``k``
        draft length per row per verify round; each round emits
        between 1 and ``k + 1`` tokens per live row (matched prefix +
        correction/bonus), so the host-sync cost is between 1 and
        ``1/(k+1)`` per token.
    ``draft`` / ``ngram`` / ``draft_cfg``
        provider selection: ``"ngram"`` (default, n-gram order
        ``ngram``) or ``"model"`` (a :class:`ModelDraft` over
        ``draft_cfg`` — a ModelConfig, a smoke-config name, or None
        for smollm-360m).
    ``provider``
        a pre-built draft provider (anything with
        ``propose(row, history, k) -> list[int]``) — overrides
        ``draft``; the testbed's scripted oracles plug in here.

    :meth:`make` normalizes what engines accept: ``None``/``False``
    (off), an int K, a dict of these fields, a provider instance, or a
    SpecConfig.  It always returns a *fresh* config with a fresh
    provider (unless one was given explicitly) — providers hold
    per-row state, so engines must never share one, mirroring
    ``make_policy``.
    """

    k: int = 4
    draft: str = "ngram"
    ngram: int = 3
    draft_cfg: Any = None
    provider: Any = None
    seed: int = 0

    @staticmethod
    def make(spec) -> Optional["SpecConfig"]:
        if spec is None or spec is False:
            return None
        if spec is True:
            cfg = SpecConfig()
        elif isinstance(spec, SpecConfig):
            cfg = dataclasses.replace(spec)
        elif isinstance(spec, int):
            cfg = SpecConfig(k=spec)
        elif isinstance(spec, dict):
            cfg = SpecConfig(**spec)
        elif hasattr(spec, "propose"):
            cfg = SpecConfig(provider=spec)
        else:
            raise ValueError(
                f"speculative= takes None/bool/int K/dict/SpecConfig/"
                f"draft provider, got {spec!r}")
        if cfg.k < 1:
            raise ValueError(f"speculative draft length k must be >= 1, "
                             f"got {cfg.k}")
        if cfg.provider is None:
            if cfg.draft == "model":
                cfg.provider = ModelDraft(cfg.draft_cfg, seed=cfg.seed)
            elif cfg.draft == "ngram":
                cfg.provider = NgramDraft(n=cfg.ngram)
            else:
                raise ValueError(f"unknown draft kind {cfg.draft!r}; "
                                 f"known: 'ngram', 'model'")
        return cfg
