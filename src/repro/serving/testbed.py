"""Deterministic scheduler testbed: the paged engine state machine
with no model, no parameters, and no JAX dispatch.

:class:`FakeEngine` subclasses :class:`repro.serving.engine.
_PagedEngine`, so admission, block growth, preemption-by-recompute,
macro-step budgeting and the step clock are the *real* scheduler code
— only the three device hooks are replaced:

* ``_reset_row`` / ``_prefill_row`` — host no-ops (the
  :class:`repro.models.kvcache.PagedCache` ledger is pure numpy, so
  block accounting still runs for real);
* ``_forward_steps`` — a position-dependent integer recurrence::

      tok' = (31 * tok + 7 * pos + 1) mod 997

  Each step depends only on the previous token and its absolute
  position, so streams are macro-step-K-invariant and survive
  preempt-by-recompute token-identically — exactly the property the
  real greedy decode has, at zero cost.  (``_apply_cow`` stays the
  inherited host no-op for the same reason: the recurrence keeps no
  per-position device state a copy-on-write would have to duplicate,
  while the refcount/COW *ledger* machinery still runs for real —
  tests/test_prefix_sharing.py drives it through this class.)

Every policy decision (EDF ordering, admission-test verdicts, victim
selection, slack aging, virtual-queue drift) is therefore
unit-testable in milliseconds (tests/test_scheduler_policy.py,
tests/test_scheduler_props.py), and the goodput benchmark's
FIFO-vs-EDF deltas come from the same state machine the JAX engines
run (benchmarks/goodput_bench.py drives FakeEngine for its committed
baseline so the numbers are host-independent).

Speculative decoding runs here too: ``_forward_verify`` scores a
draft chunk against the same recurrence (greedy target per position,
longest matching prefix + correction, budget-clamped — the numpy
mirror of :func:`repro.models.model.greedy_verify_update`), and
:class:`ScriptedDraft` is a schedule-driven provider that proposes
exactly ``a`` correct tokens per round — so acceptance-dependent
scheduler paths (budget clamps, rollback accounting, the EC
spec_accept discount) are unit-testable with *chosen* acceptance
patterns (tests/test_spec_decode.py, tests/test_differential.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.configs import get_smoke_config
from repro.serving.engine import _PagedEngine

#: recurrence constants — small primes; 997 keeps tokens in-vocab for
#: every smoke config
_A, _B, _C, _MOD = 31, 7, 1, 997


def fake_stream(prompt, n: int) -> list:
    """Reference continuation of ``prompt`` under the testbed
    recurrence — what a request's ``out_tokens`` must equal regardless
    of scheduling (the testbed's golden oracle)."""
    toks = list(prompt)
    out = []
    for _ in range(n):
        pos = len(toks) - 1  # position of the token being fed
        out.append((_A * toks[-1] + _B * pos + _C) % _MOD)
        toks.append(out[-1])
    return out


class ScriptedDraft:
    """Schedule-driven draft provider for the testbed.

    ``schedule[r]`` (cycled; default all-``k``) is how many of the K
    proposals in round ``r`` are *correct* — the true recurrence
    continuation of the row's history — before the provider switches
    to deliberately-wrong tokens (``(true + 1) % _MOD``).  The engine
    must then emit exactly ``min(a, K) + 1`` tokens for an unclamped
    row (accepted prefix + correction/bonus), which makes acceptance
    accounting and rollback arithmetic exactly predictable.  Rounds
    are counted per row, mirroring how providers see one ``propose``
    per live row per verify round.
    """

    def __init__(self, schedule: Optional[Sequence[int]] = None):
        self.schedule = list(schedule) if schedule else None
        self._round: dict = {}

    def propose(self, row: int, history: Sequence[int], k: int) -> list:
        r = self._round.get(row, 0)
        self._round[row] = r + 1
        a = k if self.schedule is None else self.schedule[r % len(
            self.schedule)]
        true = fake_stream(history, k)
        return [t if j < a else (t + 1) % _MOD
                for j, t in enumerate(true)]


class FakeEngine(_PagedEngine):
    """The real paged scheduler over a scripted integer decoder."""

    def __init__(self, cfg=None, *, max_rows: int = 4, max_len: int = 64,
                 block_size: int = 8, num_blocks: Optional[int] = None,
                 prefill_chunk: int = 16, watermark_blocks: int = 0,
                 decode_steps: int = 1, policy=None,
                 prefix_sharing: bool = True, speculative=None):
        cfg = cfg or get_smoke_config("smollm-360m")
        super().__init__(cfg, max_rows=max_rows, max_len=max_len,
                         block_size=block_size, num_blocks=num_blocks,
                         prefill_chunk=prefill_chunk,
                         watermark_blocks=watermark_blocks,
                         decode_steps=decode_steps, policy=policy,
                         prefix_sharing=prefix_sharing,
                         speculative=speculative)

    # ------------------------------------------------------- no devices
    def _reset_row(self, row: int):
        pass

    def _prefill_row(self, row: int, toks: np.ndarray, pos0: int):
        pass

    def _forward_steps(self, tokens: np.ndarray, pos: np.ndarray,
                       budgets: np.ndarray, k: int) -> np.ndarray:
        out = np.zeros((len(tokens), k), dtype=np.int32)
        for i in range(len(tokens)):
            tok, p = int(tokens[i, 0]), int(pos[i])
            for j in range(k):
                tok = (_A * tok + _B * (p + j) + _C) % _MOD
                out[i, j] = tok
        return out

    def _forward_verify(self, tokens: np.ndarray, pos: np.ndarray,
                        budgets: np.ndarray) -> np.ndarray:
        """Numpy mirror of ``Model.verify_steps`` over the testbed
        recurrence: the greedy "target" at chunk slot j is the
        recurrence applied to the *fed* token ``tokens[i, j]``, so the
        accepted length is the longest prefix where drafts reproduce
        the true continuation; emission is the accepted prefix plus
        one correction, clamped to the row budget (-1 padding)."""
        s = tokens.shape[1]
        out = np.full((len(tokens), s), -1, dtype=np.int32)
        for i in range(len(tokens)):
            b = int(budgets[i])
            if b <= 0:
                continue
            p = int(pos[i])
            g = [(_A * int(tokens[i, j]) + _B * (p + j) + _C) % _MOD
                 for j in range(s)]
            acc = 0
            while acc < s - 1 and g[acc] == int(tokens[i, acc + 1]):
                acc += 1
            n = min(acc + 1, b)
            out[i, :n] = g[:n]
        return out
