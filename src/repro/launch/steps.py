"""Step factories + abstract input specs + sharding assignment.

`make_step(cfg, shape, mesh)` returns (fn, args_structs) where every leaf of
args_structs is a ShapeDtypeStruct carrying its NamedSharding — ready for
``jax.jit(fn).lower(*args)`` without any device allocation.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.models.transformer import build_segments
from repro.sharding.specs import fit_spec, param_spec
from repro.training.optimizer import AdamWState, adamw_init
from repro.training.train_step import make_train_step


def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=fit_spec(shape, spec, mesh))


# ----------------------------------------------------------------------
# Param / optimizer shardings
# ----------------------------------------------------------------------
def param_shardings(mesh: Mesh, model, params_struct):
    segs = model.segments
    enc_layers = model.cfg.n_encoder_layers

    def to_spec(path, leaf):
        keys = []
        for k in path:
            keys.append(getattr(k, "key", getattr(k, "idx", None)))
        spath = "/".join(str(k) for k in keys)
        stacked = False
        if "segments" in keys:
            i = keys.index("segments")
            seg_idx = keys[i + 1]
            if keys[0] == "encoder":
                stacked = enc_layers > 1
            else:
                seg = segs[seg_idx]
                stacked = seg.length > 1 and not seg.shared
        prefix = "seg:" if stacked else ""
        return NamedSharding(mesh, param_spec(prefix + spath, leaf.shape,
                                              mesh))

    return jax.tree_util.tree_map_with_path(to_spec, params_struct)


def _cache_shardings(mesh: Mesh, cfg: ModelConfig, cache_struct_,
                     layout: str = "heads"):
    b = _batch_axes(mesh)

    def spec_for(path, leaf):
        name = str(path[-1].key)
        if name in ("k", "v"):
            if layout == "seq":
                return fit_spec(leaf.shape, P(None, b, "model", None, None),
                                mesh)
            return fit_spec(leaf.shape, P(None, b, None, "model", None), mesh)
        if name in ("xk", "xv"):
            return fit_spec(leaf.shape, P(None, b, None, "model", None), mesh)
        if name == "h":  # ssm state: (L,B,di,ds) or (L,B,nh,hd,ds)
            spec = [None, b] + [None] * (leaf.ndim - 2)
            spec[2] = "model"
            return fit_spec(leaf.shape, P(*spec), mesh)
        if name == "conv":
            return fit_spec(leaf.shape, P(None, b, None, "model"), mesh)
        return fit_spec(leaf.shape, P(*([None] * leaf.ndim)), mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache_struct_)


def _with_shardings(structs, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        structs, shardings)


# ----------------------------------------------------------------------
# Abstract input specs per shape kind
# ----------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                decode_cache_layout: str = "heads"):
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    b = _batch_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    specs = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = _sds((B, S), jnp.int32, mesh, P(b, None))
        if cfg.n_image_tokens:
            specs["frontend"] = _sds((B, cfg.n_image_tokens, cfg.d_model),
                                     jnp.bfloat16, mesh, P(b, None, None))
        if cfg.is_encoder_decoder:
            specs["frontend"] = _sds((B, cfg.encoder_seq, cfg.d_model),
                                     jnp.bfloat16, mesh, P(b, None, None))
    else:  # decode
        specs["token"] = _sds((B, 1), jnp.int32, mesh, P(b, None))
        specs["pos"] = _sds((B,), jnp.int32, mesh, P(b))
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
        shardings = _cache_shardings(mesh, cfg, cache,
                                     layout=decode_cache_layout)
        specs["cache"] = _with_shardings(cache, shardings)
    return specs


# ----------------------------------------------------------------------
# Step functions
# ----------------------------------------------------------------------
def make_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              decode_cache_layout: str = "heads"):
    """Returns (fn, args) with sharded ShapeDtypeStruct args.

    train  : fn(params, opt_state, batch) -> (params, opt_state, metrics)
    prefill: fn(params, batch) -> (logits, cache)
    decode : fn(params, cache, batch) -> (next_token, cache)
    """
    model = build_model(cfg)
    pstruct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = param_shardings(mesh, model, pstruct)
    params_in = _with_shardings(pstruct, pshard)
    batch = input_specs(cfg, shape, mesh, decode_cache_layout)

    if shape.kind == "train":
        step = make_train_step(model)
        ostruct = jax.eval_shape(adamw_init, pstruct)
        mom_shard = pshard
        if os.environ.get("REPRO_ZERO1"):
            # ZeRO-1 (§Perf hillclimb): additionally shard optimizer
            # moments over the data axes on the first free divisible dim
            b_axes = _batch_axes(mesh)
            n_data = 1
            for ax in b_axes:
                n_data *= mesh.shape[ax]

            def zero1(ns, leaf):
                spec = list(ns.spec) + [None] * (
                    len(leaf.shape) - len(ns.spec))
                for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
                    if ax is None and dim % n_data == 0 and dim >= n_data:
                        spec[i] = b_axes if len(b_axes) > 1 else b_axes[0]
                        break
                return NamedSharding(mesh, P(*spec))

            mom_shard = jax.tree.map(zero1, pshard, pstruct)
        oshard = AdamWState(step=NamedSharding(mesh, P()),
                            mu=mom_shard, nu=mom_shard)
        opt_in = _with_shardings(ostruct, oshard)

        def fn(params, opt_state, b):
            return step(params, opt_state, b)

        return fn, (params_in, opt_in, batch)

    if shape.kind == "prefill":
        def fn(params, b):
            # serving prefill: populate the cache, return ONLY the
            # last-position logits (what the sampler needs)
            hidden, caches, _ = model.forward(
                params, b, mode="prefill",
                caches=model.init_cache(shape.global_batch, shape.seq_len),
                return_hidden=True)
            logits = jnp.einsum("bd,vd->bv", hidden[:, -1],
                                model.head_weight(params))
            return logits, caches

        return fn, (params_in, batch)

    # decode
    cache_in = batch.pop("cache")

    def fn(params, caches, b):
        logits, new_caches = model.decode_step(params, caches, b)
        # greedy sampler over the logical vocab (head table is padded)
        next_tok = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), new_caches

    return fn, (params_in, cache_in, batch)
