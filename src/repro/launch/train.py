"""Training driver.

Smoke scale (this host):
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --smoke --steps 100 --batch 8 --seq 64

Production scale (TPU pod): drop --smoke; the mesh comes from
make_production_mesh() and params/optimizer shard per repro.sharding.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import build_model
from repro.sharding.specs import use_mesh_rules
from repro.training import checkpoint
from repro.training.data import SyntheticLM
from repro.training.optimizer import adamw_init
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + trivial mesh (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = (make_host_mesh() if args.smoke
            else make_production_mesh(multi_pod=args.multi_pod))
    model = build_model(cfg)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    with mesh, use_mesh_rules(mesh):
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = jax.jit(make_train_step(model, base_lr=args.lr,
                                       warmup=max(2, args.steps // 10),
                                       total_steps=args.steps))
        n = sum(p.size for p in jax.tree.leaves(params))
        print(f"[train] {cfg.name}: {n/1e6:.1f}M params, "
              f"mesh={dict(mesh.shape)}", flush=True)
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
            if cfg.n_image_tokens:
                batch["frontend"] = jnp.zeros(
                    (args.batch, cfg.n_image_tokens, cfg.d_model))
            if cfg.is_encoder_decoder:
                batch["frontend"] = jnp.zeros(
                    (args.batch, cfg.encoder_seq, cfg.d_model))
            params, opt, metrics = step(params, opt, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                toks = args.batch * args.seq * (i + 1)
                print(f"step {i:4d}  ce={float(metrics['ce']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.2f}  "
                      f"tok/s={toks/(time.time()-t0):,.0f}", flush=True)
        if args.ckpt:
            checkpoint.save(args.ckpt, params)
            print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
