"""Production meshes.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.  Single pod = 16x16 = 256 chips
(v5e pod slice); multi-pod = 2 pods = 512 chips with a leading "pod" axis.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import (see launch/dryrun.py)")
    devs = np.asarray(devices[:n]).reshape(shape)
    return Mesh(devs, axes)


def make_host_mesh() -> Mesh:
    """Trivial 1x1 mesh over the single real device (smoke tests)."""
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
