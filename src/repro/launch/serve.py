"""Serving driver: continuous-batching engine + request stream.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 16 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=96)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder_decoder or cfg.n_image_tokens:
        print(f"[serve] note: {args.arch} needs frontend embeddings; "
              "serving text-only decoder path")
    eng = ServingEngine(cfg, max_batch=args.max_batch,
                        cache_len=args.cache_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size,
                              size=rng.integers(2, 8)).tolist()
        eng.submit(Request(id=i, prompt=prompt, max_new_tokens=args.max_new))
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {cfg.name}: {len(done)}/{args.requests} requests, "
          f"{toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  req {r.id}: {r.out_tokens}")


if __name__ == "__main__":
    main()
