"""Parse lowered/compiled HLO for roofline terms.

``cost_analysis()`` gives FLOPs and bytes accessed; collective bytes are NOT
included there, so we parse the HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every `dtype[a,b,...]` group in a shape string
    (handles tuples `(f32[2,3], s32[4])`)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def row(self) -> dict:
        out = {}
        for k in _COLLECTIVES:
            out[f"{k}_bytes"] = self.bytes_by_kind.get(k, 0)
            out[f"{k}_count"] = self.count_by_kind.get(k, 0)
        out["collective_bytes"] = self.total_bytes
        return out


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum *output* shape bytes of every collective op instruction.

    HLO lines look like:
      %ag = f32[16,4096]{1,0} all-gather(f32[1,4096]{1,0} %x), ...
    We take the result shape on the lhs (bytes actually moved per device
    scale with this; for all-reduce in/out sizes match).
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for kind in _COLLECTIVES:
            # match `= <shape> <kind>(` or `<kind>-start(` / `-done(`
            m = re.search(
                r"=\s+((?:\([^)]*\))|(?:[\w\[\],{}:#*\s]*?))\s*" + kind +
                r"(?:-start|-done)?\(", stripped)
            if m is None:
                continue
            if kind + "-done(" in stripped:
                continue  # counted at -start
            shape_str = m.group(1)
            b = _shape_bytes(shape_str)
            stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
            stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
            break
    return stats


# ----------------------------------------------------------------------
# Roofline terms
# ----------------------------------------------------------------------
# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s  (~50 GB/s/link)


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int) -> dict:
    """The three roofline times (seconds).

    Calibration (see EXPERIMENTS.md §Dry-run): ``cost_analysis()`` of an
    SPMD-partitioned module reports PER-DEVICE flops/bytes, and collective
    shapes in the partitioned HLO are per-device too — so none of the
    terms divide by n_chips.  (Ring all-gather actually moves
    (n-1)/n x bytes per link; we use the x1 upper bound.)
    """
    del n_chips
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_collective = coll_bytes / ICI_BW_PER_LINK
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
    }


def mfu(flops_per_token: float, tok_per_s: float) -> float:
    """Model FLOPs Utilization: useful model FLOP/s as a fraction of one
    chip's ``PEAK_FLOPS_BF16``.

    ``flops_per_token`` is the *model* count (2 x active params for
    inference, 6 x for training), not the HLO count — MFU deliberately
    excludes rematerialization and padding so it measures how much of
    the roof goes to the model.  Benches that measure ``tok_per_s`` on
    the CPU host report this as a *nominal* distance-to-roof: the
    utilization one v5e chip would see sustaining that token rate.
    """
    return flops_per_token * tok_per_s / PEAK_FLOPS_BF16


def mbu(bytes_per_token: float, tok_per_s: float) -> float:
    """Model Bandwidth Utilization: resident-state traffic per second as
    a fraction of one chip's ``HBM_BW``.

    ``bytes_per_token`` is what a fused decode step *must* stream per
    generated token — weights once per step plus the KV pool — so MBU
    is the decode roofline's memory axis: weight-only quantization
    lowers bytes_per_token and therefore the bandwidth a given tokens/s
    costs (SERVING.md §Quantization).
    """
    return bytes_per_token * tok_per_s / HBM_BW
