import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, with no device allocation.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m \
      --shape train_4k --mesh both --out results.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.config import SHAPES  # noqa: E402
from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch.hlo_analysis import collective_stats, roofline_terms  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_step  # noqa: E402
from repro.sharding.specs import use_mesh_rules  # noqa: E402


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               decode_cache_layout: str = "heads",
               verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "layout": decode_cache_layout}
    if not cfg.supports_shape(shape):
        rec["status"] = "skipped(DESIGN.md rule)"
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        with mesh, use_mesh_rules(mesh):
            fn, args = make_step(cfg, shape, mesh,
                                 decode_cache_layout=decode_cache_layout)
            lowered = jax.jit(fn).lower(*args)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        mem = compiled.memory_analysis()
        flops = float(cost.get("flops", 0.0))
        hbm_bytes = sum(float(v) for k, v in cost.items()
                        if k.startswith("bytes accessed"))
        coll = collective_stats(compiled.as_text())
        rec.update(coll.row())
        rec.update(roofline_terms(flops, hbm_bytes, coll.total_bytes,
                                  n_chips))
        rec.update({
            "status": "ok",
            "flops": flops,
            "hbm_bytes": hbm_bytes,
            "n_chips": n_chips,
            "compile_s": round(time.time() - t0, 1),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        })
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']} "
                  f"({decode_cache_layout}): OK "
                  f"flops={flops:.3e} hbm={hbm_bytes:.3e} "
                  f"coll={coll.total_bytes:.3e} "
                  f"dom={rec['dominant']} {rec['compile_s']}s", flush=True)
            print(f"  memory_analysis: args={rec['argument_bytes']:.3e} "
                  f"temp={rec['temp_bytes']:.3e} out={rec['output_bytes']:.3e}",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = f"FAIL: {type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
                  f"FAILED {type(e).__name__}: {str(e)[:300]}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--layout", default="heads", choices=["heads", "seq"])
    ap.add_argument("--out", default=None, help="append JSONL results here")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = dryrun_one(arch, shape, multi,
                                 decode_cache_layout=args.layout)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
                if str(rec.get("status", "")).startswith("FAIL"):
                    n_fail += 1
    print(f"[dryrun] done, {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
