from repro.microservice.partition import decompose, to_application  # noqa: F401
