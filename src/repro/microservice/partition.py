"""Bridge: real model configs -> the paper's microservice abstraction.

A transformer serving pipeline decomposes into
  light: tokenize -> [core stages...] -> light: sample -> light: detokenize
with core MSs = contiguous layer ranges (plus expert groups for MoE and
the encoder for enc-dec).  Profiles (a_m, b_m, r_m) derive from FLOPs and
activation/param bytes, so the paper's placement machinery operates on
*real* numbers; `profile_stage_ms` measures actual jit walltime (the
examples use it on CPU at smoke scale).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.graph import Application, Microservice, TaskType
from repro.core import paper_params as pp


@dataclass
class StageSpec:
    name: str
    kind: str            # "core" | "light"
    layer_range: tuple | None
    flops_per_token: float
    param_bytes: int
    act_bytes_out: int   # activation bytes shipped to the next stage


def decompose(cfg, n_core_stages: int = 2, tokens_per_req: int = 64,
              bytes_per_param: float = 2.0) -> List[StageSpec]:
    """``bytes_per_param`` sets the resident weight bytes per parameter
    for the core stages' `param_bytes` (2.0 = bf16 dense; weight-only
    quantization passes models.quantize.bytes_per_param(fmt), shrinking
    the service memory footprint the placement IP sees).  FLOPs are
    unchanged — dequant happens inside the matmul."""
    d = cfg.d_model
    stages: List[StageSpec] = [
        StageSpec("tokenize", "light", None, 1e3, 1 << 20, tokens_per_req * 4),
    ]
    if cfg.is_encoder_decoder:
        enc_flops = (cfg.n_encoder_layers
                     * cfg.layer_params("attn") * 2)
        stages.append(StageSpec(
            "encoder", "core", (0, cfg.n_encoder_layers), enc_flops,
            int(cfg.n_encoder_layers * cfg.layer_params("attn")
                * bytes_per_param),
            cfg.encoder_seq * d * 2))
    per = cfg.n_layers // n_core_stages
    for i in range(n_core_stages):
        lo = i * per
        hi = cfg.n_layers if i == n_core_stages - 1 else (i + 1) * per
        flops = sum(cfg.layer_active_params(cfg.block_pattern[j]) * 2
                    for j in range(lo, hi))
        pbytes = int(sum(cfg.layer_params(cfg.block_pattern[j])
                         * bytes_per_param for j in range(lo, hi)))
        stages.append(StageSpec(f"stage{i}", "core", (lo, hi),
                                flops, pbytes, d * 2))
    stages.append(StageSpec("sample", "light", None,
                            cfg.vocab_size * 4.0, 1 << 20, 4))
    stages.append(StageSpec("detokenize", "light", None, 1e3, 1 << 20,
                            tokens_per_req * 4))
    return stages


def to_application(cfg, stages: List[StageSpec],
                   rng: np.random.Generator,
                   measured_ms: dict | None = None,
                   deadline_ms: float = 80.0,
                   rate: float = 0.5) -> Application:
    """Build a core.graph.Application whose single task type is this
    model's serving pipeline.  Workloads a_m are expressed in MB with
    rates f in MB/ms such that a/f equals the (measured or estimated)
    stage latency."""
    services = []
    light_spec = pp.TABLE_I["light_ms"]
    for i, st in enumerate(stages):
        est_ms = (measured_ms or {}).get(
            st.name, max(st.flops_per_token / 5e9, 0.05))
        a_mb = max(st.act_bytes_out / 1e6, 0.05)
        if st.kind == "core":
            # deterministic rate calibrated to the stage latency
            services.append(Microservice(
                idx=i, name=st.name, kind="core",
                r=np.array([4.0, st.param_bytes / 1e9,
                            8.0, st.param_bytes / 1e9]),
                a=a_mb, b=a_mb, f_det=a_mb / est_ms,
                c_dp=pp.TABLE_I["core_ms"]["c_dp"],
                c_mt=pp.TABLE_I["core_ms"]["c_mt"]))
        else:
            # stochastic: Gamma with mean matching the measurement
            shape = float(rng.uniform(*light_spec["f_gamma_shape"]))
            scale = (a_mb / est_ms) / shape
            services.append(Microservice(
                idx=i, name=st.name, kind="light",
                r=np.array([0.5, 0.1, 0.25, 0.1]),
                a=a_mb, b=a_mb, f_shape=shape, f_scale=scale,
                c_dp=light_spec["c_dp"], c_mt=light_spec["c_mt"],
                c_pl=light_spec["c_pl"]))
    ids = list(range(len(services)))
    tt = TaskType(idx=0, name=f"serve-{cfg.name}", ms_ids=ids,
                  edges=[(ids[i], ids[i + 1]) for i in range(len(ids) - 1)],
                  deadline=deadline_ms,
                  payload=0.01, rate=rate)
    return Application(services=services, task_types=[tt])


def profile_stage_ms(fn, *args, iters: int = 3) -> float:
    """Median walltime of a jit'd callable (ms)."""
    import jax
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(ts))
