"""Loss + train step factory."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.training.optimizer import adamw_update, cosine_lr


def chunked_ce(x, head_w, targets, mask, chunk: int = 512):
    """Cross-entropy without materializing (B, S, V) float32 logits.

    Scans over sequence chunks; `jax.checkpoint` on the body makes the
    backward pass recompute each chunk's logits instead of storing them —
    peak memory goes from O(S·V) to O(chunk·V).
    """
    b, s, d = x.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    n = s // chunk
    xc = x.reshape(b, n, chunk, d).swapaxes(0, 1)           # (n,B,C,D)
    tc = targets.reshape(b, n, chunk).swapaxes(0, 1)        # (n,B,C)
    mc = mask.reshape(b, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, inp):
        xx, tt, mm = inp
        lg = jnp.einsum("bcd,vd->bcv", xx, head_w).astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tt[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mm
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mm)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(model, params, batch):
    """Next-token cross entropy (+ MoE aux), chunked over the sequence."""
    hidden, _, aux = model.forward(params, batch, mode="train",
                                   return_hidden=True)
    tokens = batch["tokens"]
    # predict t+1 from t; last position masked out
    targets = jnp.concatenate(
        [tokens[:, 1:], jnp.zeros_like(tokens[:, :1])], axis=1)
    mask = jnp.concatenate(
        [jnp.ones_like(tokens[:, 1:], dtype=jnp.float32),
         jnp.zeros_like(tokens[:, :1], dtype=jnp.float32)], axis=1)
    if batch.get("loss_mask") is not None:
        mask = mask * batch["loss_mask"].astype(jnp.float32)
    ce = chunked_ce(hidden, model.head_weight(params), targets, mask)
    total = ce + aux["moe_aux_loss"]
    return total, {"ce": ce, **aux}


def make_train_step(model, *, base_lr=3e-4, warmup=100, total_steps=10_000,
                    weight_decay=0.1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch), has_aux=True)(params)
        lr = cosine_lr(opt_state.step, base_lr, warmup, total_steps)
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr=lr, weight_decay=weight_decay)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step
