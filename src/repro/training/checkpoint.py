"""Minimal-dependency checkpointing: pytree -> .npz (+ treedef JSON).

Works for params and optimizer state; restores exact dtypes/shapes.
"""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(path, __paths__=json.dumps(paths), **arrays)


def restore(path: str, like):
    """Restore into the structure of `like` (a template pytree)."""
    data = np.load(path, allow_pickle=False)
    paths_saved = json.loads(str(data["__paths__"]))
    paths_t, leaves_t, treedef = _flatten_with_paths(like)
    assert paths_saved == paths_t, "checkpoint/template structure mismatch"
    leaves = [jax.numpy.asarray(data[f"a{i}"]).astype(l.dtype)
              for i, l in enumerate(leaves_t)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
