"""AdamW + cosine schedule, pure JAX (no optax dependency)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def cosine_lr(step, base_lr: float, warmup: int, total: int,
              min_frac: float = 0.1):
    warm = base_lr * (step + 1) / max(1, warmup)
    prog = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-12))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        update = update + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), gnorm
