from repro.training.optimizer import adamw_init, adamw_update, cosine_lr  # noqa: F401
from repro.training.train_step import loss_fn, make_train_step  # noqa: F401
