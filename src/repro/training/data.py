"""Synthetic LM data pipeline.

A deterministic, seekable stream of token batches: a mixture of (a) a
Zipf-distributed unigram stream and (b) embedded copy/induction patterns so
a ~100M model shows a clearly decreasing loss within a few hundred steps.
Sharded loading: each data-parallel host slices the global batch.
"""
from __future__ import annotations

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = global_batch
        self.seed = seed
        ranks = np.arange(1, vocab_size + 1)
        p = ranks ** (-zipf_a)
        self.p = p / p.sum()

    def batch_at(self, step: int, shard: int = 0, n_shards: int = 1):
        """Deterministic batch for (step, shard)."""
        rng = np.random.default_rng((self.seed, step, shard))
        b = self.batch // n_shards
        toks = rng.choice(self.vocab, size=(b, self.seq), p=self.p)
        # plant induction patterns: copy a span forward
        span = max(4, self.seq // 16)
        for i in range(b):
            if self.seq >= 2 * span + 2:
                src = rng.integers(0, self.seq // 2 - span)
                dst = rng.integers(self.seq // 2, self.seq - span)
                toks[i, dst:dst + span] = toks[i, src:src + span]
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
