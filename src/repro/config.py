"""Configuration system for the repro framework.

Two families of config live here:

* :class:`ModelConfig` — a single composable description covering every
  assigned architecture family (dense GQA / MoE / SSM / hybrid / enc-dec
  audio / VLM).  A model is a ``block_pattern``: one block kind per layer,
  plus an MLP kind.  ``repro.models.model`` consumes this directly.
* :class:`ShapeConfig` — the four assigned input shapes (train_4k,
  prefill_32k, decode_32k, long_500k).

Everything is a frozen dataclass so configs hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# Block kinds understood by repro.models.transformer
BLOCK_KINDS = ("attn", "swa", "cross", "mamba1", "mamba2")
MLP_KINDS = ("dense", "moe", "none")


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    """Unified architecture description.

    ``block_pattern`` has one entry per decoder layer; encoder layers (for
    enc-dec models) are always full bidirectional attention.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...]
    mlp_kind: str = "dense"

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> use d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM (mamba) ---
    ssm_state: int = 0
    d_inner: int = 0  # 0 -> 2 * d_model
    conv_width: int = 4
    mamba2_headdim: int = 64

    # --- attention details ---
    window: int = 0  # sliding-window size for "swa" blocks
    # zamba2-style weight sharing: all layers of `shared_block_kind` reuse
    # one parameter set.
    shared_block_kind: str = ""
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- enc-dec (audio) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0  # stub frontend: number of frame embeddings

    # --- VLM ---
    n_image_tokens: int = 0  # stub frontend: number of patch embeddings

    # provenance
    source: str = ""

    # dtype of params/activations in the production configs
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.block_pattern) == self.n_layers, (
            f"{self.name}: pattern len {len(self.block_pattern)} != "
            f"n_layers {self.n_layers}"
        )
        for b in self.block_pattern:
            assert b in BLOCK_KINDS, f"unknown block kind {b!r}"
        assert self.mlp_kind in MLP_KINDS

    # ------------------------------------------------------------------
    # Derived quantities
    # ------------------------------------------------------------------
    @property
    def d_inner_eff(self) -> int:
        return self.d_inner if self.d_inner else 2 * self.d_model

    @property
    def moe_d_ff_eff(self) -> int:
        return self.moe_d_ff if self.moe_d_ff else self.d_ff

    @property
    def has_attention(self) -> bool:
        return any(b in ("attn", "swa", "cross") for b in self.block_pattern)

    @property
    def is_subquadratic(self) -> bool:
        """True if no block requires a full-length KV cache at decode."""
        return all(b in ("mamba1", "mamba2", "swa") for b in self.block_pattern)

    def supports_shape(self, shape: ShapeConfig) -> bool:
        """Decode-shape applicability rules (see DESIGN.md)."""
        if shape.name != "long_500k":
            return True
        # long_500k: SSM / hybrid / windowed archs only.  gemma3's 5:1
        # local:global still qualifies (global layers are linear per decoded
        # token with a seq-sharded cache; local layers are O(window)).
        if self.family in ("ssm", "hybrid"):
            return True
        if self.name.startswith("gemma3") or self.name.startswith("mixtral"):
            return True
        return False

    # --- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ---------
    def _attn_params(self, kind: str) -> int:
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        p = d * h * hd + 2 * d * kv * hd + h * hd * d  # q, k, v, o
        if self.qkv_bias:
            p += h * hd + 2 * kv * hd
        if kind == "cross":
            p += 2 * d  # extra norms
        return p + 2 * d  # norms

    def _mlp_params(self) -> int:
        if self.mlp_kind == "none":
            return 0
        if self.mlp_kind == "moe":
            ff = self.moe_d_ff_eff
            return self.n_experts * 3 * self.d_model * ff + self.d_model * self.n_experts
        return 3 * self.d_model * self.d_ff

    def _mlp_active_params(self) -> int:
        if self.mlp_kind == "none":
            return 0
        if self.mlp_kind == "moe":
            ff = self.moe_d_ff_eff
            return self.experts_per_token * 3 * self.d_model * ff + self.d_model * self.n_experts
        return 3 * self.d_model * self.d_ff

    def _mamba_params(self, kind: str) -> int:
        d, di, ds = self.d_model, self.d_inner_eff, self.ssm_state
        p = d * 2 * di  # in_proj (x, z)
        p += self.conv_width * di  # depthwise conv
        if kind == "mamba1":
            dt_rank = max(1, d // 16)
            p += di * (dt_rank + 2 * ds)  # x_proj -> (dt, B, C)
            p += dt_rank * di  # dt_proj
            p += di * ds  # A_log
        else:  # mamba2 (SSD): per-head A, dt; B,C projected from x
            nh = max(1, di // self.mamba2_headdim)
            p += d * 2 * ds  # B, C proj (state-space ins)
            p += nh * 2  # A_log, dt_bias per head
        p += di  # D skip
        p += di * d  # out_proj
        return p + 2 * d  # norms

    def layer_params(self, kind: str) -> int:
        if kind in ("attn", "swa", "cross"):
            return self._attn_params(kind) + self._mlp_params()
        return self._mamba_params(kind)

    def layer_active_params(self, kind: str) -> int:
        if kind in ("attn", "swa", "cross"):
            return self._attn_params(kind) + self._mlp_active_params()
        return self._mamba_params(kind)

    def num_params(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        n += self.d_model  # final norm
        shared_counted = False
        for b in self.block_pattern:
            if b == self.shared_block_kind:
                if shared_counted:
                    continue
                shared_counted = True
            n += self.layer_params(b)
        if self.is_encoder_decoder:
            # encoder: full attn + dense mlp, bidirectional
            enc_layer = self._attn_params("attn") + 3 * self.d_model * self.d_ff
            n += self.n_encoder_layers * enc_layer
            # decoder cross-attn over encoder output (one per decoder layer)
            n += self.n_layers * self._attn_params("cross")
        return n

    def num_active_params(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        n += self.d_model
        shared_counted = False
        for b in self.block_pattern:
            if b == self.shared_block_kind:
                if shared_counted:
                    continue
                shared_counted = True
            n += self.layer_active_params(b)
        if self.is_encoder_decoder:
            enc_layer = self._attn_params("attn") + 3 * self.d_model * self.d_ff
            n += self.n_encoder_layers * enc_layer
            n += self.n_layers * self._attn_params("cross")
        return n


# ----------------------------------------------------------------------
# Pattern builders
# ----------------------------------------------------------------------
def uniform(kind: str, n: int) -> Tuple[str, ...]:
    return tuple([kind] * n)


def local_global(n: int, local: int = 5, window_kind: str = "swa") -> Tuple[str, ...]:
    """gemma3-style `local:1 global` repeating pattern."""
    pat = []
    for i in range(n):
        pat.append("attn" if (i % (local + 1)) == local else window_kind)
    return tuple(pat)


def every_kth(n: int, base: str, special: str, k: int) -> Tuple[str, ...]:
    """`special` at layers k-1, 2k-1, ... (0-indexed), `base` elsewhere."""
    return tuple(special if (i % k) == (k - 1) else base for i in range(n))


# ----------------------------------------------------------------------
# Reduced variants for CPU smoke tests
# ----------------------------------------------------------------------
def reduce_config(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 128,
                  n_experts: int = 4, vocab: int = 512,
                  seq_cap: int = 64) -> ModelConfig:
    """Shrink a production config to a CPU-smokeable variant of the same family.

    Keeps the block-kind mix: the reduced pattern samples one layer of each
    distinct kind present (up to ``n_layers``).
    """
    kinds = []
    for b in cfg.block_pattern:
        if b not in kinds:
            kinds.append(b)
    pattern = tuple((kinds * n_layers)[:n_layers])
    n_heads = max(2, min(4, cfg.n_heads))
    n_kv = max(1, min(n_heads, cfg.n_kv_heads))
    while n_heads % n_kv:
        n_kv -= 1
    head_dim = max(16, d_model // n_heads)
    ne = min(n_experts, cfg.n_experts) if cfg.n_experts else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        block_pattern=pattern,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=max(32, d_model * 2),
        moe_d_ff=max(32, d_model) if cfg.mlp_kind == "moe" else 0,
        vocab_size=vocab,
        n_experts=ne,
        experts_per_token=min(cfg.experts_per_token, max(1, ne // 2)) if ne else 0,
        d_inner=2 * d_model if cfg.ssm_state else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        window=min(cfg.window, seq_cap // 2) if cfg.window else 0,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 16) if cfg.encoder_seq else 0,
        n_image_tokens=min(cfg.n_image_tokens, 16) if cfg.n_image_tokens else 0,
        dtype="float32",
    )


@dataclass(frozen=True)
class RuntimeConfig:
    """Knobs for the distributed runtime (see repro.launch)."""

    data_axis: int = 16
    model_axis: int = 16
    pods: int = 1
    # decode cache layout: "heads" (baseline GSPMD) or "seq" (shard_map
    # seq-parallel flash-decode — the beyond-paper optimization)
    decode_cache_layout: str = "heads"
    remat: str = "none"  # none | full | dots
    param_dtype: str = "bfloat16"
