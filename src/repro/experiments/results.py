"""Versioned, machine-readable JSON results for the replication runner.

File layout (EXPERIMENTS.md §JSON schema)::

    {
      "schema_version": 1,
      "meta":  {...free-form provenance: grid, section, cli args...},
      "rows":  [ {<Simulator.metrics() + spec fields>}, ... ]
    }

Serialization is deterministic: keys are sorted, separators fixed, and
NaNs (e.g. latency percentiles of an empty trial) are written as null
so the files are strict JSON and byte-identical across replays.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

SCHEMA_VERSION = 1


def _clean(obj):
    """NaN/inf -> None; numpy scalars -> python (strict JSON)."""
    if isinstance(obj, dict):
        return {k: _clean(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_clean(v) for v in obj]
    if isinstance(obj, (np.floating, float)):
        f = float(obj)
        return f if math.isfinite(f) else None
    if isinstance(obj, (np.integer,)):
        return int(obj)
    return obj


def dumps(rows: Sequence[Dict], meta: Optional[Dict] = None) -> str:
    doc = {"schema_version": SCHEMA_VERSION, "meta": _clean(meta or {}),
           "rows": _clean(list(rows))}
    return json.dumps(doc, sort_keys=True, separators=(",", ":"),
                      allow_nan=False)


def save_results(path: str, rows: Sequence[Dict],
                 meta: Optional[Dict] = None) -> None:
    with open(path, "w") as f:
        f.write(dumps(rows, meta))
        f.write("\n")


def load_results(path: str) -> Tuple[List[Dict], Dict]:
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):       # pre-schema flat row dumps
        return doc, {}
    assert doc.get("schema_version") == SCHEMA_VERSION, doc.get(
        "schema_version")
    return doc["rows"], doc.get("meta", {})


# ----------------------------------------------------------------------
# Aggregation
# ----------------------------------------------------------------------
def metrics_equal(a: Dict, b: Dict) -> bool:
    """Exact equality for trial-metric dicts, with NaN == NaN.

    Empty trials (nothing completed) have NaN latency percentiles in
    BOTH engines; plain dict `==` would flag those identical rows as
    divergent (nan != nan), so equality gates (benchmarks/sim_bench.py,
    tests/test_vectorized_replay.py) use this instead."""
    if a.keys() != b.keys():
        return False
    for k, va in a.items():
        vb = b[k]
        if (isinstance(va, float) and isinstance(vb, float)
                and math.isnan(va) and math.isnan(vb)):
            continue
        if va != vb:
            return False
    return True


def summarize_rows(rows: Iterable[Dict],
                   keys: Sequence[str] = ("scenario", "strategy",
                                          "rate_multiplier")
                   ) -> List[Dict]:
    """Group rows by `keys`, aggregate the headline metrics."""
    groups: Dict[tuple, List[Dict]] = {}
    for r in rows:
        groups.setdefault(tuple(r.get(k) for k in keys), []).append(r)

    def _ordering(t):
        # type-aware: numeric columns sort numerically (kappa 0 < 6 < 12,
        # not lexicographic "0" < "12" < "6"), None last
        return tuple((v is None, not isinstance(v, (int, float)),
                      v if isinstance(v, (int, float)) else str(v))
                     for v in t)

    out = []
    for gkey in sorted(groups, key=_ordering):
        rs = groups[gkey]

        def col(c):
            return np.array([r[c] for r in rs], dtype=float)

        ot, comp, cost = col("on_time"), col("completed"), col("total_cost")
        summ = dict(zip(keys, gkey))
        summ.update({
            "n_trials": len(rs),
            "on_time_mean": float(ot.mean()),
            "on_time_p10": float(np.percentile(ot, 10)),
            "on_time_p50": float(np.percentile(ot, 50)),
            "on_time_p90": float(np.percentile(ot, 90)),
            "on_time_std": float(ot.std()),
            "completed_mean": float(comp.mean()),
            "completed_std": float(comp.std()),
            "gap_mean": float((comp - ot).mean()),
            "cost_mean": float(cost.mean()),
            "cost_std": float(cost.std()),
        })
        out.append(summ)
    return out


def markdown_table(summaries: Sequence[Dict],
                   keys: Sequence[str] = ("scenario", "strategy",
                                          "rate_multiplier")) -> str:
    """Render grouped summaries as a GitHub-flavored markdown table."""
    cols = list(keys) + ["n_trials", "on_time_mean", "on_time_p10",
                         "on_time_p90", "completed_mean", "cost_mean"]
    lines = ["| " + " | ".join(cols) + " |",
             "|" + "---|" * len(cols)]
    for s in summaries:
        cells = []
        for c in cols:
            v = s.get(c)
            cells.append(f"{v:.4f}" if isinstance(v, float) else str(v))
        lines.append("| " + " | ".join(cells) + " |")
    return "\n".join(lines)
