"""Parallel Monte-Carlo replication runner (seed x strategy x scenario).

Fans fully-specified `TrialSpec`s out across worker processes.  Every
random stream a trial consumes is derived from the spec alone via
`np.random.SeedSequence` entropy lists (seed, crc32(scenario),
stream-id[, crc32(strategy)]), so

  * the environment (application + network + churn + modulation) is
    identical for every strategy sharing a (seed, scenario, rate) cell;
  * results are independent of worker count, scheduling order, and
    PYTHONHASHSEED — the same grid replays byte-identical.

Results are plain dicts (Simulator.metrics() plus the spec fields);
`repro.experiments.results` serializes them to the versioned JSON
schema documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import multiprocessing as mp
import os
import sys
from concurrent.futures import ProcessPoolExecutor
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.experiment import (STRATEGIES, build_strategy, spawn_rng,
                                   stable_seed)
from repro.core.simulator import Simulator
from repro.experiments.scenarios import get_scenario

# sub-stream ids inside a (seed, scenario) cell
_ENV_STREAM, _CHURN_STREAM, _MOD_STREAM = 0, 1, 2

WORKERS_ENV = "REPRO_EXP_WORKERS"


@dataclasses.dataclass(frozen=True)
class TrialSpec:
    """One fully-deterministic trial of the replication grid."""
    seed: int
    strategy: str
    scenario: str = "baseline"
    rate_multiplier: float = 1.0
    horizon_slots: int = 100
    drain_slots: int = 400          # post-horizon completion window
    eps: float = 0.2
    kappa: Optional[int] = None     # proposal diversity override
    #: weight bytes/param for core-service memory demand (None = the
    #: bf16 calibration; quantized re-runs pass 1.0 for int8, 0.5 int4)
    bytes_per_param: Optional[float] = None


def make_grid(seeds: Iterable[int],
              strategies: Optional[Sequence[str]] = None,
              scenarios: Sequence[str] = ("baseline",),
              rate_multipliers: Sequence[float] = (1.0,),
              horizon_slots: int = 100, drain_slots: int = 400,
              eps: float = 0.2,
              kappas: Sequence[Optional[int]] = (None,),
              bytes_per_param: Optional[float] = None) -> List[TrialSpec]:
    """Cartesian replication grid in deterministic order."""
    return [TrialSpec(seed=int(seed), strategy=name, scenario=scen,
                      rate_multiplier=float(mult),
                      horizon_slots=horizon_slots,
                      drain_slots=drain_slots, eps=eps, kappa=kappa,
                      bytes_per_param=bytes_per_param)
            for scen in scenarios
            for mult in rate_multipliers
            for seed in seeds
            for name in (strategies or list(STRATEGIES))
            for kappa in kappas]


def run_one(spec: TrialSpec) -> Dict:
    """Build the trial's environment and strategy, run, annotate."""
    scen = get_scenario(spec.scenario)
    sid = stable_seed(spec.scenario)
    env_rng = spawn_rng(spec.seed, sid, _ENV_STREAM)
    app = scen.build_application(env_rng,
                                 rate_multiplier=spec.rate_multiplier)
    net = scen.build_network(env_rng)
    churn = scen.churn_schedule(
        net, spawn_rng(spec.seed, sid, _CHURN_STREAM), spec.horizon_slots)
    modulation = scen.arrival_modulation(
        spawn_rng(spec.seed, sid, _MOD_STREAM))
    strat = build_strategy(spec.strategy, horizon_slots=spec.horizon_slots,
                           eps=spec.eps, kappa=spec.kappa, seed=spec.seed,
                           bytes_per_param=spec.bytes_per_param)
    sim = Simulator(app, net, strat,
                    rng=spawn_rng(spec.seed, sid,
                                  stable_seed(spec.strategy)),
                    horizon_slots=spec.horizon_slots,
                    drain_slots=spec.drain_slots,
                    churn=churn, arrival_modulation=modulation)
    m = sim.run()
    m.update(seed=spec.seed, scenario=spec.scenario,
             rate_multiplier=spec.rate_multiplier,
             horizon_slots=spec.horizon_slots,
             drain_slots=spec.drain_slots, eps=spec.eps,
             kappa=spec.kappa, bytes_per_param=spec.bytes_per_param)
    return m


def default_workers(n_specs: int) -> int:
    env = os.environ.get(WORKERS_ENV)
    n = int(env) if env else (os.cpu_count() or 1)
    return max(1, min(n, n_specs))


def run_grid(specs: Sequence[TrialSpec], n_workers: Optional[int] = None,
             progress: bool = False) -> List[Dict]:
    """Run a grid, fanning out across processes; result order == spec
    order regardless of completion order, so output is deterministic."""
    if not specs:
        return []
    if n_workers is None:
        n_workers = default_workers(len(specs))
    results: List[Dict] = []
    if n_workers <= 1:
        for i, spec in enumerate(specs):
            results.append(run_one(spec))
            if progress:
                print(f"# trial {i + 1}/{len(specs)} done "
                      f"({spec.scenario}/{spec.strategy}/s{spec.seed})",
                      flush=True)
        return results
    # fork is fastest but undefined once XLA's threads/mutexes exist in
    # the parent (e.g. pytest imported jax); forkserver forks from a
    # clean server process instead.  Workers only re-import numpy-level
    # modules to unpickle TrialSpec/run_one, so this stays cheap.
    methods = mp.get_all_start_methods()
    if "fork" in methods and "jax" not in sys.modules:
        method = "fork"
    else:
        method = "forkserver" if "forkserver" in methods else "spawn"
    with ProcessPoolExecutor(max_workers=n_workers,
                             mp_context=mp.get_context(method)) as ex:
        for i, m in enumerate(ex.map(run_one, specs)):
            results.append(m)
            if progress:
                spec = specs[i]
                print(f"# trial {i + 1}/{len(specs)} done "
                      f"({spec.scenario}/{spec.strategy}/s{spec.seed})",
                      flush=True)
    return results
