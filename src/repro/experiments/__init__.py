"""Monte-Carlo experiment harness: scenario registry + parallel runner.

Entry points:

  * `repro.experiments.runner.make_grid` / `run_grid` — build and fan a
    seed x strategy x scenario replication grid across processes;
  * `repro.experiments.scenarios.get_scenario` / `list_scenarios` — the
    named workload/environment dynamics registry;
  * `repro.experiments.results` — versioned machine-readable JSON;
  * `repro.experiments.report` — markdown summary tables from results
    files (``python -m repro.experiments.report FILE --by keys``).

See EXPERIMENTS.md for the CLI and schema documentation.
"""
from repro.experiments.results import load_results, save_results  # noqa: F401
from repro.experiments.runner import (TrialSpec, make_grid,  # noqa: F401
                                      run_grid, run_one)
from repro.experiments.scenarios import (get_scenario,  # noqa: F401
                                         list_scenarios)
