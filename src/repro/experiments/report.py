"""Render replication-runner results JSON as markdown summary tables.

Thin CLI over :func:`repro.experiments.results.summarize_rows` /
:func:`markdown_table`: load one or more versioned results files, group
rows by the requested spec columns, and print a GitHub-flavored table
(plus the file meta for provenance).  This is the reporting entry point
the scale_load sweep (benchmarks/scale_load.py) and ad-hoc grid runs
share::

    PYTHONPATH=src python -m repro.experiments.report \
        bench_scale_load.json --by scenario,strategy

Any spec field stored on the rows works as a group key (scenario,
strategy, rate_multiplier, seed, kappa, horizon_slots, ...).
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Sequence

from repro.experiments.results import (load_results, markdown_table,
                                       summarize_rows)


def report(paths: Sequence[str],
           by: Sequence[str] = ("scenario", "strategy")) -> str:
    """Markdown report for the concatenated rows of `paths`."""
    out: List[str] = []
    rows: List[Dict] = []
    for path in paths:
        file_rows, meta = load_results(path)
        rows.extend(file_rows)
        desc = ", ".join(f"{k}={v}" for k, v in sorted(meta.items())
                         if not isinstance(v, (dict, list)))
        out.append(f"**{path}** ({len(file_rows)} rows; {desc})")
    out.append("")
    out.append(markdown_table(summarize_rows(rows, keys=tuple(by)),
                              keys=tuple(by)))
    return "\n".join(out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="summarize replication-runner results JSON")
    ap.add_argument("results", nargs="+", help="results JSON file(s)")
    ap.add_argument("--by", default="scenario,strategy",
                    help="comma-separated group-by spec columns")
    args = ap.parse_args(argv)
    print(report(args.results, by=tuple(args.by.split(","))))


if __name__ == "__main__":
    main()
