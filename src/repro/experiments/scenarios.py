"""Scenario registry: named workload + environment dynamics.

A Scenario bundles everything a Monte-Carlo trial samples besides the
strategy: the application instance, the network topology, a per-slot
arrival-rate modulation (workload dynamics), and a node
failure/recovery churn schedule (environment dynamics).  Each is a
named config runnable from ``python -m benchmarks.run --scenario
<name>`` and addressable from the grid runner.

Registered scenarios:

  baseline       paper Table-I instance, stationary Poisson arrivals
  bursty_mmpp    2-state Markov-modulated Poisson arrival process
  diurnal        sinusoidal (day/night) load with random phase
  failure_churn  rolling edge-server outages with recovery
  skewed_mix     one task type dominates the arrival mix
  tiered         heterogeneous cloud / edge / device network
  scale_load_N          N-user population on a proportionally scaled
                        two-tier metro (N in SCALE_LOAD_USERS, 10..500)
  scale_load_tiered_N   same sweep over the four-tier cloud/edge/device
                        topology (the `tiered` pairing)

Scenarios are instantiated per trial (they may hold rng state for the
modulation process); everything they sample is driven by generators the
runner spawns from the trial's SeedSequence, so trials replay exactly.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Type

import numpy as np

from repro.core import paper_params as pp_defaults
from repro.core.graph import Application, make_application
from repro.core.network import EdgeNetwork, make_network, make_tiered_network
from repro.core.simulator import ChurnEvent

_REGISTRY: Dict[str, Type["Scenario"]] = {}


def register(cls: Type["Scenario"]) -> Type["Scenario"]:
    assert cls.name and cls.name not in _REGISTRY, cls.name
    _REGISTRY[cls.name] = cls
    return cls


def get_scenario(name: str) -> "Scenario":
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"registered: {sorted(_REGISTRY)}") from None


def list_scenarios() -> Dict[str, str]:
    return {n: cls.description for n, cls in sorted(_REGISTRY.items())}


# ----------------------------------------------------------------------
# Arrival-rate modulation processes (called once per generation slot)
# ----------------------------------------------------------------------
class MMPPModulation:
    """2-state Markov-modulated Poisson process: arrival rates switch
    between a quiet multiplier and a burst multiplier with per-slot
    transition probabilities.  Mean multiplier ~1 for the defaults, so
    aggregate load matches baseline but arrives in bursts."""

    def __init__(self, rng: np.random.Generator, low: float = 0.4,
                 high: float = 2.8, p_low_high: float = 0.08,
                 p_high_low: float = 0.24):
        self.rng = rng
        self.mults = (low, high)
        self.p_switch = (p_low_high, p_high_low)
        self.state = 0

    def __call__(self, t_slot: int) -> float:
        if self.rng.random() < self.p_switch[self.state]:
            self.state = 1 - self.state
        return self.mults[self.state]


class DiurnalModulation:
    """Sinusoidal load: 1 + amp * sin(2*pi*(t/period + phase))."""

    def __init__(self, rng: np.random.Generator, amp: float = 0.6,
                 period_slots: float = 48.0):
        self.amp = amp
        self.period = period_slots
        self.phase = float(rng.uniform(0.0, 1.0))

    def __call__(self, t_slot: int) -> float:
        return max(0.0, 1.0 + self.amp * np.sin(
            2.0 * np.pi * (t_slot / self.period + self.phase)))


# ----------------------------------------------------------------------
# Scenarios
# ----------------------------------------------------------------------
class Scenario:
    """Base: the paper's stationary Table-I evaluation setup."""

    name = ""
    description = ""

    def build_application(self, rng: np.random.Generator,
                          rate_multiplier: float = 1.0) -> Application:
        return make_application(rng, rate_multiplier=rate_multiplier)

    def build_network(self, rng: np.random.Generator) -> EdgeNetwork:
        return make_network(rng)

    def arrival_modulation(
            self, rng: np.random.Generator
    ) -> Optional[Callable[[int], float]]:
        return None

    def churn_schedule(self, net: EdgeNetwork, rng: np.random.Generator,
                       horizon_slots: int) -> List[ChurnEvent]:
        return []


@register
class BaselineScenario(Scenario):
    name = "baseline"
    description = ("paper Table-I instance: stationary Poisson arrivals, "
                   "static ED/ES topology, no faults")


@register
class BurstyMMPPScenario(Scenario):
    name = "bursty_mmpp"
    description = ("2-state MMPP arrivals: quiet 0.4x / burst 2.8x rate "
                   "switching, ~baseline mean load")

    def arrival_modulation(self, rng):
        return MMPPModulation(rng)


@register
class DiurnalScenario(Scenario):
    name = "diurnal"
    description = ("sinusoidal day/night load, amplitude 0.6, period 48 "
                   "slots, random phase per trial")

    def arrival_modulation(self, rng):
        return DiurnalModulation(rng)


@register
class FailureChurnScenario(Scenario):
    name = "failure_churn"
    description = ("rolling edge-server outages: every ES fails for a "
                   "window inside the horizon, staggered, then recovers")

    # fraction of the horizon each ES stays down
    down_frac = 0.25

    def churn_schedule(self, net, rng, horizon_slots):
        """Stagger one outage window per ES across the horizon.  Any
        placement concentrated on a single server is guaranteed to be
        hit by some window; a kappa-diverse backbone keeps serving."""
        ess = [int(v) for v in np.flatnonzero(net.is_es)]
        rng.shuffle(ess)
        down = max(2, int(self.down_frac * horizon_slots))
        events: List[ChurnEvent] = []
        for i, v in enumerate(ess):
            start = max(1, int((i + 0.5) * horizon_slots / (len(ess) + 1)))
            events.append(ChurnEvent(slot=start, node=v, action="fail"))
            events.append(ChurnEvent(slot=start + down, node=v,
                                     action="recover"))
        return events


@register
class SkewedMixScenario(Scenario):
    name = "skewed_mix"
    description = ("one task type dominates the arrival mix (3x rate), "
                   "the rest are throttled to 0.5x; dominant type "
                   "rotates with the trial seed")

    def build_application(self, rng, rate_multiplier=1.0):
        from repro.core import paper_params as pp
        mults = [0.5] * pp.N_TASK_TYPES
        mults[int(rng.integers(pp.N_TASK_TYPES))] = 3.0
        return make_application(rng, rate_multiplier=rate_multiplier,
                                type_rate_multipliers=mults)


@register
class TieredScenario(Scenario):
    name = "tiered"
    description = ("four-tier cloud/edge/device network: weak near-user "
                   "devices, metro EDs/ESs, one far high-capacity cloud")

    def build_network(self, rng):
        return make_tiered_network(rng)


# ----------------------------------------------------------------------
# scale_load family: population scaling (the vectorized engine's raison
# d'etre — the scalar loop ground to a halt past a few dozen users)
# ----------------------------------------------------------------------
SCALE_LOAD_USERS = (10, 25, 50, 100, 200, 500)


class ScaleLoadScenario(Scenario):
    """``scale_load_N``: N users on a two-tier metro whose node counts
    grow with the population (~4 users per ED / per ES vs. the
    baseline's 1.5), so both aggregate load and per-node contention
    rise with N.  Everything else is the paper's Table-I instance."""

    n_users = 10

    def _topo(self):
        n_eds = max(pp_defaults.N_EDS, -(-self.n_users // 4))
        n_ess = max(pp_defaults.N_ESS, -(-self.n_users // 4))
        return n_eds, n_ess

    def build_network(self, rng):
        n_eds, n_ess = self._topo()
        return make_network(rng, n_eds=n_eds, n_ess=n_ess,
                            n_users=self.n_users)


class ScaleLoadTieredScenario(ScaleLoadScenario):
    """``scale_load_tiered_N``: the same population sweep entering the
    four-tier cloud/edge/device topology (devices scale with users; one
    far cloud absorbs the overflow)."""

    def build_network(self, rng):
        n_eds, n_ess = self._topo()
        return make_tiered_network(rng,
                                   n_devices=max(4, -(-self.n_users // 8)),
                                   n_eds=n_eds, n_ess=n_ess,
                                   n_users=self.n_users)


for _n in SCALE_LOAD_USERS:
    register(type(f"ScaleLoad{_n}", (ScaleLoadScenario,), {
        "name": f"scale_load_{_n}", "n_users": _n,
        "description": (f"{_n} users on a proportionally scaled two-tier "
                        f"metro (scale_load family)")}))
    register(type(f"ScaleLoadTiered{_n}", (ScaleLoadTieredScenario,), {
        "name": f"scale_load_tiered_{_n}", "n_users": _n,
        "description": (f"{_n} users on a proportionally scaled four-tier "
                        f"cloud/edge/device network (scale_load family)")}))
