"""The proposal: static IP placement + Algorithm 1 online light-MS control.

Greedy per-slot deployment: repeatedly evaluate, for every feasible
incremental deployment (one instance of light MS m on node v), the
marginal drift-plus-penalty change

  dL(v,m) = eta * c_new  -  sum_{j captured} phi * H_j * (defer_j - dT_j)

where dT_j = transfer + propagation + g_{m,eps}(y+1) (QoS-aware next-hop
latency, eq. below Alg. 1) and defer_j is what task j faces without the
new instance (its best existing instance, or one slot of queueing).
Implement the deployment with the most negative dL, repeat until none
helps; finally route every waiting task to its min-dT instance (lines
14-16), updating parallelism as we go.

Interpretation notes vs. the paper's pseudocode are in
EXPERIMENTS.md §Algorithm 1 notes.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import static_placement as sp
from repro.core.effective_capacity import build_ec_maps
from repro.core.lyapunov import ETA, PHI_DEFAULT, VirtualQueues, ZETA
from repro.core.qos import qos_scores
from repro.core.simulator import SLOT_MS, Simulator

Y_MAX = 16  # practical parallelism cap (duration scales with y_eff)


class ProposalStrategy:
    """Two-tier: static core IP + effective-capacity Lyapunov controller."""

    name = "proposal"
    use_mean_estimate = False   # PropAvg ablation flips this

    def __init__(self, eps: float = 0.2, kappa: int = 8,
                 xi: float = sp.XI_DEFAULT, eta: float = ETA,
                 phi: float = PHI_DEFAULT, horizon_slots: int = 100):
        self.eps = eps
        self.kappa = kappa
        self.xi = xi
        self.eta = eta
        self.phi = phi
        self.horizon = horizon_slots
        self.queues = VirtualQueues(zeta=ZETA)

    # ------------------------------------------------------------------
    def place_core(self, app, net) -> Dict[int, np.ndarray]:
        self.app, self.net = app, net
        self.ec = build_ec_maps(app, self.eps)
        z, q = qos_scores(app, net)
        prob = sp.build_problem(app, net, z, q, kappa=self.kappa,
                                xi=self.xi, horizon_slots=self.horizon)
        return sp.solve(prob)

    # ------------------------------------------------------------------
    def admit(self, task):
        self.queues.admit(task.id)

    def task_done(self, task):
        self.queues.drop(task.id)

    def end_slot(self, t: float, sim: Simulator):
        # eq. (18) update for tasks still in flight
        for tid, task in sim.tasks.items():
            if task.finish is None:
                self.queues.update(tid, (t + 1) - task.t_gen,
                                   task.tt.deadline)

    # ------------------------------------------------------------------
    def _estimate(self, m: int, y: int) -> float:
        ec = self.ec[m]
        return ec.g_mean(y) if self.use_mean_estimate else ec.g(y)

    def _dt(self, sim, task, m, v, y, now) -> float:
        """Next-hop latency from `now`: remaining transfer+prop of inputs
        to v + QoS-aware processing estimate."""
        arrive = task.data_ready_at(m, sim.net, v)
        return max(0.0, arrive - now) + self._estimate(m, y)

    def assign_light(self, t: float, sim: Simulator,
                     waiting: List[tuple]) -> List[tuple]:
        app, net = sim.app, sim.net
        waiting = [(tid, m) for tid, m in waiting]
        if not waiting:
            return []

        # live instances and remaining capacity (busy instances are
        # reusable — g_{m,eps}(y+1) prices their contention)
        live = {i.id: i for i in sim.alive_instances(t)}
        for i in live.values():
            i.y_now = i.y_at(t)
        free_r = net.R - sim.light_resources_used(t)
        for m, xv in sim.x_cr.items():   # cores always reserve their share
            free_r -= xv[:, None] * app.ms(m).r[None, :]
        free_r = np.maximum(free_r, 0.0)

        new_instances: List = []

        def feasible(v, m):
            if v in sim.dead_nodes:
                return False
            return bool((free_r[v] >= app.ms(m).r).all())

        def candidates(ms_needed):
            return [(v, m) for m in ms_needed for v in range(net.n_nodes)
                    if feasible(v, m)]

        # ---------------- greedy deployment loop (Algorithm 1) ----------
        while True:
            ms_needed = {m for _, m in waiting}
            best = (0.0, None, None)
            for v, m in candidates(ms_needed):
                ms = app.ms(m)
                cost_new = self.eta * (ms.c_dp + ms.c_mt + ms.c_pl)
                gain = 0.0
                y_hyp = 0
                for tid, mm in waiting:
                    if mm != m:
                        continue
                    task = sim.tasks[tid]
                    dt_new = self._dt(sim, task, m, v, y_hyp + 1, t)
                    # defer option: best existing instance or 1-slot wait
                    defer = SLOT_MS + self._estimate(m, 1)
                    for inst in live.values():
                        if inst.m == m:
                            defer = min(defer, self._dt(
                                sim, task, m, inst.v, inst.y_now + 1, t))
                    for inst in new_instances:
                        if inst.m == m:
                            defer = min(defer, self._dt(
                                sim, task, m, inst.v, inst.y_now + 1, t))
                    if dt_new < defer:
                        h = self.queues.get(tid)
                        gain += self.phi * h * (defer - dt_new)
                        y_hyp += 1
                dl = cost_new - gain
                if dl < best[0]:
                    best = (dl, v, m)
            if best[1] is None:
                break
            _, v, m = best
            inst = sim.spawn_instance(v, m, t)
            new_instances.append(inst)
            free_r[v] -= app.ms(m).r

        # ---------------- routing (lines 14-16) -------------------------
        pool = list(live.values()) + new_instances
        still = []
        order = sorted(waiting,
                       key=lambda wm: -self.queues.get(wm[0]))
        for tid, m in order:
            task = sim.tasks[tid]
            opts = [i for i in pool if i.m == m and i.y_now < Y_MAX]
            if not opts:
                still.append((tid, m))
                continue
            dts = [self._dt(sim, task, m, i.v, i.y_now + 1, t)
                   for i in opts]
            k = int(np.argmin(dts))
            inst = opts[k]
            sim.commit_light(task, m, inst, now=t)
            inst.y_now += 1
        return still


class PropAvgStrategy(ProposalStrategy):
    """Ablation: identical two-tier logic, mean-value delay estimates."""

    name = "prop_avg"
    use_mean_estimate = True
