"""The proposal: static IP placement + Algorithm 1 online light-MS control.

Greedy per-slot deployment: repeatedly evaluate, for every feasible
incremental deployment (one instance of light MS m on node v), the
marginal drift-plus-penalty change

  dL(v,m) = eta * c_new  -  sum_{j captured} phi * H_j * (defer_j - dT_j)

where dT_j = transfer + propagation + g_{m,eps}(y+1) (QoS-aware next-hop
latency, eq. below Alg. 1) and defer_j is what task j faces without the
new instance (its best existing instance, or one slot of queueing).
Implement the deployment with the most negative dL, repeat until none
helps; finally route every waiting task to its min-dT instance (lines
14-16), updating parallelism as we go.

The controller is vectorized (EXPERIMENTS.md §Vectorized engine): per
slot it builds one data-readiness matrix per waiting stage (tasks x
nodes, via the affine routed-path tables), evaluates every candidate
deployment's dL against whole node vectors per greedy round, and keeps
the virtual queues H_j in a flat tid-indexed array.  The pre-PR scalar
control flow is preserved decision-for-decision; the scalar reference
in `repro.core.simulator_scalar` replays it loop-by-loop.

Interpretation notes vs. the paper's pseudocode are in
EXPERIMENTS.md §Algorithm 1 notes.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import static_placement as sp
from repro.core.effective_capacity import build_ec_maps
from repro.core.lyapunov import ETA, PHI_DEFAULT, ZETA
from repro.core.qos import qos_scores
from repro.core.simulator import SLOT_MS, Simulator

Y_MAX = 16  # practical parallelism cap (duration scales with y_eff)


class ArrayQueues:
    """Virtual queues H_j (eq. 18) in a flat tid-indexed array —
    numerically identical to the dict-backed
    :class:`repro.core.lyapunov.VirtualQueues`, but whole-cohort
    updates are one masked vector op per slot."""

    def __init__(self, zeta: float = ZETA):
        self.zeta = zeta
        self.h = np.full(256, zeta)

    def _ensure(self, n: int):
        cap = len(self.h)
        if n <= cap:
            return
        while cap < n:
            cap *= 2
        new = np.full(cap, self.zeta)
        new[:len(self.h)] = self.h
        self.h = new

    def admit(self, tid: int):
        self._ensure(tid + 1)
        self.h[tid] = self.zeta

    def get(self, tid: int) -> float:
        return float(self.h[tid]) if tid < len(self.h) else self.zeta

    def get_many(self, tids: np.ndarray) -> np.ndarray:
        self._ensure(int(tids.max()) + 1 if len(tids) else 0)
        return self.h[tids]

    def update_many(self, tids: np.ndarray, latency: np.ndarray,
                    deadline: np.ndarray):
        """Eq. (18): H <- max{H + T_j(t) - D_n, zeta}, batched."""
        self._ensure(int(tids.max()) + 1 if len(tids) else 0)
        self.h[tids] = np.maximum(self.h[tids] + latency - deadline,
                                  self.zeta)

    def drop(self, tid: int):
        pass  # finished tasks simply stop being updated/queried


class ProposalStrategy:
    """Two-tier: static core IP + effective-capacity Lyapunov controller."""

    name = "proposal"
    use_mean_estimate = False   # PropAvg ablation flips this

    def __init__(self, eps: float = 0.2, kappa: int = 8,
                 xi: float = sp.XI_DEFAULT, eta: float = ETA,
                 phi: float = PHI_DEFAULT, horizon_slots: int = 100,
                 bytes_per_param: float | None = None):
        self.eps = eps
        self.kappa = kappa
        self.xi = xi
        self.eta = eta
        self.phi = phi
        self.horizon = horizon_slots
        # weight bytes per parameter for the core services' memory
        # demand (None = the bf16 calibration; quantized re-runs pass
        # models.quantize.bytes_per_param(fmt))
        self.bytes_per_param = bytes_per_param
        self.queues = ArrayQueues(zeta=ZETA)

    # ------------------------------------------------------------------
    def place_core(self, app, net) -> Dict[int, np.ndarray]:
        self.app, self.net = app, net
        self.ec = build_ec_maps(app, self.eps)
        # per light MS: the g_{m,eps}(y) table (or the mean-value table
        # for the PropAvg ablation) and its parallelism cap
        self._g_tab = {
            m: (ec.mean_table if self.use_mean_estimate else ec.table)
            for m, ec in self.ec.items()}
        self._y_cap = {m: ec.y_max for m, ec in self.ec.items()}
        z, q = qos_scores(app, net)
        prob = sp.build_problem(app, net, z, q, kappa=self.kappa,
                                xi=self.xi, horizon_slots=self.horizon,
                                bytes_per_param=self.bytes_per_param)
        return sp.solve(prob)

    # ------------------------------------------------------------------
    def admit(self, task):
        self.queues.admit(task.id)

    def task_done(self, task):
        self.queues.drop(task.id)

    def end_slot(self, t: float, sim: Simulator):
        # eq. (18) update for tasks still in flight, as one vector op
        n = len(sim.tasks)
        ids = np.flatnonzero(sim.task_open[:n])
        if len(ids):
            self.queues.update_many(ids,
                                    (t + 1.0) - sim.task_t_gen[ids],
                                    sim.task_deadline[ids])

    # ------------------------------------------------------------------
    def _g(self, m: int, y) -> np.ndarray:
        """g_{m,eps}(y) table lookup, vectorized over y (clipped like
        ECMap.g)."""
        return self._g_tab[m][np.minimum(y, self._y_cap[m]) - 1]

    def assign_light(self, t: float, sim: Simulator,
                     waiting: List[tuple]) -> List[tuple]:
        app, net, store = sim.app, sim.net, sim.store
        waiting = [(tid, m) for tid, m in waiting]
        if not waiting:
            return []

        # live instances and remaining capacity (busy instances are
        # reusable — g_{m,eps}(y+1) prices their contention)
        alive = sim.alive_light_idx(t)
        store.refresh_y(alive, t)
        free_r = net.R - sim.light_resources_used(t)
        for m, xv in sim.x_cr.items():   # cores always reserve their share
            free_r -= xv[:, None] * app.ms(m).r[None, :]
        free_r = np.maximum(free_r, 0.0)

        # ---------------- per-stage matrices (one build per slot) -------
        stages = sorted({m for _, m in waiting})
        by_m = {m: [j for j, (_, mm) in enumerate(waiting) if mm == m]
                for m in stages}
        h_all = self.queues.get_many(
            np.array([tid for tid, _ in waiting], dtype=np.int64))
        # wait[m][row, v] = max(0, data_ready_at(m, v) - t): the
        # transfer+propagation half of dT for every (task, node) pair
        wait = {}
        row_of = {}
        for m in stages:
            rows = [np.maximum(
                sim.tasks[waiting[j][0]].data_ready_at_nodes(m, net) - t,
                0.0) for j in by_m[m]]
            wait[m] = np.stack(rows)
            row_of[m] = {j: r for r, j in enumerate(by_m[m])}
        # instance pools per stage (spawn order), and the defer vector:
        # best dT via an existing instance, floored by 1-slot queueing
        pools = {m: [int(i) for i in alive[store.m[alive] == m]]
                 for m in stages}
        defer = {}
        for m in stages:
            d = np.full(len(by_m[m]),
                        SLOT_MS + float(self._g(m, np.int64(1))))
            if pools[m]:
                pa = np.array(pools[m])
                dts = (wait[m][:, store.v[pa]]
                       + self._g(m, store.y_now[pa] + 1)[None, :])
                d = np.minimum(d, dts.min(axis=1))
            defer[m] = d

        dead = np.fromiter(sim.dead_nodes, dtype=np.int64) \
            if sim.dead_nodes else None

        # ---------------- greedy deployment loop (Algorithm 1) ----------
        while True:
            best_dl, best_v, best_m = 0.0, None, None
            for m in stages:
                ms = app.ms(m)
                feas = (free_r >= ms.r[None, :]).all(axis=1)
                if dead is not None:
                    feas[dead] = False
                vv = np.flatnonzero(feas)
                if not len(vv):
                    continue
                cost_new = self.eta * (ms.c_dp + ms.c_mt + ms.c_pl)
                w_sub = wait[m][:, vv]                       # J x F
                d_m = defer[m]
                y_hyp = np.zeros(len(vv), dtype=np.int64)
                gain = np.zeros(len(vv))
                # only tasks capturable on at least one candidate node
                # can move y_hyp or gain (g is increasing in y, so
                # wait + g(1) is a lower bound on their dT)
                g1 = float(self._g(m, np.int64(1)))
                js = np.flatnonzero(
                    ((w_sub + g1) < d_m[:, None]).any(axis=1))
                for j in js:
                    dt_new = w_sub[j] + self._g(m, y_hyp + 1)
                    cap = dt_new < d_m[j]
                    if cap.any():
                        gain = np.where(
                            cap,
                            gain + self.phi * h_all[by_m[m][j]]
                            * (d_m[j] - dt_new),
                            gain)
                        y_hyp += cap
                dl = cost_new - gain
                k = int(np.argmin(dl))
                if dl[k] < best_dl:
                    best_dl, best_v, best_m = float(dl[k]), int(vv[k]), m
            if best_v is None:
                break
            inst = sim.spawn_instance(best_v, best_m, t)
            pools[best_m].append(inst)
            free_r[best_v] -= app.ms(best_m).r
            # the fresh instance (y_now = 0) tightens only its stage's
            # defer vector
            defer[best_m] = np.minimum(
                defer[best_m],
                wait[best_m][:, best_v]
                + float(self._g(best_m, np.int64(1))))

        # ---------------- routing (lines 14-16) -------------------------
        order = sorted(range(len(waiting)), key=lambda j: -h_all[j])
        still = []
        pool_arr = {m: np.array(pools[m], dtype=np.int64) for m in stages}
        for j in order:
            tid, m = waiting[j]
            pa = pool_arr[m]
            if len(pa):
                ok = store.y_now[pa] < Y_MAX
                cand = pa[ok]
            else:
                cand = pa
            if not len(cand):
                still.append((tid, m))
                continue
            dts = (wait[m][row_of[m][j], store.v[cand]]
                   + self._g(m, store.y_now[cand] + 1))
            inst = int(cand[int(np.argmin(dts))])
            sim.commit_light(sim.tasks[tid], m, inst, now=t)
            store.y_now[inst] += 1
        return still


class PropAvgStrategy(ProposalStrategy):
    """Ablation: identical two-tier logic, mean-value delay estimates."""

    name = "prop_avg"
    use_mean_estimate = True
