"""Event-driven slot simulator for the paper's evaluation (Sec. IV).

Continuous-time event engine (heapq) for stage completions; control
decisions at 1 ms slot boundaries:

* core MS stages dispatch immediately on readiness to the min-finish-time
  instance (static placement fixed by the strategy);
* light MS stages queue and are assigned by the strategy's per-slot
  controller (Algorithm 1 for the proposal; RR / GA / mean-value for the
  baselines);
* light-service durations are *sampled* from the Gamma contention model —
  strategies only see their own estimates (effective-capacity or mean).

Costs follow eqs (6)-(7); metrics: completion rate, on-time rate, cost.

The hot paths are vectorized over flat numpy arrays (EXPERIMENTS.md
§Vectorized engine): arrivals are ONE Poisson draw per slot over the
users x task-type grid (`draw_arrivals`), light-instance state lives in
column arrays (`InstanceStore`) so aliveness / resource usage / cost
accrual are masked reductions, and data-readiness is evaluated for
whole candidate-node vectors at once via the affine routed-path tables
of `EdgeNetwork.prepare`.  `repro.core.simulator_scalar` keeps the
fixed-semantics scalar reference engine that consumes the identical RNG
stream — `benchmarks/sim_bench.py` checks the two agree trial-for-trial
and tracks the speedup.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Application, TaskType
from repro.core.network import EdgeNetwork

SLOT_MS = 1.0

# commit_light service sampling: blocks of ~3x the expected slot count
# are drawn until the cumulative service covers the workload; after this
# many blocks we raise — the pre-vectorization engine silently scheduled
# the task to finish early instead, shortening its true service time
MAX_SERVICE_BLOCKS = 1024


@dataclass(frozen=True)
class ChurnEvent:
    """A scheduled node state change: at slot `slot`, `node` fails or
    recovers.  Generalizes the old single (fail_node, fail_at) pair to
    multi-node failure/recovery schedules (scenario registry)."""
    slot: int
    node: int
    action: str                  # "fail" | "recover"

    def __post_init__(self):
        assert self.action in ("fail", "recover"), self.action


@dataclass
class Task:
    id: int
    tt: TaskType
    user: int
    t_gen: float
    ed: int                      # entry node
    # when the wireless uplink of the input payload completes; t_gen is
    # the generation instant (E2E latency reference).  Optional so
    # hand-built Tasks degrade to "payload present at t_gen".
    uplink_done: Optional[float] = None
    done: Dict[int, float] = field(default_factory=dict)   # ms -> finish t
    loc: Dict[int, int] = field(default_factory=dict)      # ms -> node
    dispatched: set = field(default_factory=set)
    finish: Optional[float] = None

    @property
    def deadline_abs(self) -> float:
        return self.t_gen + self.tt.deadline

    def ready_stages(self) -> List[int]:
        out = []
        for m in self.tt.ms_ids:
            if m in self.done or m in self.dispatched:
                continue
            if all(p in self.done for p in self.tt.parents(m)):
                out.append(m)
        return out

    def data_ready_at(self, m: int, net: EdgeNetwork, v: int) -> float:
        """When all of m's input data can be present on node v."""
        parents = self.tt.parents(m)
        if not parents:
            # input payload sits at the entry ED once the uplink has
            # finished (NOT at t_gen: the old code re-set t_gen to the
            # generation instant after construction, so source stages
            # saw their data one uplink too early); payload moves ED->v
            up = self.t_gen if self.uplink_done is None else self.uplink_done
            return up + net.path_ms(self.ed, v, self.tt.payload)
        t = 0.0
        for p in parents:
            tp = self.done[p] + net.path_ms(self.loc[p], v,
                                            self._b(p))
            t = max(t, tp)
        return t

    def data_ready_at_nodes(self, m: int, net: EdgeNetwork,
                            nodes: Optional[np.ndarray] = None
                            ) -> np.ndarray:
        """Vector of `data_ready_at(m, net, v)` over `nodes` (all nodes
        when omitted); elementwise identical to the scalar method."""
        def route_row(src: int, mb: float) -> np.ndarray:
            if nodes is None:
                return net.path_ms_row(src, mb)
            return (mb * net.path_invbw[src, nodes]
                    + net.path_prop[src, nodes])

        parents = self.tt.parents(m)
        if not parents:
            up = self.t_gen if self.uplink_done is None else self.uplink_done
            return up + route_row(self.ed, self.tt.payload)
        acc = None
        for p in parents:
            row = self.done[p] + route_row(self.loc[p], self._b(p))
            acc = row if acc is None else np.maximum(acc, row)
        return acc

    def _b(self, m):  # filled by simulator (app reference shortcut)
        return self._app.ms(m).b


# ----------------------------------------------------------------------
# Shared stochastic kernels (vectorized engine AND the scalar reference
# call these, so both consume the identical RNG stream)
# ----------------------------------------------------------------------
def draw_arrivals(rng: np.random.Generator, net: EdgeNetwork,
                  app: Application, t_slot: int, mult: float
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                             np.ndarray]:
    """Batched per-slot arrival sampling: one Poisson draw over the
    users x task-type grid, one uniform batch of generation offsets,
    one fading batch of uplink delays.  Tasks are ordered (user-major,
    type-minor) to match the old nested-loop generation order."""
    rates = np.array([tt.rate for tt in app.task_types])
    lam = np.broadcast_to(rates * (mult * SLOT_MS),
                          (net.n_users, len(rates)))
    counts = rng.poisson(lam)
    total = int(counts.sum())
    if total == 0:
        z = np.zeros(0)
        return np.zeros(0, dtype=int), np.zeros(0, dtype=int), z, z
    u_idx = np.repeat(np.arange(net.n_users), counts.sum(axis=1))
    tt_idx = np.repeat(np.tile(np.arange(len(rates)), net.n_users),
                       counts.ravel())
    t_gen = t_slot + rng.uniform(0.0, SLOT_MS, size=total)
    payloads = np.array([tt.payload for tt in app.task_types])[tt_idx]
    uplink = net.sample_uplink_ms_batch(rng, u_idx, payloads)
    return u_idx, tt_idx, t_gen, uplink


def sample_service_ms(rng: np.random.Generator, ms, work: float) -> float:
    """True light-service duration from the paper's cumulative service
    process F(0,t) = sum_tau f_m(tau) with i.i.d. Gamma per-slot rates:
    the task (admitted at concurrency y_eff, so `work` = y_eff * a)
    completes in the first slot where the cumulative service reaches its
    scaled workload.  Blocks are drawn until the workload is covered —
    raising after MAX_SERVICE_BLOCKS rather than ever silently
    scheduling an early finish."""
    n_exp = max(4, int(3 * work / max(ms.f_mean, 1e-6)) + 4)
    dur = 0.0
    for _ in range(MAX_SERVICE_BLOCKS):
        f = np.maximum(rng.gamma(ms.f_shape, ms.f_scale, size=n_exp), 1e-6)
        cum = np.cumsum(f) * SLOT_MS
        if cum[-1] >= work:
            i = int(np.searchsorted(cum, work))
            prev = cum[i - 1] if i else 0.0
            return dur + i * SLOT_MS + (work - prev) / f[i]
        work -= cum[-1]
        dur += n_exp * SLOT_MS
    raise RuntimeError(
        f"cumulative Gamma service for MS {ms.name!r} did not cover the "
        f"workload after {MAX_SERVICE_BLOCKS} blocks of {n_exp} slots — "
        f"the service-rate parameters are degenerate for this workload")


class InstanceStore:
    """Flat column-array state for light-MS instances (replaces the
    per-object ``LightInstance`` list): node, service, birth, busy
    horizon and current-slot parallelism live in numpy arrays so
    aliveness, resource usage and cost accrual reduce over masks; the
    per-instance in-flight finish times stay as small pruned lists."""

    _COLS = ("v", "m", "born", "busy_until", "persistent", "y_now")

    def __init__(self, cap: int = 64):
        self.n = 0
        self.v = np.zeros(cap, dtype=np.int64)
        self.m = np.zeros(cap, dtype=np.int64)
        self.born = np.zeros(cap)
        self.busy_until = np.zeros(cap)
        self.persistent = np.zeros(cap, dtype=bool)
        self.y_now = np.zeros(cap, dtype=np.int64)
        self.active: List[List[float]] = []

    def _grow(self):
        cap = max(64, 2 * len(self.v))
        for name in self._COLS:
            arr = getattr(self, name)
            new = np.zeros(cap, dtype=arr.dtype)
            new[:self.n] = arr[:self.n]
            setattr(self, name, new)

    def spawn(self, v: int, m: int, born: float,
              persistent: bool = False) -> int:
        if self.n == len(self.v):
            self._grow()
        i = self.n
        self.v[i] = v
        self.m[i] = m
        self.born[i] = born
        self.busy_until[i] = 0.0
        self.persistent[i] = persistent
        self.y_now[i] = 0
        self.active.append([])
        self.n += 1
        return i

    def y_at(self, i: int, now: float) -> int:
        """Concurrent tasks on instance i at time `now` (prunes
        finished entries)."""
        lst = [f for f in self.active[i] if f > now]
        self.active[i] = lst
        return len(lst)

    def refresh_y(self, idx: np.ndarray, now: float) -> None:
        """Recompute y_now for the given instances at slot time."""
        for i in idx:
            self.y_now[i] = self.y_at(int(i), now)

    def alive_mask(self, now: float, dead_nodes) -> np.ndarray:
        """Alive = persistent, still busy, or spawned within the last
        slot — and not homed on a failed node."""
        n = self.n
        alive = (self.persistent[:n] | (self.busy_until[:n] > now)
                 | (self.born[:n] >= now - SLOT_MS))
        if dead_nodes:
            alive &= ~np.isin(self.v[:n], np.fromiter(
                dead_nodes, dtype=np.int64))
        return alive


class Simulator:
    def __init__(self, app: Application, net: EdgeNetwork, strategy,
                 rng: np.random.Generator, horizon_slots: int = 100,
                 drain_slots: int = 400, fail_node: Optional[int] = None,
                 fail_at: Optional[int] = None,
                 churn: Optional[Sequence[ChurnEvent]] = None,
                 arrival_modulation: Optional[
                     Callable[[int], float]] = None):
        self.app = app
        self.net = net
        self.strategy = strategy
        self.rng = rng
        self.horizon = horizon_slots
        self.drain = drain_slots
        # fault-injection (validates the kappa diversity constraint C6):
        # a churn schedule of fail/recover events per node — a failed
        # node's core instances stop serving and no light instance can
        # be (re)placed there until (if ever) it recovers.  The legacy
        # (fail_node, fail_at) pair is folded into the schedule.
        events = list(churn or [])
        if fail_node is not None and fail_at is not None:
            events.append(ChurnEvent(slot=fail_at, node=fail_node,
                                     action="fail"))
        self._churn_by_slot: Dict[int, List[ChurnEvent]] = {}
        for ev in events:
            self._churn_by_slot.setdefault(ev.slot, []).append(ev)
        # per-slot multiplier on mean arrival rates (MMPP / diurnal
        # scenarios); called once per generation slot, in order
        self.arrival_modulation = arrival_modulation
        self.dead_nodes: set = set()
        self.tasks: Dict[int, Task] = {}
        self.events: list = []      # (time, seq, task_id, ms)
        self._seq = itertools.count()
        self._task_ids = itertools.count()
        self.waiting: List[tuple] = []   # (task_id, ms) light stages queued
        # core state
        self.x_cr: Dict[int, np.ndarray] = {}
        self.core_free: Dict[tuple, np.ndarray] = {}
        self._core_hosts: Dict[int, np.ndarray] = {}
        # light state
        self.store = InstanceStore()
        self.light_cost = 0.0
        self._prev_alive_counts: Optional[np.ndarray] = None
        # (M, K) stacked per-MS resource requirement rows
        self._r_stack = np.stack([ms.r for ms in app.services])
        # flat tid-indexed task ledgers for vectorized controllers and
        # metrics (mirrors the Task objects)
        cap = 256
        self.task_t_gen = np.zeros(cap)
        self.task_deadline = np.zeros(cap)
        self.task_finish = np.full(cap, np.nan)
        self.task_open = np.zeros(cap, dtype=bool)
        # metrics
        self.n_generated = 0

    # ------------------------------------------------------------------
    # Setup
    # ------------------------------------------------------------------
    def place_core(self):
        self.x_cr = self.strategy.place_core(self.app, self.net)
        for m, xv in self.x_cr.items():
            for v in range(self.net.n_nodes):
                if xv[v] > 0:
                    self.core_free[(v, m)] = np.zeros(int(xv[v]))
            self._core_hosts[m] = np.flatnonzero(np.asarray(xv) > 0)
        # capacity left for lights
        used = np.zeros_like(self.net.R)
        for m, xv in self.x_cr.items():
            used += xv[:, None] * self.app.ms(m).r[None, :]
        self.R_lt = self.net.R - used

    def core_cost(self) -> float:
        total = 0.0
        for m, xv in self.x_cr.items():
            ms = self.app.ms(m)
            total += (ms.c_dp + ms.c_mt * self.horizon) * xv.sum()
        return float(total)

    # ------------------------------------------------------------------
    # Arrivals
    # ------------------------------------------------------------------
    def _ensure_task_cap(self, n: int):
        cap = len(self.task_t_gen)
        if n <= cap:
            return
        while cap < n:
            cap *= 2
        for name in ("task_t_gen", "task_deadline", "task_finish",
                     "task_open"):
            arr = getattr(self, name)
            fill = np.nan if name == "task_finish" else 0
            new = np.full(cap, fill, dtype=arr.dtype)
            new[:len(arr)] = arr
            setattr(self, name, new)

    def _generate(self, t_slot: int):
        mult = (self.arrival_modulation(t_slot)
                if self.arrival_modulation is not None else 1.0)
        u_idx, tt_idx, t_gen, uplink = draw_arrivals(
            self.rng, self.net, self.app, t_slot, mult)
        total = len(u_idx)
        if total == 0:
            return
        self._ensure_task_cap(len(self.tasks) + total)
        for k in range(total):
            tid = next(self._task_ids)
            tt = self.app.task_types[int(tt_idx[k])]
            task = Task(id=tid, tt=tt, user=int(u_idx[k]),
                        t_gen=float(t_gen[k]),
                        ed=int(self.net.user_ed[u_idx[k]]),
                        uplink_done=float(t_gen[k] + uplink[k]))
            task._app = self.app
            self.tasks[tid] = task
            self.task_t_gen[tid] = task.t_gen
            self.task_deadline[tid] = tt.deadline
            self.task_open[tid] = True
            self.n_generated += 1
            if hasattr(self.strategy, "admit"):
                self.strategy.admit(task)
            self._advance_task(task, now=task.uplink_done)

    # ------------------------------------------------------------------
    # DAG progression
    # ------------------------------------------------------------------
    def _advance_task(self, task: Task, now: float):
        for m in task.ready_stages():
            if self.app.ms(m).is_core:
                self._dispatch_core(task, m, now)
            else:
                task.dispatched.add(m)
                self.waiting.append((task.id, m))

    def _dispatch_core(self, task: Task, m: int, now: float):
        ms = self.app.ms(m)
        hosts = self._core_hosts.get(m)
        best = None
        if hosts is not None and len(hosts):
            ready_nodes = task.data_ready_at_nodes(m, self.net, hosts)
            proc = ms.a / ms.f_det
            for h in range(len(hosts)):
                v = int(hosts[h])
                if v in self.dead_nodes:
                    continue
                ready = max(float(ready_nodes[h]), now)
                free = self.core_free[(v, m)]
                i = int(np.argmin(free))
                start = max(ready, free[i])
                fin = start + proc
                if best is None or fin < best[0]:
                    best = (fin, v, i)
        if best is None:   # no instance anywhere: task cannot complete
            task.dispatched.add(m)
            return
        fin, v, i = best
        self.core_free[(v, m)][i] = fin
        task.dispatched.add(m)
        heapq.heappush(self.events,
                       (fin, next(self._seq), task.id, m, v))

    def commit_light(self, task: Task, m: int, inst: int, now: float):
        """Strategy decided: run stage m of task on store instance
        index `inst`; samples the true Gamma service duration."""
        ms = self.app.ms(m)
        store = self.store
        v = int(store.v[inst])
        ready = max(task.data_ready_at(m, self.net, v), now)
        y_eff = store.y_at(inst, ready) + 1
        dur = sample_service_ms(self.rng, ms, ms.a * y_eff)
        fin = ready + dur
        store.busy_until[inst] = max(store.busy_until[inst], fin)
        store.active[inst].append(fin)
        heapq.heappush(self.events,
                       (fin, next(self._seq), task.id, m, v))

    def spawn_instance(self, v: int, m: int, now: float,
                       persistent: bool = False) -> int:
        assert v not in self.dead_nodes, "cannot place on a failed node"
        return self.store.spawn(v, m, now, persistent)

    # ------------------------------------------------------------------
    # Per-slot accounting
    # ------------------------------------------------------------------
    def alive_light_idx(self, now: float) -> np.ndarray:
        """Indices of alive light instances, in spawn order."""
        return np.flatnonzero(self.store.alive_mask(now, self.dead_nodes))

    def light_resources_used(self, now: float) -> np.ndarray:
        used = np.zeros_like(self.net.R)
        idx = self.alive_light_idx(now)
        if len(idx):
            np.add.at(used, self.store.v[idx],
                      self._r_stack[self.store.m[idx]])
        return used

    def _accrue_light_cost(self, t: float):
        idx = self.alive_light_idx(t)
        n_ms = len(self.app.services)
        counts = np.bincount(self.store.v[idx] * n_ms + self.store.m[idx],
                             minlength=self.net.n_nodes * n_ms)
        prev = self._prev_alive_counts
        if prev is None:
            prev = np.zeros_like(counts)
        # iterate occupied (v, m) cells in sorted order (the scalar
        # reference iterates sorted too, so the float accumulation
        # order — hence the cost bits — matches exactly)
        for k in np.flatnonzero(counts):
            m = int(k) % n_ms
            ms = self.app.ms(m)
            c = int(counts[k])
            newly = max(0, c - int(prev[k]))
            self.light_cost += ms.c_dp * newly + (ms.c_mt + ms.c_pl) * c
        self._prev_alive_counts = counts

    # ------------------------------------------------------------------
    # Main loop
    # ------------------------------------------------------------------
    def run(self) -> dict:
        self.place_core()
        if hasattr(self.strategy, "init_light"):
            self.strategy.init_light(self)
        t_end = self.horizon + self.drain
        for t_slot in range(t_end):
            for ev in self._churn_by_slot.get(t_slot, ()):
                if ev.action == "fail":
                    self.dead_nodes.add(ev.node)
                else:
                    self.dead_nodes.discard(ev.node)
            if t_slot < self.horizon:
                self._generate(t_slot)
            # controller at slot boundary
            if self.waiting:
                still = self.strategy.assign_light(float(t_slot), self,
                                                   self.waiting)
                self.waiting = still
            self._accrue_light_cost(float(t_slot))
            # drain events due this slot
            while self.events and self.events[0][0] < t_slot + 1:
                fin, _, tid, m, v = heapq.heappop(self.events)
                task = self.tasks[tid]
                task.done[m] = fin
                task.loc[m] = v
                if m == task.tt.sink():
                    task.finish = fin
                    self.task_finish[tid] = fin
                    self.task_open[tid] = False
                    if hasattr(self.strategy, "task_done"):
                        self.strategy.task_done(task)
                else:
                    self._advance_task(task, now=fin)
            if hasattr(self.strategy, "end_slot"):
                self.strategy.end_slot(float(t_slot), self)
            if (t_slot >= self.horizon and not self.events
                    and not self.waiting):
                break
        return self.metrics()

    def metrics(self) -> dict:
        n_tasks = len(self.tasks)
        finish = self.task_finish[:n_tasks]
        t_gen = self.task_t_gen[:n_tasks]
        fin_mask = ~np.isnan(finish)
        lat = finish[fin_mask] - t_gen[fin_mask]
        on_time = int((lat <= self.task_deadline[:n_tasks][fin_mask]).sum())
        n = max(self.n_generated, 1)
        return {
            "strategy": getattr(self.strategy, "name", "?"),
            "generated": self.n_generated,
            "completed": int(fin_mask.sum()) / n,
            "on_time": on_time / n,
            "core_cost": self.core_cost(),
            "light_cost": self.light_cost,
            "total_cost": self.core_cost() + self.light_cost,
            "mean_latency_ms": float(np.mean(lat)) if len(lat)
            else float("nan"),
            "p95_latency_ms": float(np.percentile(lat, 95)) if len(lat)
            else float("nan"),
        }
