"""Event-driven slot simulator for the paper's evaluation (Sec. IV).

Continuous-time event engine (heapq) for stage completions; control
decisions at 1 ms slot boundaries:

* core MS stages dispatch immediately on readiness to the min-finish-time
  instance (static placement fixed by the strategy);
* light MS stages queue and are assigned by the strategy's per-slot
  controller (Algorithm 1 for the proposal; RR / GA / mean-value for the
  baselines);
* light-service durations are *sampled* from the Gamma contention model —
  strategies only see their own estimates (effective-capacity or mean).

Costs follow eqs (6)-(7); metrics: completion rate, on-time rate, cost.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.graph import Application, TaskType
from repro.core.network import EdgeNetwork

SLOT_MS = 1.0


@dataclass(frozen=True)
class ChurnEvent:
    """A scheduled node state change: at slot `slot`, `node` fails or
    recovers.  Generalizes the old single (fail_node, fail_at) pair to
    multi-node failure/recovery schedules (scenario registry)."""
    slot: int
    node: int
    action: str                  # "fail" | "recover"

    def __post_init__(self):
        assert self.action in ("fail", "recover"), self.action


@dataclass
class Task:
    id: int
    tt: TaskType
    user: int
    t_gen: float
    ed: int                      # entry node
    done: Dict[int, float] = field(default_factory=dict)   # ms -> finish t
    loc: Dict[int, int] = field(default_factory=dict)      # ms -> node
    dispatched: set = field(default_factory=set)
    finish: Optional[float] = None

    @property
    def deadline_abs(self) -> float:
        return self.t_gen + self.tt.deadline

    def ready_stages(self) -> List[int]:
        out = []
        for m in self.tt.ms_ids:
            if m in self.done or m in self.dispatched:
                continue
            if all(p in self.done for p in self.tt.parents(m)):
                out.append(m)
        return out

    def data_ready_at(self, m: int, net: EdgeNetwork, v: int) -> float:
        """When all of m's input data can be present on node v."""
        parents = self.tt.parents(m)
        if not parents:
            # input payload sits at the entry ED after uplink (t_gen
            # already includes uplink; payload moves ED -> v)
            return self.t_gen + net.path_ms(self.ed, v, self.tt.payload)
        t = 0.0
        for p in parents:
            tp = self.done[p] + net.path_ms(self.loc[p], v,
                                            self._b(p))
            t = max(t, tp)
        return t

    def _b(self, m):  # filled by simulator (app reference shortcut)
        return self._app.ms(m).b


@dataclass
class LightInstance:
    id: int
    v: int
    m: int
    born: float
    busy_until: float = 0.0
    y_now: int = 0                                   # assigned this slot
    persistent: bool = False                         # static allocation
    active: List[float] = field(default_factory=list)  # finish times

    def y_at(self, now: float) -> int:
        """Concurrent tasks on this instance at time `now`."""
        self.active = [f for f in self.active if f > now]
        return len(self.active)


class Simulator:
    def __init__(self, app: Application, net: EdgeNetwork, strategy,
                 rng: np.random.Generator, horizon_slots: int = 100,
                 drain_slots: int = 400, fail_node: Optional[int] = None,
                 fail_at: Optional[int] = None,
                 churn: Optional[Sequence[ChurnEvent]] = None,
                 arrival_modulation: Optional[
                     Callable[[int], float]] = None):
        self.app = app
        self.net = net
        self.strategy = strategy
        self.rng = rng
        self.horizon = horizon_slots
        self.drain = drain_slots
        # fault-injection (validates the kappa diversity constraint C6):
        # a churn schedule of fail/recover events per node — a failed
        # node's core instances stop serving and no light instance can
        # be (re)placed there until (if ever) it recovers.  The legacy
        # (fail_node, fail_at) pair is folded into the schedule.
        events = list(churn or [])
        if fail_node is not None and fail_at is not None:
            events.append(ChurnEvent(slot=fail_at, node=fail_node,
                                     action="fail"))
        self._churn_by_slot: Dict[int, List[ChurnEvent]] = {}
        for ev in events:
            self._churn_by_slot.setdefault(ev.slot, []).append(ev)
        # per-slot multiplier on mean arrival rates (MMPP / diurnal
        # scenarios); called once per generation slot, in order
        self.arrival_modulation = arrival_modulation
        self.dead_nodes: set = set()
        self.tasks: Dict[int, Task] = {}
        self.events: list = []      # (time, seq, task_id, ms)
        self._seq = itertools.count()
        self._task_ids = itertools.count()
        self.waiting: List[tuple] = []   # (task_id, ms) light stages queued
        # core state
        self.x_cr: Dict[int, np.ndarray] = {}
        self.core_free: Dict[tuple, np.ndarray] = {}
        # light state
        self.instances: List[LightInstance] = []
        self._inst_ids = itertools.count()
        self.light_cost = 0.0
        self.prev_alive: Dict[tuple, int] = {}
        # metrics
        self.n_generated = 0

    # ------------------------------------------------------------------
    # Setup
    # ------------------------------------------------------------------
    def place_core(self):
        self.x_cr = self.strategy.place_core(self.app, self.net)
        for m, xv in self.x_cr.items():
            for v in range(self.net.n_nodes):
                if xv[v] > 0:
                    self.core_free[(v, m)] = np.zeros(int(xv[v]))
        # capacity left for lights
        used = np.zeros_like(self.net.R)
        for m, xv in self.x_cr.items():
            used += xv[:, None] * self.app.ms(m).r[None, :]
        self.R_lt = self.net.R - used

    def core_cost(self) -> float:
        total = 0.0
        for m, xv in self.x_cr.items():
            ms = self.app.ms(m)
            total += (ms.c_dp + ms.c_mt * self.horizon) * xv.sum()
        return float(total)

    # ------------------------------------------------------------------
    # Arrivals
    # ------------------------------------------------------------------
    def _generate(self, t_slot: int):
        mult = (self.arrival_modulation(t_slot)
                if self.arrival_modulation is not None else 1.0)
        for u in range(self.net.n_users):
            for tt in self.app.task_types:
                n = self.rng.poisson(tt.rate * mult * SLOT_MS)
                for _ in range(n):
                    t_gen = t_slot + self.rng.uniform(0, SLOT_MS)
                    tid = next(self._task_ids)
                    up = self.net.sample_uplink_ms(self.rng, u, tt.payload)
                    task = Task(id=tid, tt=tt, user=u,
                                t_gen=t_gen + up,
                                ed=int(self.net.user_ed[u]))
                    task.t_gen = t_gen  # E2E measured from generation
                    task._uplink_done = t_gen + up
                    task._app = self.app
                    self.tasks[tid] = task
                    self.n_generated += 1
                    if hasattr(self.strategy, "admit"):
                        self.strategy.admit(task)
                    self._advance_task(task, now=t_gen + up)

    # ------------------------------------------------------------------
    # DAG progression
    # ------------------------------------------------------------------
    def _advance_task(self, task: Task, now: float):
        for m in task.ready_stages():
            if self.app.ms(m).is_core:
                self._dispatch_core(task, m, now)
            else:
                task.dispatched.add(m)
                self.waiting.append((task.id, m))

    def _dispatch_core(self, task: Task, m: int, now: float):
        ms = self.app.ms(m)
        best = None
        for (v, mm), free in self.core_free.items():
            if mm != m or v in self.dead_nodes:
                continue
            ready = max(task.data_ready_at(m, self.net, v), now)
            i = int(np.argmin(free))
            start = max(ready, free[i])
            fin = start + ms.a / ms.f_det
            if best is None or fin < best[0]:
                best = (fin, v, i)
        if best is None:   # no instance anywhere: task cannot complete
            task.dispatched.add(m)
            return
        fin, v, i = best
        self.core_free[(v, m)][i] = fin
        task.dispatched.add(m)
        heapq.heappush(self.events,
                       (fin, next(self._seq), task.id, m, v))

    def commit_light(self, task: Task, m: int, inst: LightInstance,
                     now: float):
        """Strategy decided: run stage m of task on `inst`.

        True duration follows the paper's cumulative service process
        F(0,t) = sum_tau f_m(tau) with i.i.d. Gamma per-slot rates: the
        task (admitted at concurrency y_eff, so it must see y_eff * a of
        aggregate work through its share) completes in the first slot
        where the cumulative service reaches its scaled workload."""
        ms = self.app.ms(m)
        ready = max(task.data_ready_at(m, self.net, inst.v), now)
        y_eff = inst.y_at(ready) + 1
        work = ms.a * y_eff
        # vectorized: draw a block sized ~3x the expected slot count
        n_exp = max(4, int(3 * work / max(ms.f_mean, 1e-6)) + 4)
        dur = 0.0
        for _ in range(8):  # geometric retry, cap ~8*n_exp slots
            f = np.maximum(self.rng.gamma(ms.f_shape, ms.f_scale,
                                          size=n_exp), 1e-6)
            cum = np.cumsum(f) * SLOT_MS
            if cum[-1] >= work:
                i = int(np.searchsorted(cum, work))
                prev = cum[i - 1] if i else 0.0
                dur += i * SLOT_MS + (work - prev) / f[i]
                break
            work -= cum[-1]
            dur += n_exp * SLOT_MS
        fin = ready + dur
        inst.busy_until = max(inst.busy_until, fin)
        inst.active.append(fin)
        heapq.heappush(self.events,
                       (fin, next(self._seq), task.id, m, inst.v))

    def spawn_instance(self, v: int, m: int, now: float,
                       persistent: bool = False) -> LightInstance:
        assert v not in self.dead_nodes, "cannot place on a failed node"
        inst = LightInstance(id=next(self._inst_ids), v=v, m=m, born=now,
                             persistent=persistent)
        self.instances.append(inst)
        return inst

    # ------------------------------------------------------------------
    # Per-slot accounting
    # ------------------------------------------------------------------
    def alive_instances(self, now: float) -> List[LightInstance]:
        return [i for i in self.instances
                if i.v not in self.dead_nodes
                and (i.persistent or i.busy_until > now
                     or i.born >= now - SLOT_MS)]

    def light_resources_used(self, now: float) -> np.ndarray:
        used = np.zeros_like(self.net.R)
        for inst in self.alive_instances(now):
            used[inst.v] += self.app.ms(inst.m).r
        return used

    def _accrue_light_cost(self, t: float):
        alive = self.alive_instances(t)
        counts: Dict[tuple, int] = {}
        for inst in alive:
            counts[(inst.v, inst.m)] = counts.get((inst.v, inst.m), 0) + 1
        for (v, m), c in counts.items():
            ms = self.app.ms(m)
            newly = max(0, c - self.prev_alive.get((v, m), 0))
            self.light_cost += ms.c_dp * newly + (ms.c_mt + ms.c_pl) * c
        self.prev_alive = counts

    # ------------------------------------------------------------------
    # Main loop
    # ------------------------------------------------------------------
    def run(self) -> dict:
        self.place_core()
        if hasattr(self.strategy, "init_light"):
            self.strategy.init_light(self)
        t_end = self.horizon + self.drain
        for t_slot in range(t_end):
            for ev in self._churn_by_slot.get(t_slot, ()):
                if ev.action == "fail":
                    self.dead_nodes.add(ev.node)
                else:
                    self.dead_nodes.discard(ev.node)
            if t_slot < self.horizon:
                self._generate(t_slot)
            # controller at slot boundary
            if self.waiting:
                still = self.strategy.assign_light(float(t_slot), self,
                                                   self.waiting)
                self.waiting = still
            self._accrue_light_cost(float(t_slot))
            # drain events due this slot
            while self.events and self.events[0][0] < t_slot + 1:
                fin, _, tid, m, v = heapq.heappop(self.events)
                task = self.tasks[tid]
                task.done[m] = fin
                task.loc[m] = v
                if m == task.tt.sink():
                    task.finish = fin
                    if hasattr(self.strategy, "task_done"):
                        self.strategy.task_done(task)
                else:
                    self._advance_task(task, now=fin)
            if hasattr(self.strategy, "end_slot"):
                self.strategy.end_slot(float(t_slot), self)
            if (t_slot >= self.horizon and not self.events
                    and not self.waiting):
                break
        return self.metrics()

    def metrics(self) -> dict:
        fin = [t for t in self.tasks.values() if t.finish is not None]
        on_time = [t for t in fin
                   if t.finish - t.t_gen <= t.tt.deadline]
        n = max(self.n_generated, 1)
        lat = [t.finish - t.t_gen for t in fin]
        return {
            "strategy": getattr(self.strategy, "name", "?"),
            "generated": self.n_generated,
            "completed": len(fin) / n,
            "on_time": len(on_time) / n,
            "core_cost": self.core_cost(),
            "light_cost": self.light_cost,
            "total_cost": self.core_cost() + self.light_cost,
            "mean_latency_ms": float(np.mean(lat)) if lat else float("nan"),
            "p95_latency_ms": float(np.percentile(lat, 95)) if lat
            else float("nan"),
        }
