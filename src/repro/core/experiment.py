"""Trial runner: sample an application + network, run all strategies."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.baselines import GAStrategy, LBRRStrategy
from repro.core.graph import make_application
from repro.core.network import make_network
from repro.core.online_controller import PropAvgStrategy, ProposalStrategy
from repro.core.simulator import Simulator

STRATEGIES = {
    "proposal": ProposalStrategy,
    "prop_avg": PropAvgStrategy,
    "lbrr": LBRRStrategy,
    "ga": GAStrategy,
}


def run_trial(seed: int, strategy_names=None, rate_multiplier: float = 1.0,
              horizon_slots: int = 100, eps: float = 0.2) -> List[Dict]:
    rng = np.random.default_rng(seed)
    app = make_application(rng, rate_multiplier=rate_multiplier)
    net = make_network(rng)
    out = []
    for name in (strategy_names or STRATEGIES):
        cls = STRATEGIES[name]
        kw = {"horizon_slots": horizon_slots} if name in (
            "proposal", "prop_avg") else {}
        if name == "proposal" or name == "prop_avg":
            kw["eps"] = eps
        strat = cls(**kw)
        sim = Simulator(app, net, strat,
                        rng=np.random.default_rng((seed, hash(name) % 2**31)),
                        horizon_slots=horizon_slots)
        m = sim.run()
        m["seed"] = seed
        m["rate_multiplier"] = rate_multiplier
        out.append(m)
    return out


def summarize(rows: List[Dict]) -> Dict[str, Dict[str, float]]:
    by = {}
    for r in rows:
        by.setdefault(r["strategy"], []).append(r)
    out = {}
    for k, rs in by.items():
        def col(c):
            return np.array([r[c] for r in rs], dtype=float)
        out[k] = {
            "n_trials": len(rs),
            "on_time_mean": col("on_time").mean(),
            "on_time_p10": float(np.percentile(col("on_time"), 10)),
            "on_time_p90": float(np.percentile(col("on_time"), 90)),
            "on_time_std": col("on_time").std(),
            "completed_mean": col("completed").mean(),
            "cost_mean": col("total_cost").mean(),
            "cost_std": col("total_cost").std(),
        }
    return out
