"""Trial primitives: strategy registry, stable seeding, summaries.

Replication-grade seeding: every stream is derived from
`np.random.SeedSequence` entropy lists, and strategy/scenario names are
folded in via `zlib.crc32` — NOT the builtin `hash()`, which is salted
per-process by PYTHONHASHSEED and silently breaks "fixed-seed"
reproducibility across runs.

The parallel grid runner lives in `repro.experiments.runner`;
`run_trial` below is the sequential one-seed convenience wrapper that
routes through the same code path (so its rows are byte-identical to
the runner's for the same spec).
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional

import numpy as np

from repro.core.baselines import GAStrategy, LBRRStrategy
from repro.core.online_controller import PropAvgStrategy, ProposalStrategy

STRATEGIES = {
    "proposal": ProposalStrategy,
    "prop_avg": PropAvgStrategy,
    "lbrr": LBRRStrategy,
    "ga": GAStrategy,
}


def stable_seed(name: str) -> int:
    """PYTHONHASHSEED-independent sub-seed for a strategy/scenario name."""
    return zlib.crc32(name.encode("utf-8"))


def spawn_rng(*entropy: int) -> np.random.Generator:
    """Deterministic generator from an entropy tuple (SeedSequence)."""
    return np.random.default_rng(np.random.SeedSequence(list(entropy)))


def build_strategy(name: str, horizon_slots: int = 100, eps: float = 0.2,
                   kappa: Optional[int] = None, seed: int = 0,
                   bytes_per_param: Optional[float] = None):
    """Instantiate a registered strategy with per-kind kwargs.

    `kappa` overrides the proposal's diversity constraint (ablations);
    `seed` feeds the GA's internal generator so replications differ;
    `bytes_per_param` rescales the core services' memory demand for
    quantized placement re-runs (SERVING.md §Quantization).
    """
    cls = STRATEGIES[name]
    if name in ("proposal", "prop_avg"):
        kw = {"horizon_slots": horizon_slots, "eps": eps}
        if kappa is not None:
            kw["kappa"] = kappa
        if bytes_per_param is not None:
            kw["bytes_per_param"] = bytes_per_param
        return cls(**kw)
    if name == "ga":
        return cls(seed=seed)
    return cls()


def run_trial(seed: int, strategy_names=None, rate_multiplier: float = 1.0,
              horizon_slots: int = 100, eps: float = 0.2,
              scenario: str = "baseline") -> List[Dict]:
    """Run every requested strategy on one sampled environment."""
    from repro.experiments.runner import TrialSpec, run_one
    out = []
    for name in (strategy_names or STRATEGIES):
        out.append(run_one(TrialSpec(
            seed=seed, strategy=name, scenario=scenario,
            rate_multiplier=rate_multiplier, horizon_slots=horizon_slots,
            eps=eps)))
    return out


def summarize(rows: List[Dict]) -> Dict[str, Dict[str, float]]:
    """Per-strategy aggregate view of trial rows (delegates to the
    general grouped aggregation in repro.experiments.results)."""
    from repro.experiments.results import summarize_rows
    return {s["strategy"]: s
            for s in summarize_rows(rows, keys=("strategy",))}
