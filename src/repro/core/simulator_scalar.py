"""Fixed-semantics SCALAR reference engine for the vectorized simulator.

This module preserves the pre-vectorization, per-object/per-task pure
Python evaluation loop — with the same *semantics fixes* the vectorized
engine carries (uplink-gated source readiness, no silent service
truncation) and the same RNG draw layout (the shared batched kernels in
`repro.core.simulator`), so a `ScalarSimulator` trial consumes exactly
the RNG stream of a vectorized `Simulator` trial and must reproduce its
metrics bit-for-bit.  `benchmarks/sim_bench.py` asserts that equality
trial-for-trial and reports the vectorized engine's wall-clock speedup
against this reference; tests/test_simulator_invariants.py locks it.

Nothing here should grow features: it exists as the semantic oracle and
the speedup baseline.  New work goes into `repro.core.simulator`.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.lyapunov import ZETA, VirtualQueues
from repro.core.simulator import (SLOT_MS, ChurnEvent, Task, draw_arrivals,
                                  sample_service_ms)


@dataclass
class LightInstance:
    id: int
    v: int
    m: int
    born: float
    busy_until: float = 0.0
    y_now: int = 0                                   # assigned this slot
    persistent: bool = False                         # static allocation
    active: List[float] = field(default_factory=list)  # finish times

    def y_at(self, now: float) -> int:
        """Concurrent tasks on this instance at time `now`."""
        self.active = [f for f in self.active if f > now]
        return len(self.active)


class ScalarSimulator:
    """The pre-vectorization event engine: per-task nested loops,
    per-object light-instance list, per-(pair) routed-path lookups."""

    def __init__(self, app, net, strategy, rng: np.random.Generator,
                 horizon_slots: int = 100, drain_slots: int = 400,
                 fail_node: Optional[int] = None,
                 fail_at: Optional[int] = None,
                 churn: Optional[Sequence[ChurnEvent]] = None,
                 arrival_modulation: Optional[
                     Callable[[int], float]] = None):
        self.app = app
        self.net = net
        self.strategy = strategy
        self.rng = rng
        self.horizon = horizon_slots
        self.drain = drain_slots
        events = list(churn or [])
        if fail_node is not None and fail_at is not None:
            events.append(ChurnEvent(slot=fail_at, node=fail_node,
                                     action="fail"))
        self._churn_by_slot: Dict[int, List[ChurnEvent]] = {}
        for ev in events:
            self._churn_by_slot.setdefault(ev.slot, []).append(ev)
        self.arrival_modulation = arrival_modulation
        self.dead_nodes: set = set()
        self.tasks: Dict[int, Task] = {}
        self.events: list = []      # (time, seq, task_id, ms)
        self._seq = itertools.count()
        self._task_ids = itertools.count()
        self.waiting: List[tuple] = []   # (task_id, ms) light stages queued
        self.x_cr: Dict[int, np.ndarray] = {}
        self.core_free: Dict[tuple, np.ndarray] = {}
        self.instances: List[LightInstance] = []
        self._inst_ids = itertools.count()
        self.light_cost = 0.0
        self.prev_alive: Dict[tuple, int] = {}
        self.n_generated = 0

    # ------------------------------------------------------------------
    def place_core(self):
        self.x_cr = self.strategy.place_core(self.app, self.net)
        for m, xv in self.x_cr.items():
            for v in range(self.net.n_nodes):
                if xv[v] > 0:
                    self.core_free[(v, m)] = np.zeros(int(xv[v]))
        used = np.zeros_like(self.net.R)
        for m, xv in self.x_cr.items():
            used += xv[:, None] * self.app.ms(m).r[None, :]
        self.R_lt = self.net.R - used

    def core_cost(self) -> float:
        total = 0.0
        for m, xv in self.x_cr.items():
            ms = self.app.ms(m)
            total += (ms.c_dp + ms.c_mt * self.horizon) * xv.sum()
        return float(total)

    # ------------------------------------------------------------------
    def _generate(self, t_slot: int):
        mult = (self.arrival_modulation(t_slot)
                if self.arrival_modulation is not None else 1.0)
        # identical batched draws as the vectorized engine, consumed by
        # the old per-task construction loop
        u_idx, tt_idx, t_gen, uplink = draw_arrivals(
            self.rng, self.net, self.app, t_slot, mult)
        for k in range(len(u_idx)):
            tid = next(self._task_ids)
            tt = self.app.task_types[int(tt_idx[k])]
            task = Task(id=tid, tt=tt, user=int(u_idx[k]),
                        t_gen=float(t_gen[k]),
                        ed=int(self.net.user_ed[u_idx[k]]),
                        uplink_done=float(t_gen[k] + uplink[k]))
            task._app = self.app
            self.tasks[tid] = task
            self.n_generated += 1
            if hasattr(self.strategy, "admit"):
                self.strategy.admit(task)
            self._advance_task(task, now=task.uplink_done)

    # ------------------------------------------------------------------
    def _advance_task(self, task: Task, now: float):
        for m in task.ready_stages():
            if self.app.ms(m).is_core:
                self._dispatch_core(task, m, now)
            else:
                task.dispatched.add(m)
                self.waiting.append((task.id, m))

    def _dispatch_core(self, task: Task, m: int, now: float):
        ms = self.app.ms(m)
        best = None
        for (v, mm), free in self.core_free.items():
            if mm != m or v in self.dead_nodes:
                continue
            ready = max(task.data_ready_at(m, self.net, v), now)
            i = int(np.argmin(free))
            start = max(ready, free[i])
            fin = start + ms.a / ms.f_det
            if best is None or fin < best[0]:
                best = (fin, v, i)
        if best is None:   # no instance anywhere: task cannot complete
            task.dispatched.add(m)
            return
        fin, v, i = best
        self.core_free[(v, m)][i] = fin
        task.dispatched.add(m)
        heapq.heappush(self.events,
                       (fin, next(self._seq), task.id, m, v))

    def commit_light(self, task: Task, m: int, inst: LightInstance,
                     now: float):
        ms = self.app.ms(m)
        ready = max(task.data_ready_at(m, self.net, inst.v), now)
        y_eff = inst.y_at(ready) + 1
        dur = sample_service_ms(self.rng, ms, ms.a * y_eff)
        fin = ready + dur
        inst.busy_until = max(inst.busy_until, fin)
        inst.active.append(fin)
        heapq.heappush(self.events,
                       (fin, next(self._seq), task.id, m, inst.v))

    def spawn_instance(self, v: int, m: int, now: float,
                       persistent: bool = False) -> LightInstance:
        assert v not in self.dead_nodes, "cannot place on a failed node"
        inst = LightInstance(id=next(self._inst_ids), v=v, m=m, born=now,
                             persistent=persistent)
        self.instances.append(inst)
        return inst

    # ------------------------------------------------------------------
    def alive_instances(self, now: float) -> List[LightInstance]:
        return [i for i in self.instances
                if i.v not in self.dead_nodes
                and (i.persistent or i.busy_until > now
                     or i.born >= now - SLOT_MS)]

    def light_resources_used(self, now: float) -> np.ndarray:
        used = np.zeros_like(self.net.R)
        for inst in self.alive_instances(now):
            used[inst.v] += self.app.ms(inst.m).r
        return used

    def _accrue_light_cost(self, t: float):
        alive = self.alive_instances(t)
        counts: Dict[tuple, int] = {}
        for inst in alive:
            counts[(inst.v, inst.m)] = counts.get((inst.v, inst.m), 0) + 1
        # sorted (v, m) iteration: the float accumulation order matches
        # the vectorized engine's bincount scan exactly
        for (v, m) in sorted(counts):
            c = counts[(v, m)]
            ms = self.app.ms(m)
            newly = max(0, c - self.prev_alive.get((v, m), 0))
            self.light_cost += ms.c_dp * newly + (ms.c_mt + ms.c_pl) * c
        self.prev_alive = counts

    # ------------------------------------------------------------------
    def run(self) -> dict:
        self.place_core()
        if hasattr(self.strategy, "init_light"):
            self.strategy.init_light(self)
        t_end = self.horizon + self.drain
        for t_slot in range(t_end):
            for ev in self._churn_by_slot.get(t_slot, ()):
                if ev.action == "fail":
                    self.dead_nodes.add(ev.node)
                else:
                    self.dead_nodes.discard(ev.node)
            if t_slot < self.horizon:
                self._generate(t_slot)
            if self.waiting:
                still = self.strategy.assign_light(float(t_slot), self,
                                                   self.waiting)
                self.waiting = still
            self._accrue_light_cost(float(t_slot))
            while self.events and self.events[0][0] < t_slot + 1:
                fin, _, tid, m, v = heapq.heappop(self.events)
                task = self.tasks[tid]
                task.done[m] = fin
                task.loc[m] = v
                if m == task.tt.sink():
                    task.finish = fin
                    if hasattr(self.strategy, "task_done"):
                        self.strategy.task_done(task)
                else:
                    self._advance_task(task, now=fin)
            if hasattr(self.strategy, "end_slot"):
                self.strategy.end_slot(float(t_slot), self)
            if (t_slot >= self.horizon and not self.events
                    and not self.waiting):
                break
        return self.metrics()

    def metrics(self) -> dict:
        fin = [t for t in self.tasks.values() if t.finish is not None]
        on_time = [t for t in fin
                   if t.finish - t.t_gen <= t.tt.deadline]
        n = max(self.n_generated, 1)
        lat = [t.finish - t.t_gen for t in fin]
        return {
            "strategy": getattr(self.strategy, "name", "?"),
            "generated": self.n_generated,
            "completed": len(fin) / n,
            "on_time": len(on_time) / n,
            "core_cost": self.core_cost(),
            "light_cost": self.light_cost,
            "total_cost": self.core_cost() + self.light_cost,
            "mean_latency_ms": float(np.mean(lat)) if lat else float("nan"),
            "p95_latency_ms": float(np.percentile(lat, 95)) if lat
            else float("nan"),
        }


# ----------------------------------------------------------------------
# Scalar strategy counterparts (pre-vectorization control loops over the
# object-based instance API; decisions match the vectorized strategies)
# ----------------------------------------------------------------------
from repro.core.baselines import (GAStrategy, LBRRStrategy,  # noqa: E402
                                  Y_FIXED)
from repro.core.online_controller import (Y_MAX,  # noqa: E402
                                          ProposalStrategy)


class ScalarProposalStrategy(ProposalStrategy):
    """Algorithm 1 as the pre-PR quadruple Python loop."""

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.queues = VirtualQueues(zeta=ZETA)

    def end_slot(self, t: float, sim):
        for tid, task in sim.tasks.items():
            if task.finish is None:
                self.queues.update(tid, (t + 1) - task.t_gen,
                                   task.tt.deadline)

    def _estimate(self, m: int, y: int) -> float:
        ec = self.ec[m]
        return ec.g_mean(y) if self.use_mean_estimate else ec.g(y)

    def _dt(self, sim, task, m, v, y, now) -> float:
        arrive = task.data_ready_at(m, sim.net, v)
        return max(0.0, arrive - now) + self._estimate(m, y)

    def assign_light(self, t: float, sim, waiting):
        app, net = sim.app, sim.net
        waiting = [(tid, m) for tid, m in waiting]
        if not waiting:
            return []

        live = {i.id: i for i in sim.alive_instances(t)}
        for i in live.values():
            i.y_now = i.y_at(t)
        free_r = net.R - sim.light_resources_used(t)
        for m, xv in sim.x_cr.items():
            free_r -= xv[:, None] * app.ms(m).r[None, :]
        free_r = np.maximum(free_r, 0.0)

        new_instances: List = []

        def feasible(v, m):
            if v in sim.dead_nodes:
                return False
            return bool((free_r[v] >= app.ms(m).r).all())

        def candidates(ms_needed):
            # sorted: canonical stage order shared with the vectorized
            # controller (the pre-PR set iteration order was arbitrary)
            return [(v, m) for m in sorted(ms_needed)
                    for v in range(net.n_nodes) if feasible(v, m)]

        while True:
            ms_needed = {m for _, m in waiting}
            best = (0.0, None, None)
            for v, m in candidates(ms_needed):
                ms = app.ms(m)
                cost_new = self.eta * (ms.c_dp + ms.c_mt + ms.c_pl)
                gain = 0.0
                y_hyp = 0
                for tid, mm in waiting:
                    if mm != m:
                        continue
                    task = sim.tasks[tid]
                    dt_new = self._dt(sim, task, m, v, y_hyp + 1, t)
                    defer = SLOT_MS + self._estimate(m, 1)
                    for inst in live.values():
                        if inst.m == m:
                            defer = min(defer, self._dt(
                                sim, task, m, inst.v, inst.y_now + 1, t))
                    for inst in new_instances:
                        if inst.m == m:
                            defer = min(defer, self._dt(
                                sim, task, m, inst.v, inst.y_now + 1, t))
                    if dt_new < defer:
                        h = self.queues.get(tid)
                        gain += self.phi * h * (defer - dt_new)
                        y_hyp += 1
                dl = cost_new - gain
                if dl < best[0]:
                    best = (dl, v, m)
            if best[1] is None:
                break
            _, v, m = best
            inst = sim.spawn_instance(v, m, t)
            new_instances.append(inst)
            free_r[v] -= app.ms(m).r

        pool = list(live.values()) + new_instances
        still = []
        order = sorted(waiting,
                       key=lambda wm: -self.queues.get(wm[0]))
        for tid, m in order:
            task = sim.tasks[tid]
            opts = [i for i in pool if i.m == m and i.y_now < Y_MAX]
            if not opts:
                still.append((tid, m))
                continue
            dts = [self._dt(sim, task, m, i.v, i.y_now + 1, t)
                   for i in opts]
            k = int(np.argmin(dts))
            inst = opts[k]
            sim.commit_light(task, m, inst, now=t)
            inst.y_now += 1
        return still


class ScalarPropAvgStrategy(ScalarProposalStrategy):
    name = "prop_avg"
    use_mean_estimate = True


class ScalarLBRRStrategy(LBRRStrategy):
    def assign_light(self, t: float, sim, waiting):
        live = list(sim.alive_instances(t))
        for i in live:
            i.y_now = i.y_at(t)
        still = []
        for tid, m in waiting:
            task = sim.tasks[tid]
            opts = [i for i in live if i.m == m and i.y_now < Y_FIXED]
            if not opts:
                still.append((tid, m))
                continue
            inst = opts[self._rr % len(opts)]
            self._rr += 1
            sim.commit_light(task, m, inst, now=t)
            inst.y_now += 1
        return still


class ScalarGAStrategy(GAStrategy):
    def assign_light(self, t: float, sim, waiting):
        live = list(sim.alive_instances(t))
        for i in live:
            i.y_now = i.y_at(t)
        still = []
        for tid, m in waiting:
            task = sim.tasks[tid]
            opts = [i for i in live if i.m == m and i.y_now < Y_FIXED]
            if not opts:
                still.append((tid, m))
                continue
            inst = min(opts, key=lambda i: i.y_now)
            sim.commit_light(task, m, inst, now=t)
            inst.y_now += 1
        return still


SCALAR_STRATEGIES = {
    "proposal": ScalarProposalStrategy,
    "prop_avg": ScalarPropAvgStrategy,
    "lbrr": ScalarLBRRStrategy,
    "ga": ScalarGAStrategy,
}


def build_scalar_strategy(name: str, horizon_slots: int = 100,
                          eps: float = 0.2, kappa=None, seed: int = 0,
                          bytes_per_param=None):
    """Scalar counterpart of `repro.core.experiment.build_strategy`."""
    cls = SCALAR_STRATEGIES[name]
    if name in ("proposal", "prop_avg"):
        kw = {"horizon_slots": horizon_slots, "eps": eps}
        if kappa is not None:
            kw["kappa"] = kappa
        if bytes_per_param is not None:
            kw["bytes_per_param"] = bytes_per_param
        return cls(**kw)
    if name == "ga":
        return cls(seed=seed)
    return cls()


def run_one_scalar(spec) -> dict:
    """`repro.experiments.runner.run_one`, but on the scalar reference
    engine — same environment streams, same spec annotation."""
    from repro.core.experiment import spawn_rng, stable_seed
    from repro.experiments.scenarios import get_scenario

    scen = get_scenario(spec.scenario)
    sid = stable_seed(spec.scenario)
    env_rng = spawn_rng(spec.seed, sid, 0)
    app = scen.build_application(env_rng,
                                 rate_multiplier=spec.rate_multiplier)
    net = scen.build_network(env_rng)
    churn = scen.churn_schedule(net, spawn_rng(spec.seed, sid, 1),
                                spec.horizon_slots)
    modulation = scen.arrival_modulation(spawn_rng(spec.seed, sid, 2))
    strat = build_scalar_strategy(
        spec.strategy, horizon_slots=spec.horizon_slots, eps=spec.eps,
        kappa=spec.kappa, seed=spec.seed,
        bytes_per_param=getattr(spec, "bytes_per_param", None))
    sim = ScalarSimulator(app, net, strat,
                          rng=spawn_rng(spec.seed, sid,
                                        stable_seed(spec.strategy)),
                          horizon_slots=spec.horizon_slots,
                          drain_slots=getattr(spec, "drain_slots", 400),
                          churn=churn, arrival_modulation=modulation)
    m = sim.run()
    m.update(seed=spec.seed, scenario=spec.scenario,
             rate_multiplier=spec.rate_multiplier,
             horizon_slots=spec.horizon_slots,
             drain_slots=getattr(spec, "drain_slots", 400), eps=spec.eps,
             kappa=spec.kappa,
             bytes_per_param=getattr(spec, "bytes_per_param", None))
    return m
