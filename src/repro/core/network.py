"""Heterogeneous edge network (Fig. 2): EDs + ESs, links, users.

Topology: ESs form a full mesh among themselves (backhaul); every ED
attaches to its two nearest ESs; users attach to one ED each over a
Nakagami-fading wireless uplink.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.core import paper_params as pp


# node tiers for heterogeneous topologies (make_tiered_network)
TIER_DEVICE, TIER_ED, TIER_ES, TIER_CLOUD = 0, 1, 2, 3

# canonical resource-column names for `EdgeNetwork.R` (Table I order);
# use `resource_index` instead of hardcoding column numbers so consumers
# stay correct if a narrower R matrix is supplied
RESOURCE_NAMES = ("cpu", "ram", "gpu", "vram")


def resource_index(name: str) -> int:
    """Column index of a named resource in ``EdgeNetwork.R``."""
    try:
        return RESOURCE_NAMES.index(name)
    except ValueError:
        raise KeyError(f"unknown resource {name!r}; "
                       f"known: {RESOURCE_NAMES}") from None


@dataclass
class EdgeNetwork:
    n_nodes: int
    is_es: np.ndarray            # (V,) bool
    R: np.ndarray                # (V, K) capacities
    bw: np.ndarray               # (V, V) link bandwidth MB/ms (0 = no link)
    dist: np.ndarray             # (V, V) km
    user_ed: np.ndarray          # (U,) entry-node index of each user
    user_bw: np.ndarray          # (U,) uplink bandwidth b_u MB/ms
    snr_m: np.ndarray            # (U,) Nakagami shape
    snr_omega: np.ndarray        # (U,) Nakagami spread
    prop_speed: float = pp.TABLE_I["prop_speed_km_per_ms"]
    tier: np.ndarray = field(default=None, repr=False)  # (V,) TIER_* ints

    # filled by prepare()
    hop_next: np.ndarray = field(default=None, repr=False)
    net_ms: np.ndarray = field(default=None, repr=False)
    # routed-path transfer delay is affine in the payload:
    #   path_ms(v1, v2, mb) = mb * path_invbw[v1, v2] + path_prop[v1, v2]
    # (sum of per-hop 1/bw, and of per-hop dist/prop_speed, along the
    # shortest-hop route); precomputed so the simulator can score whole
    # candidate-node vectors at once
    path_invbw: np.ndarray = field(default=None, repr=False)
    path_prop: np.ndarray = field(default=None, repr=False)

    def __post_init__(self):
        if self.tier is None:  # classic two-tier topology
            self.tier = np.where(self.is_es, TIER_ES, TIER_ED)

    def nodes_in_tier(self, t: int) -> np.ndarray:
        return np.flatnonzero(self.tier == t)

    @property
    def n_users(self) -> int:
        return len(self.user_ed)

    # ------------------------------------------------------------------
    def link_ms(self, v1: int, v2: int, mb: float) -> float:
        """Transmission + propagation delay for `mb` MB over one hop
        (eq. 2); 0 if same node."""
        if v1 == v2:
            return 0.0
        bw = self.bw[v1, v2]
        assert bw > 0, f"no link {v1}->{v2}"
        return mb / bw + self.dist[v1, v2] / self.prop_speed

    def path_ms(self, v1: int, v2: int, mb: float) -> float:
        """Multi-hop routed transfer delay along the precomputed
        shortest-hop route (affine in ``mb``)."""
        if v1 == v2:
            return 0.0
        out = mb * self.path_invbw[v1, v2] + self.path_prop[v1, v2]
        assert np.isfinite(out), f"no route {v1}->{v2}"
        return float(out)

    def path_ms_row(self, v1: int, mb: float) -> np.ndarray:
        """Vector of routed transfer delays from ``v1`` to every node."""
        return mb * self.path_invbw[v1] + self.path_prop[v1]

    def sample_uplink_ms(self, rng, u: int, payload_mb: float) -> float:
        """Eq. (1) with Nakagami-m fading SNR."""
        m, omega = self.snr_m[u], self.snr_omega[u]
        gamma = rng.gamma(m, omega / m)  # Nakagami power ~ Gamma(m, omega/m)
        rate = self.user_bw[u] * np.log2(1.0 + gamma)
        return payload_mb / max(rate, 1e-6)

    def sample_uplink_ms_batch(self, rng, users: np.ndarray,
                               payload_mb: np.ndarray) -> np.ndarray:
        """Eq. (1) for a batch of (user, payload) pairs — ONE Gamma draw
        for the whole batch, so per-slot arrival sampling is a handful
        of vector calls rather than per-task scalar draws."""
        if len(users) == 0:
            return np.zeros(0)
        m, omega = self.snr_m[users], self.snr_omega[users]
        gamma = rng.gamma(m, omega / m)
        rate = self.user_bw[users] * np.log2(1.0 + gamma)
        return payload_mb / np.maximum(rate, 1e-6)

    def mean_uplink_ms(self, u: int, payload_mb: float) -> float:
        """Mean-value analysis version of eq. (1): E[gamma] = omega for
        Nakagami-m power (Jensen approx on log2)."""
        omega = self.snr_omega[u]
        rate = self.user_bw[u] * np.log2(1.0 + omega)
        return payload_mb / max(rate, 1e-6)

    # ------------------------------------------------------------------
    def prepare(self, mean_transfer_mb: float = 1.0):
        """All-pairs shortest paths (Floyd-Warshall) with edge weight =
        transfer(1MB) + propagation; stores next-hop for routing."""
        v = self.n_nodes
        w = np.full((v, v), np.inf)
        np.fill_diagonal(w, 0.0)
        for i in range(v):
            for j in range(v):
                if i != j and self.bw[i, j] > 0:
                    w[i, j] = (mean_transfer_mb / self.bw[i, j]
                               + self.dist[i, j] / self.prop_speed)
        nxt = np.tile(np.arange(v), (v, 1))
        nxt[w == np.inf] = -1
        for i in range(v):
            nxt[i, i] = i
        for k in range(v):
            for i in range(v):
                improved = w[i, k] + w[k] < w[i]
                w[i, improved] = w[i, k] + w[k, improved]
                nxt[i, improved] = nxt[i, k]
        self.hop_next = nxt
        self.net_ms = w
        # walk every route simultaneously to decompose path delay into
        # its payload-proportional and propagation components (affine
        # coefficients consumed by path_ms / path_ms_row)
        with np.errstate(divide="ignore"):
            edge_inv = np.where(self.bw > 0, 1.0 / np.where(
                self.bw > 0, self.bw, 1.0), np.inf)
        np.fill_diagonal(edge_inv, 0.0)
        edge_prop = self.dist / self.prop_speed
        invbw = np.zeros((v, v))
        prop = np.zeros((v, v))
        cur = np.tile(np.arange(v)[:, None], (1, v))
        tgt = np.tile(np.arange(v)[None, :], (v, 1))
        unreachable = nxt < 0
        for _ in range(v):
            act = (cur != tgt) & ~unreachable
            if not act.any():
                break
            step = nxt[cur[act], tgt[act]]
            invbw[act] += edge_inv[cur[act], step]
            prop[act] += edge_prop[cur[act], step]
            cur[act] = step
        invbw[unreachable] = np.inf
        prop[unreachable] = np.inf
        self.path_invbw = invbw
        self.path_prop = prop
        return self


def make_network(rng: np.random.Generator,
                 n_eds: int = pp.N_EDS, n_ess: int = pp.N_ESS,
                 n_users: int = pp.N_USERS) -> EdgeNetwork:
    v = n_eds + n_ess
    is_es = np.array([False] * n_eds + [True] * n_ess)
    R = np.zeros((v, pp.K_RESOURCES))
    for i in range(v):
        spec = pp.TABLE_I["es" if is_es[i] else "ed"]["R"]
        R[i] = [rng.uniform(lo, hi) for lo, hi in spec]

    lo, hi = pp.TABLE_I["link_dist_km"]
    pos = rng.uniform(0, hi, size=(v, 2))  # km field
    dist = np.clip(np.linalg.norm(pos[:, None] - pos[None, :], axis=-1),
                   lo, None)

    bw = np.zeros((v, v))

    def connect(i, j):
        w = rng.uniform(*pp.TABLE_I["link_bw"])
        bw[i, j] = bw[j, i] = w

    # ES full mesh
    for i in range(n_eds, v):
        for j in range(i + 1, v):
            connect(i, j)
    # each ED -> two nearest ESs
    for i in range(n_eds):
        es_order = np.argsort(dist[i, n_eds:]) + n_eds
        for j in es_order[:2]:
            connect(i, int(j))

    user_ed = rng.integers(0, n_eds, size=n_users)
    net = EdgeNetwork(
        n_nodes=v, is_es=is_es, R=R, bw=bw, dist=dist,
        user_ed=user_ed,
        user_bw=rng.uniform(*pp.TABLE_I["user_bw"], size=n_users),
        snr_m=rng.uniform(*pp.TABLE_I["snr_nakagami_m"], size=n_users),
        snr_omega=rng.uniform(*pp.TABLE_I["snr_nakagami_omega"],
                              size=n_users),
    )
    return net.prepare()


# capacity scaling / backhaul parameters for the four-tier topology
TIERED = {
    "device_R_scale": 0.25,      # device caps = scale * ED range
    "cloud_R_scale": 8.0,        # cloud caps = scale * ES range
    "cloud_bw": (2.0, 5.0),      # MB/ms ES <-> cloud backhaul
    "cloud_dist_km": (200.0, 500.0),   # long-haul propagation dominates
    "device_bw": (0.05, 0.3),    # MB/ms constrained device <-> ED link
}


def make_tiered_network(rng: np.random.Generator,
                        n_devices: int = 4,
                        n_eds: int = pp.N_EDS, n_ess: int = pp.N_ESS,
                        n_cloud: int = 1,
                        n_users: int = pp.N_USERS) -> EdgeNetwork:
    """Heterogeneous cloud/edge/device topology (scenario `tiered`).

    Node order: devices [0, nd), EDs, ESs, cloud last.  Devices are
    weak near-user nodes on constrained links; the cloud is a huge
    far-away pool reached over high-bandwidth, high-propagation-delay
    backhaul.  Users enter at a device when devices exist, so payloads
    must either execute on starved local silicon or pay the haul up.
    """
    v = n_devices + n_eds + n_ess + n_cloud
    tier = np.array([TIER_DEVICE] * n_devices + [TIER_ED] * n_eds
                    + [TIER_ES] * n_ess + [TIER_CLOUD] * n_cloud)
    is_es = tier >= TIER_ES
    ed0, es0, cl0 = n_devices, n_devices + n_eds, n_devices + n_eds + n_ess

    R = np.zeros((v, pp.K_RESOURCES))
    for i in range(v):
        if tier[i] == TIER_DEVICE:
            spec, scale = pp.TABLE_I["ed"]["R"], TIERED["device_R_scale"]
        elif tier[i] == TIER_ED:
            spec, scale = pp.TABLE_I["ed"]["R"], 1.0
        elif tier[i] == TIER_ES:
            spec, scale = pp.TABLE_I["es"]["R"], 1.0
        else:
            spec, scale = pp.TABLE_I["es"]["R"], TIERED["cloud_R_scale"]
        R[i] = [scale * rng.uniform(lo, hi) for lo, hi in spec]

    lo, hi = pp.TABLE_I["link_dist_km"]
    pos = rng.uniform(0, hi, size=(v, 2))
    dist = np.clip(np.linalg.norm(pos[:, None] - pos[None, :], axis=-1),
                   lo, None)
    # the cloud sits far outside the metro field
    for c in range(cl0, v):
        dist[c, :] = dist[:, c] = rng.uniform(*TIERED["cloud_dist_km"],
                                              size=v)
        dist[c, c] = 0.0

    bw = np.zeros((v, v))

    def connect(i, j, rng_range):
        w = rng.uniform(*rng_range)
        bw[i, j] = bw[j, i] = w

    # ES full mesh
    for i in range(es0, cl0):
        for j in range(i + 1, cl0):
            connect(i, j, pp.TABLE_I["link_bw"])
    # each ED -> two nearest ESs
    for i in range(ed0, es0):
        es_order = es0 + np.argsort(dist[i, es0:cl0])
        for j in es_order[:2]:
            connect(i, int(j), pp.TABLE_I["link_bw"])
    # each device -> its nearest ED, over a constrained link
    for i in range(n_devices):
        j = ed0 + int(np.argmin(dist[i, ed0:es0]))
        connect(i, j, TIERED["device_bw"])
    # cloud -> every ES over fat long-haul pipes
    for c in range(cl0, v):
        for j in range(es0, cl0):
            connect(c, j, TIERED["cloud_bw"])

    entry_pool = n_devices if n_devices > 0 else n_eds
    entry_off = 0 if n_devices > 0 else ed0
    user_ed = entry_off + rng.integers(0, entry_pool, size=n_users)
    net = EdgeNetwork(
        n_nodes=v, is_es=is_es, R=R, bw=bw, dist=dist,
        user_ed=user_ed,
        user_bw=rng.uniform(*pp.TABLE_I["user_bw"], size=n_users),
        snr_m=rng.uniform(*pp.TABLE_I["snr_nakagami_m"], size=n_users),
        snr_omega=rng.uniform(*pp.TABLE_I["snr_nakagami_omega"],
                              size=n_users),
        tier=tier,
    )
    return net.prepare()
