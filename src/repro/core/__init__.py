"""The paper's contribution: two-tier network-aware microservice deployment.

Modules:
  paper_params        Table I parameter ranges + samplers
  graph               microservice + task-DAG model (Fig. 1)
  network             heterogeneous edge network (Fig. 2)
  latency             eqs (1)-(5)
  qos                 mean-value heuristics z~, d~, Q (eqs 15-16)
  static_placement    sparsity-constrained integer program (14)+(16)
  effective_capacity  eqs (20)-(21): E_c(theta), g_{m,eps}(y)
  lyapunov            virtual queues (18) + drift-plus-penalty (19)
  online_controller   Algorithm 1 (greedy light-MS deployment)
  baselines           LBRR / GA / PropAvg
  simulator           event-driven slot simulator (Sec. IV)
"""
from repro.core.graph import Application, Microservice, TaskType  # noqa: F401
from repro.core.network import EdgeNetwork  # noqa: F401
