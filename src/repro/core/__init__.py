"""The paper's contribution: two-tier network-aware microservice deployment.

Modules:
  paper_params        Table I parameter ranges + samplers
  graph               microservice + task-DAG model (Fig. 1)
  network             heterogeneous edge network (Fig. 2), eqs (1)-(2)
  qos                 mean-value heuristics z~, d~, Q (eqs 15-16)
  static_placement    sparsity-constrained integer program (14)+(16)
  effective_capacity  eqs (20)-(21): E_c(theta), g_{m,eps}(y)
  lyapunov            virtual queues (18) + drift-plus-penalty (19)
  online_controller   Algorithm 1 (greedy light-MS deployment)
  baselines           LBRR / GA / PropAvg
  simulator           event-driven slot simulator (Sec. IV)
  experiment          single-trial driver shared by benches/examples

Paper-notation glossary (symbols as they appear in code):

  ========  ==================================================  ==========
  symbol    meaning                                             where
  ========  ==================================================  ==========
  a_m       workload of MS m per task, MB                       ``Microservice.a`` (Table I)
  b_m       output shipped downstream by MS m, MB               ``Microservice.b`` (Table I)
  r_m       resource requirement vector [CPU, RAM, GPU, VRAM]   ``Microservice.r`` (Table I)
  f_det     deterministic service rate of a core MS, MB/ms      ``Microservice.f_det``
  f_shape,  Gamma(shape, scale) service-rate contention model   ``Microservice.f_shape/f_scale``
  f_scale   of a light MS (eq. 20 input)
  c_dp/c_mt deployment / per-slot maintenance cost (eqs 6-7)    ``Microservice.c_dp/c_mt``
  c_pl      per-placement cost of a light MS (eq. 7)            ``Microservice.c_pl``
  A_n, D_n  input payload (MB) / deadline (ms) of task type n   ``TaskType.payload/deadline``
  R_{v,k}   capacity of node v in resource k                    ``EdgeNetwork.R``
  b_u       user u uplink bandwidth, MB/ms (eq. 1)              ``EdgeNetwork.user_bw``
  m, Omega  Nakagami fading shape / spread (eq. 1)              ``EdgeNetwork.snr_m/snr_omega``
  z~_{v,m}  load estimate of core m at node v (eq. 15)          ``qos.qos_scores``
  Q_{v,m}   urgency-weighted QoS score (eq. 16)                 ``qos.qos_scores``
  x_{v,m}   core-instance count at node v (IP variable, eq. 14) ``static_placement.solve``
  kappa     minimum open deployment sites, C6 diversity         ``PlacementProblem.kappa``
  xi        cost-vs-QoS weight in the IP objective              ``static_placement.XI_DEFAULT``
  H_j(t)    floored virtual deadline-debt queue (eq. 18)        ``lyapunov.VirtualQueues``
  zeta      virtual-queue floor (> 0 keeps control proactive)   ``lyapunov.ZETA``
  eta, phi  cost / queue weights in drift-plus-penalty (19)     ``lyapunov.ETA/PHI_DEFAULT``
            (eta plays the Lyapunov "V" trade-off role: larger
            eta favors cost over latency-debt drift)
  theta     QoS exponent of effective capacity (eqs 20-21)      ``effective_capacity.THETA_GRID``
  E_c       effective capacity, nats/MB scale (eq. 20)          ``effective_capacity.effective_capacity``
  g_{m,eps} statistically-safe latency budget at parallelism y  ``effective_capacity.ECMap.g``
  eps       latency-violation probability target                ``paper_params.EPSILON``
  y         parallelism (tasks sharing a light instance)        ``ECMap.g(y)``, ``Y_MAX``
  ========  ==================================================  ==========

Serving-side terms (the paged engines apply the same admit-under-
contention pattern to KV memory — SERVING.md §Scheduling covers the
QoS/policy layer and §Paper ↔ code has the Algorithm-1 correspondence
table):

  ==============  ==============================================  ==========
  term            meaning                                         where
  ==============  ==============================================  ==========
  block size      tokens per fixed-size KV block (the allocation  ``PagedCache.block_size`` (models/kvcache.py)
                  granule, serving analogue of r_m)
  block table     per-request logical→physical block map; slot s  ``PagedCache.tables`` / ``meta()``
                  lives at (table[s // bs], s % bs)
  scratch block   physical block 0, never allocated; absorbs      ``PagedCache`` pools, kvcache docstring
                  inactive decode rows' writes
  watermark       free-block headroom held back at admission to   ``PagedCache.watermark_blocks``
                  protect running requests' decode growth
                  (serving analogue of g_{m,eps} headroom)
  preemption      recompute-on-readmission eviction of a          ``_PagedEngine._preempt`` (serving/engine.py)
                  policy-chosen victim when the pool is
                  exhausted; greedy decode keeps outputs
                  token-identical
  QoS class       per-request SLO tier (interactive / standard    ``Request.qos``, ``scheduler.QOS_CLASSES``
                  / batch) carrying TTFT + TPOT deadlines in
                  engine steps (serving analogue of task type
                  n with deadline D_n)
  TTFT            time-to-first-token budget: t_first - t_submit  ``QoSClass.ttft``, ``scheduler.ttft_met``
                  must not exceed it (engine steps)
  TPOT            time-per-output-token budget: decode steps      ``QoSClass.tpot``, ``scheduler.tpot_met``
                  per generated token after the first
  slack           steps until a request's effective deadline,     ``EDFPolicy.slack`` (serving/scheduler.py)
                  the EDF ordering/victim key (aged by
                  age_rate, boosted by the class's H_c)
  goodput         fraction of submitted requests meeting both     ``scheduler.goodput`` / ``per_class_stats``
                  TTFT and TPOT — rejected/unfinished count
                  as misses (the paper's on-time completion
                  ratio at the serving layer)
  draft           K tokens proposed per row per verify round by   ``SpecConfig.provider`` (serving/speculative.py)
                  a cheap provider (host n-gram table or a
                  small shadow model) for the target model to
                  score in one parallel chunk dispatch
  acceptance      tokens emitted per row per verify round: the    ``greedy_verify_update`` (models/model.py),
  length          longest draft prefix matching the target's      ``_EngineBase.spec_accept_mean``
                  greedy argmax, + 1 correction/bonus token —
                  its mean is the speculative speedup EC
                  admission sees (``CapacityView.spec_accept``,
                  serving analogue of a service-rate scale)
  weight-only     packed int8/int4 projection weights, dequant    ``models/quantize.py``, ``kernels/quant_matmul.py``
  quantization    fused into the matmul; activations, KV, and
                  the arithmetic stay full-precision, so only
                  the weight *bytes* shrink (engines thread it
                  as ``quantization=``; placement sees it as
                  ``bytes_per_param`` on r_m's RAM/VRAM dims)
  MFU             Model FLOPs Utilization: achieved useful        ``launch.hlo_analysis.mfu``,
                  FLOP/s over the accelerator peak —              bench rows in bench_engine/quant.json
                  distance to the compute roof (reported
                  against nominal v5e peak on this CPU host)
  MBU             Model Bandwidth Utilization: achieved bytes/s   ``launch.hlo_analysis.mbu``
                  (weights once per step + KV pool) over peak
                  HBM bandwidth — distance to the memory roof;
                  the decode regime lives here, which is why
                  shrinking weight bytes is a tokens/s win
  ==============  ==============================================  ==========

See README.md §Paper ↔ code mapping for the construct-level table,
ARCHITECTURE.md for how the two tiers cooperate, and SERVING.md for
the serving engines' request lifecycle and memory model.
"""
from repro.core.graph import Application, Microservice, TaskType  # noqa: F401
from repro.core.network import EdgeNetwork  # noqa: F401
