"""Static core-MS placement: the sparsity-constrained integer program
(eq. 14 with diversity constraints C4–C6 of eq. 16).

    min_x  sum_{v,m} x_{v,m} (c_m - xi * Q_{v,m})
    C1: r_{m,k} x_{v,m} <= R_{v,k}            (per-(v,m) box bound)
    C2: sum_v x_{v,m} >= sum_v z~_{v,m}       (global demand cover)
    C3: x integer >= 0
    C4/C5: x_{v,m} in {0} U [C3_MIN, C2_BIG]  (open-site band)
    C6: #open sites >= kappa                  (diversity)

Structure: the objective and C1/C2 decompose per MS m; only C6 couples.
Solver: per-m exact greedy (sort sites by net coefficient; negative-cost
sites are filled to their box bound, then demand is covered at cheapest
cost), then a diversity repair pass opens the cheapest additional sites
until C6 holds.  `brute_force` cross-checks optimality on small instances
(see tests/test_static_placement.py).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

C3_MIN = 1        # C5: minimum instances on an open site
XI_DEFAULT = 0.1  # cost-vs-QoS weight xi


@dataclass
class PlacementProblem:
    cost: Dict[int, float]          # c_m = c_dp + c_mt per core MS
    q: Dict[int, np.ndarray]        # Q_{v,m}
    z: Dict[int, np.ndarray]        # z~_{v,m}
    box: Dict[int, np.ndarray]      # per-(v,m) max instances from C1
    kappa: int = 0
    xi: float = XI_DEFAULT

    @property
    def core_ids(self):
        return sorted(self.cost)

    def net_coeff(self, m: int) -> np.ndarray:
        return self.cost[m] - self.xi * self.q[m]

    def demand(self, m: int) -> int:
        return int(np.ceil(self.z[m].sum()))

    def objective(self, x: Dict[int, np.ndarray]) -> float:
        return float(sum((self.net_coeff(m) * x[m]).sum()
                         for m in self.core_ids))

    def open_sites(self, x: Dict[int, np.ndarray]) -> int:
        return int(sum((x[m] > 0).sum() for m in self.core_ids))

    def feasible(self, x: Dict[int, np.ndarray]) -> bool:
        for m in self.core_ids:
            if (x[m] > self.box[m]).any() or (x[m] < 0).any():
                return False
            if x[m].sum() < self.demand(m):
                return False
        return self.open_sites(x) >= self.kappa


#: bf16 weight bytes — the baseline service sizes are calibrated to it
DENSE_BYTES_PER_PARAM = 2.0
#: Table-I resource columns that scale with the weight footprint
_MEM_DIMS = (1, 3)   # ram, vram (network.RESOURCE_NAMES)


def build_problem(app, net, z_tilde, q_score, kappa: int,
                  xi: float = XI_DEFAULT, horizon_slots: int = 1,
                  bytes_per_param: Optional[float] = None
                  ) -> PlacementProblem:
    """``bytes_per_param`` rescales the memory dimensions (RAM/VRAM) of
    every *core* service's demand vector by ``bytes_per_param / 2.0``
    before the C1 box is computed — the placement view of weight-only
    quantization (SERVING.md §Quantization): int8 halves and int4
    quarters the resident weight bytes, so each site's box bound admits
    proportionally more instances.  Compute dims and light services are
    untouched (dequant happens inside the matmul; FLOPs are unchanged)."""
    mem_scale = (1.0 if bytes_per_param is None
                 else bytes_per_param / DENSE_BYTES_PER_PARAM)
    cost, box = {}, {}
    for m in app.core_ids:
        ms = app.ms(m)
        cost[m] = ms.c_dp + ms.c_mt * horizon_slots
        r = np.asarray(ms.r, dtype=float).copy()
        if mem_scale != 1.0:
            for k in _MEM_DIMS:
                if k < r.shape[-1]:
                    r[..., k] *= mem_scale
        # C1 box: r_{m,k} * x <= R_{v,k}  ->  x <= min_k floor(R/r)
        with np.errstate(divide="ignore"):
            per_k = np.floor(net.R / np.maximum(r, 1e-9))
        box[m] = per_k.min(axis=1).astype(int)
    return PlacementProblem(cost=cost, q=q_score, z=z_tilde, box=box,
                            kappa=kappa, xi=xi)


# ----------------------------------------------------------------------
# Exact decomposed solver
# ----------------------------------------------------------------------
def solve(problem: PlacementProblem) -> Dict[int, np.ndarray]:
    x = {}
    for m in problem.core_ids:
        coeff = problem.net_coeff(m)
        cap = problem.box[m].copy()
        xm = np.zeros_like(cap)
        # 1) negative net cost -> profitable: fill to the box bound
        neg = coeff < 0
        xm[neg] = cap[neg]
        # 2) cover remaining demand at the cheapest positive sites
        need = problem.demand(m) - xm.sum()
        if need > 0:
            order = np.argsort(coeff)
            for v in order:
                if need <= 0:
                    break
                if xm[v] >= cap[v]:
                    continue
                take = min(cap[v] - xm[v], need)
                if take >= C3_MIN or xm[v] > 0:
                    xm[v] += take
                    need -= take
        x[m] = xm

    # 3) diversity repair (C6): either open a fresh site (add C3_MIN
    # instances) or *move* an instance from the most expensive open donor
    # site (keeps demand covered, often cheaper) — whichever is cheaper.
    def best_repair():
        cands = []
        for m in problem.core_ids:
            coeff = problem.net_coeff(m)
            donors = [(coeff[v], v) for v in range(len(coeff))
                      if x[m][v] > max(C3_MIN, problem.demand(m) and 0)]
            surplus = x[m].sum() - problem.demand(m)
            for v in range(len(coeff)):
                if x[m][v] != 0 or problem.box[m][v] < C3_MIN:
                    continue
                open_cost = coeff[v] * C3_MIN
                cands.append((open_cost, m, v, None))
                # move: take one instance away from the priciest donor
                movable = [(c, dv) for c, dv in donors if x[m][dv] > C3_MIN]
                if surplus > 0:
                    # surplus instance can simply be deleted on add
                    movable += [(c, dv) for c, dv in donors]
                if movable and C3_MIN == 1:
                    dcost, dv = max(movable)
                    cands.append((coeff[v] - dcost, m, v, dv))
        return sorted(cands, key=lambda c: c[0])

    while problem.open_sites(x) < problem.kappa:
        cands = best_repair()
        if not cands:
            break  # infeasible kappa; return best effort
        _, m, v, donor = cands[0]
        x[m][v] = C3_MIN
        if donor is not None:
            x[m][donor] -= 1
    return x


# ----------------------------------------------------------------------
# Brute force (tests only)
# ----------------------------------------------------------------------
def brute_force(problem: PlacementProblem,
                max_inst: int = 3) -> Optional[Dict[int, np.ndarray]]:
    """Exhaustive search over small instances for solver cross-checks."""
    core = problem.core_ids
    v_n = len(problem.box[core[0]])
    best, best_obj = None, np.inf
    ranges = []
    for m in core:
        per_site = [range(0, min(int(problem.box[m][v]), max_inst) + 1)
                    for v in range(v_n)]
        ranges.append(list(itertools.product(*per_site)))
    for combo in itertools.product(*ranges):
        x = {m: np.array(combo[i]) for i, m in enumerate(core)}
        if not problem.feasible(x):
            continue
        obj = problem.objective(x)
        if obj < best_obj - 1e-12:
            best, best_obj = x, obj
    return best
