"""Lyapunov machinery (Sec. III-B): floored virtual queues (eq. 18) and
the drift-plus-penalty objective (eq. 19).

Notation (glossary in ``repro.core.__init__``): H_j(t) is task j's
deadline-debt queue, zeta its floor, and eta the cost weight playing
the classic Lyapunov "V" role in the drift-plus-penalty trade-off —
larger eta buys lower cost at more latency-debt drift.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

ZETA = 1.0       # queue floor (> 0: keeps the controller proactive)
# eta must stay small relative to phi*zeta*(slot benefit): the floor term
# is what makes the controller deploy BEFORE deadlines blow (the paper's
# zeta discussion); large eta starves fresh tasks whose H is still zeta.
ETA = 0.05
PHI_DEFAULT = 1.0


@dataclass
class VirtualQueues:
    """H_j(t) per active task j."""

    zeta: float = ZETA
    h: Dict[int, float] = field(default_factory=dict)

    def admit(self, task_id: int):
        self.h[task_id] = self.zeta

    def update(self, task_id: int, latency_so_far: float, deadline: float):
        """Eq. (18): H <- max{H + T_j(t) - D_n, zeta}."""
        cur = self.h.get(task_id, self.zeta)
        self.h[task_id] = max(cur + latency_so_far - deadline, self.zeta)

    def get(self, task_id: int) -> float:
        return self.h.get(task_id, self.zeta)

    def drop(self, task_id: int):
        self.h.pop(task_id, None)


def drift_plus_penalty_delta(cost_delta: float, h_j: float,
                             latency_delta: float, deadline_slack: float,
                             eta: float = ETA,
                             phi: float = PHI_DEFAULT) -> float:
    """Marginal change of eq. (19) for one incremental decision.

    L = eta * C_lt + sum_j phi_j H_j(t) [T_j(t) - D_n]; an assignment that
    adds `latency_delta` to task j and `cost_delta` to the bill changes L
    by eta*cost_delta + phi*H_j*(latency_delta - slack-release).
    """
    return eta * cost_delta + phi * h_j * (latency_delta - deadline_slack)
