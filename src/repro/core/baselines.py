"""Baselines from Sec. IV: LBRR, GA (PropAvg lives in online_controller)."""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.simulator import Simulator

Y_FIXED = 4   # LBRR / GA fixed parallelism level


def _demand_per_ms(app) -> Dict[int, float]:
    """Mean arrival-rate-weighted load (tasks/ms) per MS."""
    d = {m.idx: 0.0 for m in app.services}
    n_users = 1
    for tt in app.task_types:
        for m in tt.ms_ids:
            d[m] += tt.rate
    return d


def _core_demand_counts(app, net) -> Dict[int, int]:
    """Instances needed so aggregate service rate covers mean load."""
    out = {}
    for m in app.core_ids:
        ms = app.ms(m)
        load = sum(tt.rate for tt in app.types_using(m)) * net.n_users
        per_inst = ms.f_det / ms.a      # tasks/ms one instance sustains
        out[m] = max(1, int(np.ceil(load / per_inst)))
    return out


def _light_need(app, net, m, headroom: float = 1.0) -> int:
    """Little's-law replica count for light MS m at parallelism Y_FIXED."""
    ms = app.ms(m)
    load = sum(tt.rate for tt in app.types_using(m)) * net.n_users
    dur = ms.a * Y_FIXED / max(ms.f_mean, 1e-6)
    return max(1, int(np.ceil(headroom * load * dur / Y_FIXED)))


def _static_light_placement(app, net, counts: Dict[int, int],
                            used: np.ndarray) -> Dict[int, np.ndarray]:
    """Least-loaded static allocation of light replicas."""
    x = {m: np.zeros(net.n_nodes, dtype=int) for m in app.light_ids}
    for m, count in counts.items():
        r = app.ms(m).r
        for _ in range(count):
            with np.errstate(divide="ignore", invalid="ignore"):
                util = np.nanmax(
                    np.where(net.R > 0, (used + r) / net.R, np.inf), axis=1)
            fits = ((net.R - used) >= r).all(axis=1)
            util[~fits] = np.inf
            v = int(np.argmin(util))
            if not np.isfinite(util[v]):
                break
            x[m][v] += 1
            used[v] += r
    return x


# ----------------------------------------------------------------------
# LBRR: least-loaded STATIC allocation + round-robin scheduling
# (paper: "Services are allocated to the least-loaded nodes.  Incoming
#  tasks are then scheduled across available instances using Round-Robin")
# ----------------------------------------------------------------------
class LBRRStrategy:
    name = "lbrr"

    def __init__(self, **_):
        self._rr = 0

    def place_core(self, app, net) -> Dict[int, np.ndarray]:
        self.app, self.net = app, net
        x = {m: np.zeros(net.n_nodes, dtype=int) for m in app.core_ids}
        used = np.zeros_like(net.R)
        for m, count in _core_demand_counts(app, net).items():
            r = app.ms(m).r
            for _ in range(count):
                with np.errstate(divide="ignore", invalid="ignore"):
                    util = np.nanmax(
                        np.where(net.R > 0, (used + r) / net.R, np.inf),
                        axis=1)
                fits = ((net.R - used) >= r).all(axis=1)
                util[~fits] = np.inf
                v = int(np.argmin(util))
                if not np.isfinite(util[v]):
                    break
                x[m][v] += 1
                used[v] += r
        self._used = used
        return x

    def init_light(self, sim: Simulator):
        app, net = self.app, self.net
        counts = {m: _light_need(app, net, m, headroom=1.0)
                  for m in app.light_ids}
        x_lt = _static_light_placement(app, net, counts, self._used)
        for m, xv in x_lt.items():
            for v in range(net.n_nodes):
                for _ in range(int(xv[v])):
                    sim.spawn_instance(v, m, 0.0, persistent=True)

    def assign_light(self, t: float, sim: Simulator,
                     waiting: List[tuple]) -> List[tuple]:
        store = sim.store
        alive = sim.alive_light_idx(t)
        store.refresh_y(alive, t)
        pools = {m: alive[store.m[alive] == m]
                 for m in {mm for _, mm in waiting}}
        still = []
        for tid, m in waiting:
            pa = pools[m]
            cand = pa[store.y_now[pa] < Y_FIXED] if len(pa) else pa
            if not len(cand):
                still.append((tid, m))   # deadline-agnostic queueing
                continue
            inst = int(cand[self._rr % len(cand)])
            self._rr += 1
            sim.commit_light(sim.tasks[tid], m, inst, now=t)
            store.y_now[inst] += 1
        return still


# ----------------------------------------------------------------------
# GA: metaheuristic static deployment of cores + light replica counts
# ----------------------------------------------------------------------
class GAStrategy:
    name = "ga"

    def __init__(self, pop: int = 24, gens: int = 30, seed: int = 0,
                 viol_weight: float = 40000.0, **_):
        self.pop = pop
        self.gens = gens
        self.rng = np.random.default_rng(seed)
        self.viol_weight = viol_weight

    # -- fitness: cost + weighted QoS-violation estimate ---------------
    def _fitness(self, genome) -> float:
        app, net = self.app, self.net
        x_cr, x_lt = genome
        cost = 0.0
        for m in app.core_ids:
            ms = app.ms(m)
            cost += (ms.c_dp + ms.c_mt * 100) * x_cr[m].sum()
        for m in app.light_ids:
            ms = app.ms(m)
            cost += (ms.c_dp + (ms.c_mt + ms.c_pl) * 100) * x_lt[m].sum()
        # capacity feasibility penalty
        used = np.zeros_like(net.R)
        for m in app.core_ids:
            used += x_cr[m][:, None] * app.ms(m).r[None, :]
        for m in app.light_ids:
            used += x_lt[m][:, None] * app.ms(m).r[None, :]
        over = np.maximum(used - net.R, 0).sum()
        # mean-value E2E estimate per task type with queueing inflation
        viol = 0.0
        for tt in app.task_types:
            est = self.mlm.mean_uplink(tt)
            unservable = False
            for m in tt.ms_ids:
                ms = app.ms(m)
                x = x_cr[m] if ms.is_core else x_lt[m]
                n_inst = max(int(x.sum()), 0)
                load = sum(t2.rate for t2 in app.types_using(m)) * net.n_users
                if n_inst == 0:
                    unservable = True
                    continue
                per_inst = (ms.f_det / ms.a if ms.is_core
                            else ms.f_mean / (ms.a * Y_FIXED))
                rho = load / max(n_inst * per_inst, 1e-6)
                infl = 1.0 / max(1.0 - min(rho, 0.95), 0.05)
                base = (ms.a / ms.f_det if ms.is_core
                        else ms.a * Y_FIXED / ms.f_mean)
                est += base * infl + 1.0  # + mean hop
            if unservable:
                viol += 1.0
            else:
                viol += max(0.0, np.tanh((est - tt.deadline) / tt.deadline))
        viol /= len(app.task_types)
        return cost + self.viol_weight * viol + 50.0 * over

    def _random_genome(self):
        app, net = self.app, self.net
        x_cr = {m: np.zeros(net.n_nodes, dtype=int) for m in app.core_ids}
        x_lt = {m: np.zeros(net.n_nodes, dtype=int) for m in app.light_ids}
        demand = _core_demand_counts(app, net)
        for m in app.core_ids:
            for _ in range(max(1, demand[m] + self.rng.integers(-1, 2))):
                x_cr[m][self.rng.integers(net.n_nodes)] += 1
        for m in app.light_ids:
            n = max(1, _light_need(app, net, m) + self.rng.integers(-1, 3))
            for _ in range(n):
                x_lt[m][self.rng.integers(net.n_nodes)] += 1
        return (x_cr, x_lt)

    def _mutate(self, genome):
        x_cr = {m: v.copy() for m, v in genome[0].items()}
        x_lt = {m: v.copy() for m, v in genome[1].items()}
        tbl = x_cr if self.rng.random() < 0.5 else x_lt
        m = list(tbl)[self.rng.integers(len(tbl))]
        v = self.rng.integers(len(tbl[m]))
        if self.rng.random() < 0.5:
            tbl[m][v] += 1
        elif tbl[m][v] > 0:
            tbl[m][v] -= 1
        return (x_cr, x_lt)

    def _crossover(self, g1, g2):
        x_cr = {m: (g1[0][m] if self.rng.random() < 0.5 else g2[0][m]).copy()
                for m in g1[0]}
        x_lt = {m: (g1[1][m] if self.rng.random() < 0.5 else g2[1][m]).copy()
                for m in g1[1]}
        return (x_cr, x_lt)

    def place_core(self, app, net) -> Dict[int, np.ndarray]:
        self.app, self.net = app, net

        class _MLM:
            def __init__(self, net):
                self.net = net

            def mean_uplink(self, tt):
                return float(np.mean([
                    self.net.mean_uplink_ms(u, tt.payload)
                    for u in range(self.net.n_users)]))

        self.mlm = _MLM(net)
        pop = [self._random_genome() for _ in range(self.pop)]
        fits = [self._fitness(g) for g in pop]
        for _ in range(self.gens):
            order = np.argsort(fits)
            elite = [pop[i] for i in order[:max(2, self.pop // 4)]]
            children = []
            while len(children) < self.pop - len(elite):
                a, b = self.rng.integers(len(elite), size=2)
                child = self._mutate(self._crossover(elite[a], elite[b]))
                children.append(child)
            pop = elite + children
            fits = [self._fitness(g) for g in pop]
        self.best = pop[int(np.argmin(fits))]
        # light replica plan is deployed statically (GA is a one-shot
        # deployment optimizer)
        self._light_plan = self.best[1]
        return self.best[0]

    def init_light(self, sim: Simulator):
        for m, xv in self._light_plan.items():
            for v in range(self.net.n_nodes):
                for _ in range(int(xv[v])):
                    sim.spawn_instance(v, m, 0.0, persistent=True)

    def assign_light(self, t: float, sim: Simulator,
                     waiting: List[tuple]) -> List[tuple]:
        store = sim.store
        alive = sim.alive_light_idx(t)
        store.refresh_y(alive, t)
        pools = {m: alive[store.m[alive] == m]
                 for m in {mm for _, mm in waiting}}
        still = []
        for tid, m in waiting:
            pa = pools[m]
            cand = pa[store.y_now[pa] < Y_FIXED] if len(pa) else pa
            if not len(cand):
                still.append((tid, m))
                continue
            # least-contended instance (GA fitness assumed balanced load)
            inst = int(cand[int(np.argmin(store.y_now[cand]))])
            sim.commit_light(sim.tasks[tid], m, inst, now=t)
            store.y_now[inst] += 1
        return still
