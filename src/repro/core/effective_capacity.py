"""Effective capacity theory (Sec. III-B, eqs 20-21).

For a light MS whose per-slot service rate is i.i.d. Gamma(shape a, scale
s) MB/ms, the log-MGF is closed-form, giving

    E_c(theta) = a * ln(1 + theta * s) / theta          (nats/MB scale)

At parallelism y the per-task rate is f/y, i.e. scale s/y.  The QoS
exponent theta links E_c to the latency-tail (eq. 21):

    P{d > D} ~ (E_c(theta)/E[f]) * exp(-theta * E_c(theta) * D)

so the smallest statistically-safe latency budget for violation
probability eps at parallelism y is

    g_{m,eps}(y) = workload_scaled * min_theta D(theta)
    D(theta) = ln(E_c(theta) / (eps * E[f/y])) / (theta * E_c(theta))

We precompute the min over a log-spaced theta grid (vectorized in jnp) —
this is the paper's "pre-calculated deterministic mapping".
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

try:  # jnp for the vectorized grid; falls back to numpy transparently
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = np

THETA_GRID = np.logspace(-3.0, 2.5, 160)


def effective_capacity(theta, shape, scale):
    """E_c(theta) for Gamma(shape, scale) service increments (MB/ms)."""
    return shape * np.log1p(theta * scale) / theta


def latency_budget(shape: float, scale: float, eps: float,
                   workload: float) -> float:
    """Chernoff/large-deviations inversion of eq. (21).

    Time d such that P{F(0,d) < workload} <= eps, where F is the
    cumulative Gamma(shape, scale) service process:

      P{F(0,t) < w} <= exp(theta*w - t*theta*E_c(theta))   (theta > 0)
      => d(theta) = (w + ln(1/eps)/theta) / E_c(theta)
      => g = min_theta d(theta).

    As w grows, g -> w / E_c(theta*): the effective-capacity service rate,
    strictly below the mean rate — the tail-aware margin the PropAvg
    ablation lacks.
    """
    th = THETA_GRID
    ec = effective_capacity(th, shape, scale)
    d = (workload + np.log(1.0 / eps) / th) / ec
    return float(np.min(d))


@dataclass
class ECMap:
    """Deterministic map g_{m,eps}(y) for one light MS."""

    a_mb: float          # workload per task
    shape: float
    scale: float
    eps: float
    y_max: int = 64

    def __post_init__(self):
        # y-way contention: the instance must serve y*a_mb of work for a
        # task admitted at parallelism y
        self.table = np.array([
            latency_budget(self.shape, self.scale, self.eps, self.a_mb * y)
            for y in range(1, self.y_max + 1)])
        mean_rate = self.shape * self.scale
        self.mean_table = np.array([
            self.a_mb * y / mean_rate for y in range(1, self.y_max + 1)])

    def g(self, y: int) -> float:
        """QoS-aware processing-delay estimate at parallelism y (ms)."""
        y = int(np.clip(y, 1, self.y_max))
        return float(self.table[y - 1])

    def g_mean(self, y: int) -> float:
        """PropAvg ablation: mean-value estimate (no tail awareness)."""
        y = int(np.clip(y, 1, self.y_max))
        return float(self.mean_table[y - 1])

    def max_parallelism(self, slack_ms: float) -> int:
        """Largest y whose safe latency still fits in `slack_ms`."""
        ok = np.nonzero(self.table <= slack_ms)[0]
        return int(ok[-1] + 1) if len(ok) else 0


def build_ec_maps(app, eps: float) -> dict:
    """ECMap per light MS of an Application."""
    return {m: ECMap(app.ms(m).a, app.ms(m).f_shape, app.ms(m).f_scale, eps)
            for m in app.light_ids}
