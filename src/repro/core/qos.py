"""Mean-value analysis heuristics for static core placement (Sec. III-A).

For a typical task of type n requiring core MS m at node v:
  d_pr(v, m): preceding latency — mean-value completion time of m's
              parents, routed along shortest (network + mean compute) paths
              from the task's source user to v;
  d_cu(v, m): processing time a_m / f_m at v;
  d_su(v, m): succeeding latency — sum of mean processing of descendants.

Then (eq. 15): load estimate z~_{v,m} apportions each (u, n)'s arrival
rate over nodes by exp(-delta * d_pr); and (eq. 16): urgency
d~ = capped ratio of remaining budget to future work, Q = z~ * d~.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.graph import Application, TaskType
from repro.core.network import EdgeNetwork

DELTA = 0.05     # exponential-decay load apportioning constant
C1_FLOOR = 0.5   # constant C1 in the urgency metric (floor of the ratio)
URG_CAP = 50.0   # numerical-sanity cap (d_su -> 0 for sink-adjacent MSs)


@dataclass
class MeanLatencyModel:
    """Mean-value latency primitives shared by QoS scoring and baselines."""

    app: Application
    net: EdgeNetwork

    def __post_init__(self):
        self._memo = {}

    def mean_proc(self, m: int) -> float:
        return self.app.ms(m).mean_proc_ms()

    def d_pr_vec(self, u: int, tt: TaskType, m: int) -> np.ndarray:
        """Mean completion time of everything before m, for every
        candidate node v at once.

        Recursive eq. (4) with mean values; parent services are assumed
        placed along the min-latency node (shortest-path relaxation of
        the circular routing dependency — see DESIGN.md §7).  Each
        parent hop is one min-plus matrix reduction over the node mesh
        (the old per-(v, v') double loop recursed millions of times on
        scale_load topologies).  Memoized per (u, type, m)."""
        key = (u, tt.idx, m)
        if key in self._memo:
            return self._memo[key]
        ed = self.net.user_ed[u]
        parents = tt.parents(m)
        if not parents:
            # first service: uplink + transfer of the input payload
            up = self.net.mean_uplink_ms(u, tt.payload)
            out = up + (self.net.net_ms[ed] / 1.0) * tt.payload
        else:
            vals = []
            for p in parents:
                # parent served at its own best node v', then ships b_p
                # to v: best[v] = min_v' (prev[v'] + net_ms[v', v] * b_p)
                prev = self.d_pr_vec(u, tt, p) + self.mean_proc(p)
                vals.append((prev[:, None] + (self.net.net_ms / 1.0)
                             * self.app.ms(p).b).min(axis=0))
            out = np.maximum.reduce(vals)
        self._memo[key] = out
        return out

    def d_pr(self, u: int, tt: TaskType, v: int, m: int) -> float:
        """Scalar view of :meth:`d_pr_vec` (kept for API compat)."""
        return float(self.d_pr_vec(u, tt, m)[v])

    def d_su(self, tt: TaskType, m: int) -> float:
        return sum(self.mean_proc(d) for d in tt.descendants(m))


def qos_scores(app: Application, net: EdgeNetwork):
    """Returns (z_tilde, Q): both (V, M_core-indexed dict of arrays)."""
    model = MeanLatencyModel(app, net)
    v_n = net.n_nodes
    core = app.core_ids
    z_tilde = {m: np.zeros(v_n) for m in core}
    q_score = {m: np.zeros(v_n) for m in core}

    for m in core:
        for tt in app.types_using(m):
            d_su = model.d_su(tt, m)
            d_cu = model.mean_proc(m)
            # Little's law: concurrent load = arrival rate x service time
            # (constraint (10) counts tasks *in service*, not arrivals)
            conc = tt.rate * model.mean_proc(m)
            for u in range(net.n_users):
                d_pre = model.d_pr_vec(u, tt, m)
                # eq. (15): exponential-decay apportioning of E[z]
                wgt = np.exp(-DELTA * d_pre)
                wgt = wgt / wgt.sum()
                z_tilde[m] += wgt * conc
                # eq. (16) upper: max{remaining budget / future work, C1}
                # — Q rewards placements whose tasks *comfortably* meet
                # deadlines (paper Sec. III-A); URG_CAP guards d_su -> 0
                denom = max(d_su, 1e-3)
                ratio = (tt.deadline - d_pre - d_cu) / denom
                urg = np.clip(ratio, C1_FLOOR, URG_CAP)
                q_score[m] += wgt * tt.rate * urg
    return z_tilde, q_score
