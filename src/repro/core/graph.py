"""Microservice + task-DAG application model (Fig. 1 of the paper).

Task graphs are *inverse trees*: each node has any number of incoming edges
but at most one outgoing edge (multimodal fusion funnels into one output).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import paper_params as pp


@dataclass
class Microservice:
    idx: int
    name: str
    kind: str                      # "core" | "light"
    r: np.ndarray                  # (K,) resource requirement
    a: float                       # workload MB per task
    b: float                       # output MB
    # core: deterministic rate; light: Gamma(shape, scale) contention model
    f_det: float = 0.0
    f_shape: float = 0.0
    f_scale: float = 0.0
    c_dp: float = 0.0
    c_mt: float = 0.0
    c_pl: float = 0.0

    @property
    def is_core(self) -> bool:
        return self.kind == "core"

    @property
    def f_mean(self) -> float:
        return self.f_det if self.is_core else self.f_shape * self.f_scale

    def mean_proc_ms(self) -> float:
        return self.a / max(self.f_mean, 1e-9)


@dataclass
class TaskType:
    idx: int
    name: str
    ms_ids: List[int]              # all MSs used, topological order
    edges: List[Tuple[int, int]]   # (src_ms, dst_ms) data dependencies
    deadline: float = 0.0          # D_n ms
    payload: float = 0.0           # A_n MB
    rate: float = 0.0              # mean Poisson arrivals per user per ms

    def parents(self, m: int) -> List[int]:
        return [s for s, d in self.edges if d == m]

    def children(self, m: int) -> List[int]:
        return [d for s, d in self.edges if s == m]

    def sources(self) -> List[int]:
        dst = {d for _, d in self.edges}
        return [m for m in self.ms_ids if m not in dst] or self.ms_ids[:1]

    def sink(self) -> int:
        src = {s for s, _ in self.edges}
        sinks = [m for m in self.ms_ids if m not in src]
        assert len(sinks) == 1, "inverse tree must have a single sink"
        return sinks[0]

    def descendants(self, m: int) -> List[int]:
        """All MSs strictly downstream of m (unique path to sink)."""
        out = []
        cur = m
        while True:
            ch = self.children(cur)
            if not ch:
                return out
            assert len(ch) <= 1, "inverse tree: at most one outgoing edge"
            cur = ch[0]
            out.append(cur)

    def validate_inverse_tree(self) -> bool:
        return all(len(self.children(m)) <= 1 for m in self.ms_ids)


@dataclass
class Application:
    services: List[Microservice]
    task_types: List[TaskType]

    @property
    def core_ids(self) -> List[int]:
        return [m.idx for m in self.services if m.is_core]

    @property
    def light_ids(self) -> List[int]:
        return [m.idx for m in self.services if not m.is_core]

    def ms(self, idx: int) -> Microservice:
        return self.services[idx]

    def types_using(self, m: int) -> List[TaskType]:
        return [tt for tt in self.task_types if m in tt.ms_ids]


# ----------------------------------------------------------------------
# Paper evaluation instance: 4 task types, 6 core MSs, 9 light MSs
# ----------------------------------------------------------------------
def _sample_ms(rng, idx, name, kind) -> Microservice:
    spec = pp.TABLE_I["core_ms" if kind == "core" else "light_ms"]
    r = np.array([rng.uniform(lo, hi) for lo, hi in spec["r"]])
    ms = Microservice(
        idx=idx, name=name, kind=kind, r=r,
        a=rng.uniform(*spec["a"]), b=rng.uniform(*spec["b"]),
        c_dp=spec["c_dp"], c_mt=spec["c_mt"], c_pl=spec["c_pl"])
    if kind == "core":
        ms.f_det = rng.uniform(*spec["f"])
    else:
        ms.f_shape = rng.uniform(*spec["f_gamma_shape"])
        ms.f_scale = rng.uniform(*spec["f_gamma_scale"])
    return ms


# Fig.-1-style inverse-tree templates over core ids C0..C5 (global idx 0..5)
# and light ids L0..L8 (global idx 6..14).  Squares=cores, circles=lights.
_DAG_TEMPLATES = [
    # type 0: AR pipeline — two modality branches fuse into a core
    # L0->C0 ; L1->C1 ; {C0,C1}->L2 ; L2->C2 ; C2->L3
    (["L0", "C0", "L1", "C1", "L2", "C2", "L3"],
     [("L0", "C0"), ("L1", "C1"), ("C0", "L2"), ("C1", "L2"),
      ("L2", "C2"), ("C2", "L3")]),
    # type 1: generation — pre, heavy chain, post
    # L4->C3 ; C3->L5 ; L5->C4 ; C4->L6
    (["L4", "C3", "L5", "C4", "L6"],
     [("L4", "C3"), ("C3", "L5"), ("L5", "C4"), ("C4", "L6")]),
    # type 2: three-branch fusion
    # L0->C0 ; L7->C5 ; L8->{merge at L2'}: {C0,C5,L1}->L5'->C2->L3
    (["L0", "C0", "L7", "C5", "L1", "L8", "C2", "L3"],
     [("L0", "C0"), ("L7", "C5"), ("C0", "L8"), ("C5", "L8"),
      ("L1", "L8"), ("L8", "C2"), ("C2", "L3")]),
    # type 3: perception — conv core then fuse with retrieval core
    # L4->C1 ; L7->C3 ; {C1,C3}->L6' ; L6'->C4 ; C4->L5'
    (["L4", "C1", "L7", "C3", "L2", "C4", "L6"],
     [("L4", "C1"), ("L7", "C3"), ("C1", "L2"), ("C3", "L2"),
      ("L2", "C4"), ("C4", "L6")]),
]


def make_application(rng: np.random.Generator,
                     rate_multiplier: float = 1.0,
                     type_rate_multipliers: Optional[Sequence[float]] = None,
                     deadline_multiplier: float = 1.0) -> Application:
    """Sample a paper-scale application instance from Table I ranges.

    `type_rate_multipliers` skews arrival rates per task type (scenario
    registry: skewed-workload mixes) on top of the global
    `rate_multiplier`; `deadline_multiplier` uniformly tightens or
    relaxes deadlines.  Sampling order is fixed, so the same rng seed
    yields the same base instance regardless of the multipliers.
    """
    if type_rate_multipliers is not None:
        assert len(type_rate_multipliers) == len(_DAG_TEMPLATES), \
            "one multiplier per task type"
    services = []
    for i in range(pp.N_CORE_MS):
        services.append(_sample_ms(rng, i, f"C{i}", "core"))
    for i in range(pp.N_LIGHT_MS):
        services.append(_sample_ms(rng, pp.N_CORE_MS + i, f"L{i}", "light"))
    name_to_idx = {ms.name: ms.idx for ms in services}

    task_types = []
    for n, (nodes, edges) in enumerate(_DAG_TEMPLATES):
        type_mult = (type_rate_multipliers[n]
                     if type_rate_multipliers is not None else 1.0)
        tt = TaskType(
            idx=n, name=f"type{n}",
            ms_ids=[name_to_idx[x] for x in nodes],
            edges=[(name_to_idx[s], name_to_idx[d]) for s, d in edges],
            deadline=rng.uniform(*pp.TABLE_I["deadline"])
            * deadline_multiplier,
            payload=rng.uniform(*pp.TABLE_I["input_payload"]),
            rate=rng.uniform(*pp.TABLE_I["arrival_rate"])
            * rate_multiplier * type_mult,
        )
        assert tt.validate_inverse_tree()
        task_types.append(tt)
    return Application(services=services, task_types=task_types)
