"""TABLE I of the paper: parameter ranges.

Each simulation trial samples concrete values from these ranges (the paper:
"values for each run are sampled from predefined ranges").

Resource vector order: [CPU, RAM, GPU, VRAM].
Units: workloads/outputs MB, rates MB/ms, deadlines ms, costs arbitrary.

Symbol key (full glossary in ``repro.core.__init__``): per-MS ``a``/``b``
are the workload a_m and output b_m, ``r`` the requirement vector r_m,
``f`` the deterministic core rate f_det, ``f_gamma_*`` the light-MS
Gamma contention model, ``c_dp``/``c_mt``/``c_pl`` the cost terms of
eqs (6)-(7).
"""
from __future__ import annotations

K_RESOURCES = 4  # CPU, RAM, GPU, VRAM

TABLE_I = {
    "core_ms": {
        "r": [(2, 16), (1, 4), (4, 32), (4, 32)],
        "a": (2.0, 16.0),          # MB workload
        "b": (0.1, 1.0),           # MB output
        "f": (8.0, 32.0),          # MB/ms deterministic rate
        "c_dp": 20.0, "c_mt": 4.0, "c_pl": 0.0,
    },
    "light_ms": {
        "r": [(0.5, 2), (0.0, 0.5), (0.25, 4), (0.0, 1)],
        "a": (0.5, 2.0),
        "b": (0.25, 1.5),
        "f_gamma_shape": (1.0, 2.0),   # Gamma(shape, scale) MB/ms
        "f_gamma_scale": (1.0, 20.0),
        "c_dp": 4.0, "c_mt": 1.0, "c_pl": 0.5,
    },
    "ed": {"R": [(1, 64), (1, 32), (0, 64), (0, 64)]},
    "es": {"R": [(128, 256), (64, 128), (1024, 2048), (256, 512)]},
    "arrival_rate": (0.15, 1.5),       # Poisson mean per (user, type) per ms
    "deadline": (50.0, 100.0),         # ms
    "snr_nakagami_m": (1.5, 3.0),      # Nakagami(m, omega)
    "snr_nakagami_omega": (0.5, 1.0),
    "input_payload": (0.5, 4.0),       # A_n MB
    "link_bw": (0.1, 1.0),             # w MB/ms
    # not tabulated explicitly in Table I; standard choices documented in
    # DESIGN.md: per-user uplink bandwidth and link distance/propagation
    "user_bw": (0.2, 1.0),             # b_u MB/ms
    "link_dist_km": (0.5, 10.0),
    "prop_speed_km_per_ms": 200.0,     # fiber ~2/3 c
}

# Evaluation scenario scale (Sec. IV: 4 task types, 6 core, 9 light MSs)
N_TASK_TYPES = 4
N_CORE_MS = 6
N_LIGHT_MS = 9
N_EDS = 6
N_ESS = 4
N_USERS = 6

# effective-capacity violation probability used by the proposal
EPSILON = 0.2
