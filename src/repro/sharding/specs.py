"""Sharding rules: logical activation/param names -> PartitionSpec.

The model code calls :func:`constrain` with a *logical* name; outside any
mesh context this is a no-op (CPU smoke tests), inside `use_mesh_rules`
(set by the launcher) it applies `jax.lax.with_sharding_constraint`.

Logical axes:
  * data axes ("data", and "pod" when multi-pod) shard the batch;
  * "model" shards heads / ffn-hidden / experts / vocab / d_inner.
"""
from __future__ import annotations

import contextlib
import os
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def sharding_rules(mesh: Mesh) -> dict:
    """Logical activation name -> PartitionSpec for this mesh."""
    b = _batch_axes(mesh)
    return {
        # activations
        "act_btd": P(b, None, None),          # (batch, seq, d_model)
        "act_btf": P(b, None, "model"),       # (batch, seq, d_ff)
        "act_btv": P(b, None, "model"),       # (batch, seq, vocab)
        "act_bthd": P(b, None, "model", None),  # (batch, seq, heads, head_dim)
        "act_btkv": P(b, None, None, None),   # kv heads usually < model axis
        "kv_cache_heads": P(b, None, None, None),
        "kv_cache_seq": P(b, "model", None, None),  # seq-parallel decode cache
        "ssm_state": P(b, "model", None),     # (batch, d_inner, d_state)
        # (experts, cap, d_model): expert-parallel when E divides the model
        # axis, else shard the capacity dim (all-to-all dispatch either way)
        "moe_buf": (
            # ep_dp (§Perf hillclimb): ALSO shard capacity over the data
            # axes so expert FLOPs scale with data parallelism
            [P("model", b, None), P("model", None, None),
             P(None, b + ("model",), None), P(None, "model", None)]
            if os.environ.get("REPRO_MOE_LAYOUT") == "ep_dp" else
            [P("model", None, None), P(None, "model", None)]),
    }


def param_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    """PartitionSpec for a parameter identified by its pytree path.

    Heuristics keyed on dimension names in the model code; divisibility is
    checked and falls back to replication per-dim.
    """
    size = mesh.shape.get("model", 1)

    def ok(dim):
        return dim % size == 0 and dim >= size

    leaf = path.split("/")[-1]
    # stacked segment params have a leading layer dim -> never shard dim 0
    offset = 1 if path.startswith("seg:") else 0
    spec = [None] * len(shape)

    def set_model(dim_idx):
        if 0 <= dim_idx < len(shape) and ok(shape[dim_idx]):
            spec[dim_idx] = "model"

    if leaf in ("w_gate", "w_up"):
        set_model(offset + 1)
    elif leaf == "w_down":
        set_model(offset + 0)
    elif leaf in ("wq", "wo"):
        # wq: (d, H*hd) sharded on heads; wo: (H*hd, d) sharded dim0
        set_model(offset + (1 if leaf == "wq" else 0))
    elif leaf in ("wk", "wv"):
        set_model(offset + 1)  # falls back to replicated if kv*hd % size != 0
    elif leaf == "w" and ("embed" in path or "lm_head" in path):
        set_model(offset + 0 if "embed" in path else offset + 0)
    elif leaf in ("we_gate", "we_up", "we_down"):
        # moe expert weights: (E, d, f) / (E, f, d) — prefer expert dim
        if ok(shape[offset + 0]):
            spec[offset + 0] = "model"
        else:  # tensor-parallel inside experts
            hid = offset + (2 if leaf in ("we_gate", "we_up") else 1)
            set_model(hid)
    elif leaf in ("in_proj", "out_proj"):
        set_model(offset + (1 if leaf == "in_proj" else 0))
    elif leaf in ("conv_w", "A_log", "D", "dt_bias", "x_proj", "dt_proj"):
        # mamba internals: shard d_inner dim where divisible
        for i in range(len(shape) - 1, offset - 1, -1):
            if ok(shape[i]):
                spec[i] = "model"
                break
    return P(*spec)


@contextlib.contextmanager
def use_mesh_rules(mesh: Optional[Mesh]):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    _state.rules = sharding_rules(mesh) if mesh is not None else None
    try:
        yield
    finally:
        _state.mesh = prev
        _state.rules = sharding_rules(prev) if prev is not None else None


def constrain(x, name: str):
    mesh = getattr(_state, "mesh", None)
    if mesh is None:
        return x
    rules = _state.rules
    if name not in rules:
        return x
    spec = rules[name]
    if isinstance(spec, list):  # fallback chain: first fully-applicable wins
        chosen = None
        for cand in spec:
            if len(cand) != x.ndim:
                continue
            if all(_fits(x.shape[i], cand[i], mesh) for i in range(x.ndim)):
                chosen = cand
                break
        spec = chosen if chosen is not None else spec[0]
    if len(spec) != x.ndim:
        return x
    # check divisibility; drop axes that don't divide
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        fixed.append(ax if dim % n == 0 and dim >= n else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def _fits(dim: int, ax, mesh: Mesh) -> bool:
    if ax is None:
        return True
    axes = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def fit_spec(shape: tuple, spec: P, mesh: Mesh) -> NamedSharding:
    """NamedSharding with non-dividing axes dropped (for explicit in_shardings)."""
    fixed = [ax if _fits(d, ax, mesh) else None for d, ax in zip(shape, spec)]
    fixed = fixed + [None] * (len(shape) - len(fixed))
    return NamedSharding(mesh, P(*fixed))
