from repro.sharding.specs import constrain, sharding_rules, use_mesh_rules  # noqa: F401
