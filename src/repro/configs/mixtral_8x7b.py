"""Mixtral-8x7B — MoE 8 experts top-2 with sliding-window attention. [arXiv:2401.04088]"""
from repro.config import ModelConfig, uniform

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=uniform("swa", 32),
    mlp_kind="moe",
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=14336,
    window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)
