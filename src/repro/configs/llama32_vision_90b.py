"""Llama-3.2-Vision-90B — dense GQA decoder with cross-attention image layers.
[hf:meta-llama/Llama-3.2-11B-Vision]

The ViT vision encoder + projector are STUBBED: ``input_specs`` provides
precomputed patch embeddings (batch, n_image_tokens, d_model).  Every 5th
layer cross-attends to them (20 cross layers out of 100).
"""
from repro.config import ModelConfig, every_kth

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    block_pattern=every_kth(100, "attn", "cross", 5),
    mlp_kind="dense",
    rope_theta=500_000.0,
    n_image_tokens=1601,  # one 560x560 tile -> 1601 patch embeddings
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
