"""Architecture config registry.

Every assigned architecture gets one module; ``get_config(arch_id)`` returns
its production :class:`~repro.config.ModelConfig`, ``get_smoke_config`` the
reduced CPU-testable variant.
"""
from __future__ import annotations

import importlib

from repro.config import ModelConfig, ShapeConfig, SHAPES, reduce_config

_ARCH_MODULES = {
    "qwen2-72b": "qwen2_72b",
    "mixtral-8x7b": "mixtral_8x7b",
    "command-r-35b": "command_r_35b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "gemma3-12b": "gemma3_12b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "smollm-360m": "smollm_360m",
    "zamba2-7b": "zamba2_7b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return reduce_config(get_config(arch_id))


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_pairs():
    """All (arch, shape) pairs that are applicable per DESIGN.md rules."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if cfg.supports_shape(s):
                out.append((a, s.name))
    return out
