"""Falcon-Mamba-7B — pure Mamba1 SSM, attention-free. [arXiv:2410.05355]"""
from repro.config import ModelConfig, uniform

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    block_pattern=uniform("mamba1", 64),
    mlp_kind="none",
    ssm_state=16,
    d_inner=8192,
    conv_width=4,
    source="arXiv:2410.05355",
)
