"""Zamba2-7B — hybrid Mamba2 backbone with shared attention blocks.
[arXiv:2411.15242]

81 layers: Mamba2 blocks with a (shared-weight) full-attention transformer
block interleaved every 6th layer.  kv=32 with 32 heads = MHA in the shared
block.  d_model 3584 -> head_dim 112; we use 112 (14 lanes of 8... padded in
kernels to 128 where required).
"""
from repro.config import ModelConfig, every_kth

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=every_kth(81, "mamba2", "attn", 6),
    mlp_kind="dense",
    ssm_state=64,
    d_inner=7168,
    conv_width=4,
    mamba2_headdim=64,
    shared_block_kind="attn",  # Zamba2's hallmark: interleaved attn blocks share weights
    source="arXiv:2411.15242",
)
