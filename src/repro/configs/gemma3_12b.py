"""Gemma 3 12B — dense GQA, 5 local : 1 global attention, 128k context.
[hf:google/gemma-3-1b-pt family]"""
from repro.config import ModelConfig, local_global

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    block_pattern=local_global(48, local=5),
    mlp_kind="dense",
    window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
)
