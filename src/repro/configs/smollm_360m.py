"""SmolLM-360M — llama-arch small dense model. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.config import ModelConfig, uniform

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    block_pattern=uniform("attn", 32),
    mlp_kind="dense",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
