"""Kimi K2 — trillion-param MoE, 384 experts top-8. [arXiv:2501.kimi2]

Per the assignment table: 61L, d_model=7168, 64H (GQA kv=8), per-expert
d_ff=2048, vocab=163840.  head_dim=112 (7168/64) per the paper-table; we
keep 128 for MXU alignment (projection shapes absorb the difference).
"""
from repro.config import ModelConfig, uniform

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    block_pattern=uniform("attn", 61),
    mlp_kind="moe",
    n_experts=384,
    experts_per_token=8,
    moe_d_ff=2048,
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2",
)
