"""SeamlessM4T-medium — enc-dec multimodal (speech) transformer backbone.
[arXiv:2308.11596]

The conv/mel audio frontend is STUBBED: ``input_specs`` provides precomputed
frame embeddings of shape (batch, encoder_seq, d_model) per the brief's
carve-out; this module implements the encoder-decoder transformer that
consumes them.
"""
from repro.config import ModelConfig, uniform

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    block_pattern=uniform("attn", 12),
    mlp_kind="dense",
    is_encoder_decoder=True,
    n_encoder_layers=12,
    encoder_seq=1024,  # stub frontend frame embeddings
    source="arXiv:2308.11596",
)
