"""Command R 35B — dense GQA, no bias, large vocab. [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.config import ModelConfig, uniform

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    block_pattern=uniform("attn", 40),
    mlp_kind="dense",
    qkv_bias=False,
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
