"""Line-coverage gate over the serving-critical modules.

``make test`` runs the tier-1 suite through this gate: coverage of
``src/repro/serving/``, ``src/repro/core/``, and
``src/repro/models/kvcache.py`` must stay at or above the committed
floor (``COV_FLOOR`` in the Makefile — the measured baseline minus one
point, so a PR that lands untested scheduler/cache code fails CI).

Measurement backend, best available first:

* ``pytest-cov`` when installed: the suite runs under ``--cov`` with
  ``--cov-fail-under`` doing the enforcement;
* stdlib ``sys.settrace`` otherwise: a selective tracer that only pays
  per-line cost inside the target files (the global trace function
  returns ``None`` for everything else, so jax/numpy internals — the
  bulk of suite runtime — run untraced).  Executable lines come from
  compiling each target file and walking its code objects' ``co_lines``
  tables, the same universe ``coverage.py`` uses.

Exit status: pytest's if the suite fails; 1 if the suite passes but
coverage is below the floor; 0 otherwise.  ``--report`` prints the
per-file table.  No third-party dependency is required, so the gate
cannot silently vanish from CI when the environment is minimal.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: coverage universe: the modules whose untested regressions hurt most
#: (scheduler/engine state machines, the block ledger, the paper math)
TARGETS = (
    "src/repro/serving",
    "src/repro/core",
    "src/repro/models/kvcache.py",
)


def target_files() -> list:
    out = []
    for t in TARGETS:
        p = os.path.join(ROOT, t)
        if os.path.isfile(p):
            out.append(p)
        else:
            for dirpath, _, names in os.walk(p):
                out += [os.path.join(dirpath, n) for n in names
                        if n.endswith(".py")]
    return sorted(out)


def executable_lines(path: str) -> set:
    """Line numbers that can execute: the union of every code object's
    ``co_lines`` table (functions, comprehensions, class and module
    bodies), minus docstring-only entries compile() already omits."""
    with open(path, "r", encoding="utf-8") as fh:
        src = fh.read()
    try:
        code = compile(src, path, "exec")
    except SyntaxError:
        return set()
    lines, stack = set(), [code]
    while stack:
        co = stack.pop()
        for const in co.co_consts:
            if hasattr(const, "co_lines"):
                stack.append(const)
        for _, _, lineno in co.co_lines():
            if lineno is not None:
                lines.add(lineno)
    return lines


def run_with_settrace(pytest_args: list):
    """(pytest_rc, hits) — run the suite under a selective tracer."""
    import pytest

    targets = {os.path.realpath(p): p for p in target_files()}
    hits = {p: set() for p in targets.values()}
    # code objects carry whatever path the import system saw (relative
    # PYTHONPATH entries, tests/../src detours) — canonicalize each
    # distinct co_filename once, then it's one dict probe per call
    canon: dict = {}

    def local(frame, event, arg):
        if event == "line":
            hits[canon[frame.f_code.co_filename]].add(frame.f_lineno)
        return local

    def tracer(frame, event, arg):
        fn = frame.f_code.co_filename
        t = canon.get(fn, 0)
        if t is None:
            return None  # untraced frame: zero per-line overhead
        if t == 0:
            t = canon[fn] = (None if fn.startswith("<") else
                             targets.get(os.path.realpath(fn)))
            if t is None:
                return None
        return local(frame, event, arg)

    threading.settrace(tracer)
    sys.settrace(tracer)
    try:
        rc = pytest.main(pytest_args)
    finally:
        sys.settrace(None)
        threading.settrace(None)
    return rc, hits


def run_with_pytest_cov(pytest_args: list, floor: float) -> int:
    import pytest
    cov_args = [f"--cov={t}" for t in
                (os.path.join(ROOT, t) for t in TARGETS)]
    return pytest.main(pytest_args + cov_args
                       + [f"--cov-fail-under={floor}"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--floor", type=float, default=0.0,
                    help="minimum percent line coverage over the "
                         "target modules (0 = measure only)")
    ap.add_argument("--report", action="store_true",
                    help="print the per-file coverage table")
    ap.add_argument("pytest_args", nargs="*",
                    help="arguments forwarded to pytest")
    args = ap.parse_args(argv)
    pytest_args = args.pytest_args or ["-x", "-q", "-m", "not tier2"]

    try:
        import pytest_cov  # noqa: F401
        return run_with_pytest_cov(pytest_args, args.floor)
    except ImportError:
        pass

    rc, hits = run_with_settrace(pytest_args)
    if rc != 0:
        return rc

    total_exec = total_hit = 0
    rows = []
    for path in sorted(hits):
        ex = executable_lines(path)
        if not ex:
            continue
        hit = len(ex & hits[path])
        total_exec += len(ex)
        total_hit += hit
        rows.append((os.path.relpath(path, ROOT), hit, len(ex)))
    pct = 100.0 * total_hit / max(1, total_exec)
    if args.report:
        for rel, hit, ex in rows:
            print(f"{rel:<48} {hit:>5}/{ex:<5} {100.0 * hit / ex:6.1f}%")
    print(f"covgate: {total_hit}/{total_exec} lines "
          f"({pct:.1f}%) over {len(rows)} files; floor {args.floor}%")
    if pct < args.floor:
        print(f"covgate: FAIL — coverage {pct:.1f}% is below the "
              f"committed floor {args.floor}%")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
