"""Per-path rule sets: which invariant applies where.

Paths are repo-root-relative posix globs (``fnmatch`` semantics, and a
pattern with no ``/`` wildcard also matches by prefix for directories).
Three kinds of scoping:

* **generic rules** run on every linted file;
* **scoped rules** only make sense on specific layers (the host-layer
  JAX ban, the engine step-clock ban);
* **exemptions** carve out files where the "violation" is the module's
  job (the ledger touching its own private fields; benchmarks timing
  with ``perf_counter``).

Keeping this table in one module — instead of scattering per-rule
lists across the rule files — is deliberate: a reviewer can read the
whole enforcement surface in one screen, and the expansion frontier
(paths a rule should grow to cover) is a one-line diff here.
"""
from __future__ import annotations

import fnmatch
from typing import Iterable

#: directories never descended into
EXCLUDE_DIRS = {"__pycache__", ".git"}

#: files never linted: the rule fixtures violate on purpose
EXCLUDE_PATHS = (
    "tests/reprolint_fixtures/*",
)

#: rules that run on every linted file
GENERIC_RULES = (
    "jit-donation",
    "host-sync",
    "seeded-rng",
    "traced-truthiness",
    "mutable-default",
)

#: scoped rules -> the paths they run on.  step-clock covers the
#: engine/simulator step logic only: benchmarks, examples, and the
#: launch CLIs time wall-clock legitimately and are exempt by absence.
SCOPED_RULES = {
    # the planning/scheduling layer must stay importable (and testable)
    # without JAX — FakeEngine's whole point (serving/testbed.py)
    "host-layer-jax": (
        "src/repro/serving/scheduler.py",
        "src/repro/serving/testbed.py",
        "src/repro/core/simulator*.py",
    ),
    # engine/simulator time is the step counter, never the wall clock
    "step-clock": (
        "src/repro/serving/*",
        "src/repro/core/*",
        "src/repro/models/*",
    ),
}

#: rule -> paths exempt from it.  ledger-privacy: the ledger itself and
#: its dedicated test harnesses (they assert on refcounts/free lists by
#: design); everything else goes through the public PagedCache API.
#: quant-static-weights: quantize.py owns the packers, its unit tests
#: exercise them directly, and the kernel benches time raw packed
#: buffers; everything else goes through quantize_params(params, fmt).
RULE_EXEMPT_PATHS = {
    "ledger-privacy": (
        "src/repro/models/kvcache.py",
        "tests/test_paged.py",
        "tests/test_paged_props.py",
        "tests/test_prefix_sharing.py",
    ),
    "quant-static-weights": (
        "src/repro/models/quantize.py",
        "tests/test_quant_matmul.py",
        "tests/test_quant.py",
        "tests/test_kernels.py",
        "benchmarks/kernels_bench.py",
    ),
}

#: owner-module rules: scoped-on-everywhere minus their exemptions
PRIVACY_RULES = ("ledger-privacy", "quant-static-weights")

#: methods forming the engine macro-step host path: the one deliberate
#: device->host materialization per macro-step lives here (suppressed
#: with a reason); anything else is a hot-loop host sync.  Read by the
#: host-sync rule.
HOT_LOOP_METHODS = {"_forward_steps", "_run_macro", "_macro_tail",
                    "_apply_cow", "_forward_verify", "_run_verify",
                    "_spec_tail"}

#: jit-wrapped functions allowed to skip donation without suppression:
#: none — the known exemption (the profiling decode jit) carries an
#: inline suppression instead, so the "why" lives next to the code.
JIT_DONATION_EXEMPT: tuple = ()


def _match(rel: str, patterns: Iterable[str]) -> bool:
    # fnmatch's ``*`` crosses ``/``, so ``dir/*`` covers nested files
    return any(fnmatch.fnmatch(rel, pat) for pat in patterns)


def excluded(rel: str) -> bool:
    return _match(rel, EXCLUDE_PATHS)


def rules_for(rel: str) -> set:
    """The rule-name set to run on one repo-relative path."""
    names = set(GENERIC_RULES)
    for rule, pats in SCOPED_RULES.items():
        if _match(rel, pats):
            names.add(rule)
    for rule in PRIVACY_RULES:
        if not _match(rel, RULE_EXEMPT_PATHS.get(rule, ())):
            names.add(rule)
    return names
