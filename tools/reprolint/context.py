"""Per-file analysis context shared by every rule.

One parse + one pre-walk per file computes everything the rules need:

* **parent links** — ``parent(node)`` / ``ancestors(node)`` /
  ``enclosing_functions(node)``;
* **import tracking** — ``qualname(node)`` resolves a ``Name`` /
  ``Attribute`` chain through the file's import aliases to a dotted
  module path (``jnp.asarray`` -> ``jax.numpy.asarray``, ``partial``
  -> ``functools.partial``), so rules match *what* is called, not what
  it happens to be spelled;
* **scope tracking** — ``binds(name, at)`` reports whether ``name`` is
  rebound by a parameter / assignment / def / import in any scope
  enclosing ``at`` (used to tell the ``hash`` builtin from a local
  variable called ``hash``);
* **traced regions** — the set of function bodies JAX traces:
  ``jax.jit``-decorated defs, functions passed to ``jax.jit(...)``,
  and the body callables of ``lax.scan`` / ``while_loop`` /
  ``fori_loop`` / ``cond`` / ``shard_map``, plus anything lexically
  nested inside one.  ``in_traced(node)`` is what the host-sync and
  traced-truthiness rules key on;
* **suppressions** — inline ``# reprolint: disable=<rules> -- <why>``
  (same line) and ``# reprolint: disable-next=<rules> -- <why>``
  (next line) directives, parsed with their required reason.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Set, Tuple

SUPPRESS_RE = re.compile(
    r"#\s*reprolint:\s*(?P<kind>disable(?:-next)?)\s*=\s*"
    r"(?P<rules>[\w,-]+)\s*(?:--\s*(?P<reason>.+?)\s*)?$")

#: decorators / wrappers whose callee function JAX traces
_JIT_NAMES = ("jax.jit", "jax.pmap")
#: (fqname, positional indices of traced callables) — control-flow
#: primitives whose body arguments execute under trace
_TRACED_CALLEE_ARGS = {
    "jax.lax.scan": (0,),
    "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": (2,),
    "jax.lax.cond": (1, 2),
    "jax.lax.switch": None,  # every arg from 1 on
    "jax.experimental.shard_map.shard_map": (0,),
    "jax.checkpoint": (0,),
    "jax.remat": (0,),
    "jax.vmap": (0,),
    "jax.grad": (0,),
    "jax.value_and_grad": (0,),
}

_SCOPE_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef, ast.Module)
_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


@dataclass
class Suppression:
    """One parsed ``# reprolint: disable[-next]=...`` directive."""
    line: int                    # line the directive sits on
    applies_to: int              # line whose findings it suppresses
    rules: Tuple[str, ...]       # rule names, or ("all",)
    reason: Optional[str]        # text after ``--`` (required)
    used: bool = False


class FileContext:
    """Parsed file + the shared analyses rules key on."""

    def __init__(self, path: str, relpath: str, source: str):
        self.path = path
        self.relpath = relpath          # repo-root-relative, posix
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self._parents: Dict[int, ast.AST] = {}
        self.imports: Dict[str, str] = {}   # alias -> dotted module path
        self._index()
        self.suppressions = self._parse_suppressions()
        self._traced_roots = self._find_traced_roots()

    # ------------------------------------------------------------------
    # structure
    # ------------------------------------------------------------------
    def _index(self):
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self._parents[id(child)] = node
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.imports[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.imports[a.asname or a.name] = (
                        f"{node.module}.{a.name}")

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self._parents.get(id(node))

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def enclosing_functions(self, node: ast.AST) -> List[ast.AST]:
        """Innermost-first chain of enclosing function/lambda nodes."""
        return [a for a in self.ancestors(node)
                if isinstance(a, _FUNC_NODES)]

    def enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        for a in self.ancestors(node):
            if isinstance(a, ast.ClassDef):
                return a
        return None

    # ------------------------------------------------------------------
    # names
    # ------------------------------------------------------------------
    def qualname(self, node: ast.AST) -> Optional[str]:
        """Dotted path of a Name/Attribute chain with import aliases
        resolved; None for anything that is not a plain chain."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.imports.get(node.id, node.id)
        return ".".join([root] + parts[::-1])

    def call_qualname(self, call: ast.Call) -> Optional[str]:
        return self.qualname(call.func)

    @staticmethod
    def _scope_nodes(scope: ast.AST) -> Iterator[ast.AST]:
        """Walk a scope's body without descending into nested scopes
        (the nested def/lambda/class node itself IS yielded — its name
        binds in the outer scope — but not its body)."""
        body = getattr(scope, "body", [])
        stack = list(body) if isinstance(body, list) else []
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, _FUNC_NODES + (ast.ClassDef,)):
                stack.extend(ast.iter_child_nodes(node))

    def binds(self, name: str, at: ast.AST) -> bool:
        """True if ``name`` is bound by a parameter, assignment, def,
        or import in any scope enclosing ``at`` (i.e. it is NOT the
        builtin there)."""
        scopes = [a for a in self.ancestors(at)
                  if isinstance(a, _SCOPE_NODES)]
        if self.tree not in scopes:
            scopes.append(self.tree)
        for scope in scopes:
            if isinstance(scope, _FUNC_NODES):
                args = scope.args
                params = (args.args + args.posonlyargs + args.kwonlyargs
                          + ([args.vararg] if args.vararg else [])
                          + ([args.kwarg] if args.kwarg else []))
                if any(p.arg == name for p in params):
                    return True
            for sub in self._scope_nodes(scope):
                if isinstance(sub, ast.Name) and sub.id == name \
                        and isinstance(sub.ctx, ast.Store):
                    return True
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.ClassDef)) and sub.name == name:
                    return True
                if isinstance(sub, (ast.Import, ast.ImportFrom)):
                    for a in sub.names:
                        if (a.asname or a.name.split(".")[0]) == name:
                            return True
        return False

    # ------------------------------------------------------------------
    # traced regions
    # ------------------------------------------------------------------
    def _local_defs(self) -> Dict[str, List[ast.AST]]:
        defs: Dict[str, List[ast.AST]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)
        return defs

    def _is_jit_expr(self, node: ast.AST) -> bool:
        """``jax.jit`` itself, or ``functools.partial(jax.jit, ...)``."""
        q = self.qualname(node)
        if q in _JIT_NAMES:
            return True
        if isinstance(node, ast.Call) \
                and self.call_qualname(node) == "functools.partial" \
                and node.args and self.qualname(node.args[0]) in _JIT_NAMES:
            return True
        return False

    def _find_traced_roots(self) -> Set[int]:
        roots: Set[int] = set()
        defs = self._local_defs()

        def mark(arg: ast.AST):
            if isinstance(arg, ast.Lambda):
                roots.add(id(arg))
            elif isinstance(arg, ast.Name):
                for d in defs.get(arg.id, []):
                    roots.add(id(d))
            elif isinstance(arg, ast.Call):
                # functools.partial(body, ...) passed as the callee
                if self.call_qualname(arg) == "functools.partial" \
                        and arg.args:
                    mark(arg.args[0])

        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if any(self._is_jit_expr(d) for d in node.decorator_list):
                    roots.add(id(node))
            if not isinstance(node, ast.Call):
                continue
            q = self.call_qualname(node)
            if q in _JIT_NAMES:  # jax.jit(fn, ...)
                if node.args:
                    mark(node.args[0])
            elif q in _TRACED_CALLEE_ARGS or (
                    q and q.endswith((".scan", ".while_loop", ".fori_loop",
                                      ".cond", ".shard_map"))
                    and q.startswith("jax.")):
                idxs = _TRACED_CALLEE_ARGS.get(
                    q, _TRACED_CALLEE_ARGS.get(
                        "jax.lax." + q.rsplit(".", 1)[-1]))
                if idxs is None:
                    idxs = range(1, len(node.args))
                for i in idxs:
                    if i < len(node.args):
                        mark(node.args[i])
        return roots

    def in_traced(self, node: ast.AST) -> bool:
        """True when ``node`` sits inside a function body JAX traces
        (including functions lexically nested in one)."""
        return any(id(f) in self._traced_roots
                   for f in self.enclosing_functions(node))

    # ------------------------------------------------------------------
    # suppressions
    # ------------------------------------------------------------------
    def _next_code_line(self, after: int) -> int:
        """First line past ``after`` that is not blank or pure comment
        (a ``disable-next`` reason may wrap onto continuation comment
        lines; the directive still targets the code below them)."""
        for i in range(after + 1, len(self.lines) + 1):
            stripped = self.lines[i - 1].strip()
            if stripped and not stripped.startswith("#"):
                return i
        return after + 1

    def _parse_suppressions(self) -> List[Suppression]:
        out = []
        for i, line in enumerate(self.lines, start=1):
            m = SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group("rules").split(",")
                          if r.strip())
            applies = (self._next_code_line(i)
                       if m.group("kind") == "disable-next" else i)
            out.append(Suppression(line=i, applies_to=applies,
                                   rules=rules, reason=m.group("reason")))
        return out

    def suppression_for(self, rule: str, line: int) -> \
            Optional[Suppression]:
        for s in self.suppressions:
            if s.applies_to == line and (rule in s.rules
                                         or "all" in s.rules):
                return s
        return None
