"""host-sync: no device->host materialization in the decode hot loop.

The hot-loop contract (SERVING.md §The decode hot loop, PR 5) is
quantitative: steady-state decode costs at most **1/K** host syncs per
generated token — one ``np.asarray`` on the macro-step output, nothing
else.  A stray ``.item()`` / ``int(traced)`` / ``np.asarray`` /
``block_until_ready`` re-introduces a per-token (or per-scan-step!)
device round trip without failing any parity test; the masked-row
subtlety in PR 5 came from exactly this class of bug.

Two scopes:

* **traced regions** (jit-decorated functions, ``lax.scan`` bodies):
  ANY host materialization is flagged — inside a trace these are
  either errors (``int()`` on a tracer raises) or silent
  constant-folding hazards.  ``int()``/``float()`` casts of shapes,
  ``len()``, and literals are static and stay allowed.
* **engine macro-step methods** (``config.HOT_LOOP_METHODS``:
  ``_forward_steps`` / ``_run_macro`` / ``_macro_tail`` /
  ``_apply_cow``): device-transfer calls (``np.asarray`` /
  ``np.array`` / ``jax.device_get`` / ``.block_until_ready()`` /
  ``.item()`` / ``.tolist()``) are flagged — the ONE deliberate sync
  per macro-step carries an inline suppression saying so.  Host-side
  ``int()`` casts of numpy values are fine there and are not flagged.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.reprolint import config
from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register

#: method attributes that force a device sync wherever they appear
_SYNC_ATTRS = {"item", "block_until_ready", "tolist"}
#: call targets that materialize a device value on the host
_TRANSFER_FNS = {"numpy.asarray", "numpy.array", "jax.device_get"}
#: builtin casts that sync when fed a traced/device value
_CAST_FNS = {"int", "float", "bool", "complex"}
#: attribute roots that make a cast static (trace-time) and safe
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize"}


def _is_static_arg(node: ast.AST) -> bool:
    """Casts of literals, ``len(...)``, and shape/dtype metadata are
    resolved at trace time — not host syncs."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id == "len":
        return True
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            return True
    return False


@register
class HostSync(Rule):
    name = "host-sync"
    description = ("no device->host materialization (.item(), "
                   "int()/float() casts, np.asarray, "
                   "block_until_ready) inside traced code or the "
                   "engine macro-step path")
    motivation = ("PR 5's <=1/K host-sync bound: one np.asarray per "
                  "macro-step is the budget; everything else rots "
                  "tokens/s silently")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            in_trace = ctx.in_traced(node)
            in_hot = self._in_hot_method(ctx, node)
            if not (in_trace or in_hot):
                continue
            where = ("traced code" if in_trace
                     else "the engine macro-step path")
            q = ctx.call_qualname(node)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_ATTRS:
                yield self.finding(
                    ctx, node,
                    f".{node.func.attr}() forces a device sync inside "
                    f"{where}")
                continue
            if q in _TRANSFER_FNS:
                yield self.finding(
                    ctx, node,
                    f"{q.replace('numpy', 'np')}() materializes a "
                    f"device value inside {where} — keep the hot loop "
                    f"on device (one sync per macro-step is the "
                    f"budget)")
                continue
            if in_trace and isinstance(node.func, ast.Name) \
                    and node.func.id in _CAST_FNS \
                    and not ctx.binds(node.func.id, node) \
                    and node.args \
                    and not _is_static_arg(node.args[0]):
                yield self.finding(
                    ctx, node,
                    f"{node.func.id}() cast of a (potentially traced) "
                    f"value inside traced code — a concretization "
                    f"error at best, a silent constant-fold at worst")

    @staticmethod
    def _in_hot_method(ctx: FileContext, node: ast.AST) -> bool:
        return any(isinstance(f, (ast.FunctionDef, ast.AsyncFunctionDef))
                   and f.name in config.HOT_LOOP_METHODS
                   for f in ctx.enclosing_functions(node))
