"""seeded-rng: SeedSequence-only randomness, crc32-only seeding.

Reproducibility here is byte-level: trial results replay identically
across worker counts and processes (tests/test_vectorized_replay.py).
Two historical bug classes, both from PR 1:

* **legacy global-state RNG** — ``np.random.rand()`` & friends draw
  from a hidden module-global stream: any library call (or test
  ordering change) that also touches it silently reshuffles every
  "seeded" experiment.  All randomness flows through explicit
  ``np.random.default_rng`` / ``Generator`` / ``SeedSequence`` objects
  injected per stream.
* **builtin hash() for seeding** — ``hash(name)`` is salted per
  process by PYTHONHASHSEED, so "fixed-seed" trials differed across
  runs until the crc32 fix (``core/experiment.py``,
  tests/test_simulator_invariants.py pins the values).  Stable name
  folding uses ``zlib.crc32``.

The stdlib ``random`` module's global-state functions are banned for
the same reason as numpy's.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register

#: the explicit-stream API that is allowed through
_NP_RANDOM_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937",
}
#: stdlib random: constructing an explicit instance is fine
_STDLIB_OK = {"Random", "SystemRandom"}


@register
class SeededRng(Rule):
    name = "seeded-rng"
    description = ("no module-level np.random.* / random.* draws (use "
                   "an injected default_rng/SeedSequence stream) and "
                   "no builtin hash() for seeding (use zlib.crc32)")
    motivation = ("PR 1: hash() is PYTHONHASHSEED-salted and the "
                  "legacy global RNG stream is shared mutable state — "
                  "both broke byte-identical replay")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            q = ctx.call_qualname(node)
            if q and q.startswith("numpy.random."):
                leaf = q.split(".")[2]
                if leaf not in _NP_RANDOM_OK:
                    yield self.finding(
                        ctx, node,
                        f"np.random.{leaf}() draws from the hidden "
                        f"module-global stream — thread an explicit "
                        f"np.random.default_rng/SeedSequence stream "
                        f"through instead")
            elif q and q.startswith("random.") and q.count(".") == 1 \
                    and ctx.imports.get("random") == "random":
                leaf = q.split(".")[1]
                if leaf not in _STDLIB_OK:
                    yield self.finding(
                        ctx, node,
                        f"random.{leaf}() uses the stdlib's global "
                        f"RNG state — use an injected "
                        f"np.random.default_rng stream")
            elif isinstance(node.func, ast.Name) \
                    and node.func.id == "hash" \
                    and not ctx.binds("hash", node):
                yield self.finding(
                    ctx, node,
                    "builtin hash() is salted per process by "
                    "PYTHONHASHSEED — for stable name folding use "
                    "zlib.crc32(s.encode()) (core/experiment.py)")
