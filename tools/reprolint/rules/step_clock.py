"""step-clock: engine/simulator time is the step counter, not the wall.

``Request.t_submit`` / ``t_admit`` / ``t_first`` / ``t_done`` are
stamped in engine *step-counter* units (one ``step()`` = one decode
iteration) and the simulator advances in slots — that is what makes
queueing delay and TTFT/TPOT deadlines comparable across engines,
machines, and CI boxes, and what keeps golden streams and goodput
baselines byte-reproducible.  A ``time.time()`` / ``perf_counter()``
leaking into step logic ties scheduling decisions to host load: the
numbers stop replaying and the SLO accounting silently becomes
machine-dependent.

Benchmarks, examples, and the launch CLIs measure wall time on
purpose; they are exempt by path in ``config.SCOPED_RULES`` (this rule
only runs over the serving/core/models layers).
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register

_WALL_CLOCK = {
    "time.time", "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns", "time.process_time",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}


@register
class StepClock(Rule):
    name = "step-clock"
    description = ("no wall-clock reads (time.time/perf_counter/...) "
                   "in engine or simulator step logic — the step "
                   "counter is the only clock")
    motivation = ("engine-step stamps are what keep golden streams "
                  "and goodput baselines byte-reproducible across "
                  "machines (PR 4/6 timestamp semantics)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            q = ctx.call_qualname(node)
            if q in _WALL_CLOCK:
                yield self.finding(
                    ctx, node,
                    f"{q}() reads the wall clock inside step logic — "
                    f"engine/simulator time is the step counter "
                    f"(Request.t_* stamps); wall timing belongs in "
                    f"benchmarks/")
