"""quant-static-weights: packed weights are static, non-donated, and
only models/quantize.py packs them.

Weight-only quantization (SERVING.md §Quantization) ships packed
``{"q": ints, "s": scales}`` leaves through every jit in the decode
path.  The contract that keeps the whole stack correct:

* **Packing is quantize.py's job.**  Everything else calls
  ``quantize_params(params, fmt)`` once at engine construction; a
  stray ``quantize_int8`` / ``pack_int4`` call elsewhere forks the
  format decision (group size, scale dtype, nibble order) away from
  the one module that owns it — and silently diverges from the golden
  harness when quantize.py evolves.
* **Packed leaves are immutable.**  The engines treat weights as
  constants; writing into a packed leaf's ``"q"``/``"s"`` slot after
  construction invalidates the committed goldens without failing any
  shape check (int8 buffers accept any int8 garbage).
* **Weights are never donated.**  The decode jits donate *caches*
  (linear state, rebound every call) but reuse the same weight buffers
  for the process lifetime; a ``jax.jit`` that donates a
  params/weights-named argument frees the packed buffers after the
  first call and the next step reads deallocated memory (or silently
  copies, on backends that refuse).

The rule is AST-static: it flags (1) packer calls outside the
exemption list (quantize.py itself, its unit tests, and the kernels
microbench that times raw packed buffers), (2) stores into a
``["q"]``/``["s"]`` subscript of a params/weights/packed-named
expression, (3) ``jax.jit(..., donate_argnums/argnames)`` covering a
params/weights-named parameter of a resolvable local def or lambda.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, List, Optional

from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register

#: the packing entry points owned by models/quantize.py
PACKERS = ("quantize_int8", "quantize_int4", "pack_int4",
           "_quantize_leaf")

#: parameter / base-expression names that hold model weights
WEIGHTS_RE = re.compile(r"(^|_)(params?|weights?|packed|quant)(_|$)")


def _param_names(fn: ast.AST) -> List[str]:
    args = fn.args
    return [a.arg for a in args.posonlyargs + args.args]


def _base_name(node: ast.AST) -> str:
    """Innermost Name/Attribute identifier of a subscript chain."""
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        node = node.value if isinstance(node, ast.Subscript) \
            else node.value
    if isinstance(node, ast.Name):
        return node.id
    return ""


def _last_attr(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


@register
class QuantStaticWeights(Rule):
    name = "quant-static-weights"
    description = ("packed quant weights enter jit static and "
                   "non-donated, are never mutated, and only "
                   "models/quantize.py packs them")
    motivation = ("a stray packer call forks the format decision; a "
                  "mutated or donated packed leaf invalidates the "
                  "committed goldens without failing any shape check")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                yield from self._check_packer_call(ctx, node)
                if ctx.call_qualname(node) == "jax.jit":
                    yield from self._check_donation(ctx, node)
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                yield from self._check_store(ctx, node)

    # -- (1) packing outside quantize.py -------------------------------
    def _check_packer_call(self, ctx, call) -> Iterator[Finding]:
        name = _last_attr(call.func)
        if name in PACKERS:
            yield self.finding(
                ctx, call,
                f"{name}() packs quant weights outside models/quantize.py"
                f" — go through quantize_params(params, fmt) so the "
                f"format decision (group size, scales, nibble order) "
                f"stays in the module that owns it")

    # -- (2) mutating a packed leaf ------------------------------------
    def _check_store(self, ctx, node) -> Iterator[Finding]:
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for t in targets:
            if not isinstance(t, ast.Subscript):
                continue
            key = t.slice
            if not (isinstance(key, ast.Constant)
                    and key.value in ("q", "s")):
                continue
            if WEIGHTS_RE.search(_base_name(t)):
                yield self.finding(
                    ctx, node,
                    f"store into packed quant leaf slot "
                    f"[{key.value!r}] — packed weights are immutable "
                    f"after quantize_params(); rebuild the tree instead")

    # -- (3) donating a weights-named jit argument ---------------------
    def _check_donation(self, ctx, call) -> Iterator[Finding]:
        donated_names = set()
        donated_idxs = set()
        for kw in call.keywords:
            if kw.arg == "donate_argnames":
                donated_names |= _const_set(kw.value, str)
            elif kw.arg == "donate_argnums":
                donated_idxs |= _const_set(kw.value, int)
        if not donated_names and not donated_idxs:
            return
        fn = self._resolve(ctx, call)
        if fn is None:
            # unresolvable target: only literal argnames are checkable
            for p in donated_names:
                if WEIGHTS_RE.search(p):
                    yield self.finding(ctx, call, self._msg(p))
            return
        for i, p in enumerate(_param_names(fn)):
            if not WEIGHTS_RE.search(p):
                continue
            if i in donated_idxs or p in donated_names:
                yield self.finding(ctx, call, self._msg(p))

    @staticmethod
    def _msg(p: str) -> str:
        return (f"jax.jit donates weights-named parameter {p!r} — "
                f"packed quant weights are static operands reused "
                f"every step; donating them frees the buffers after "
                f"the first call (donate the caches, not the params)")

    @staticmethod
    def _resolve(ctx, call) -> Optional[ast.AST]:
        if not call.args:
            return None
        target = call.args[0]
        if isinstance(target, ast.Lambda):
            return target
        if isinstance(target, ast.Name):
            defs = [n for n in ast.walk(ctx.tree)
                    if isinstance(n, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))
                    and n.name == target.id]
            if defs:
                return defs[-1]
        return None


def _const_set(node: ast.AST, typ) -> set:
    if isinstance(node, ast.Constant) and isinstance(node.value, typ):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, typ)}
    return set()
