"""jit-donation: cache-carrying jits must donate their cache argument.

The engines treat caches as *linear state*: every jitted call rebinds
``self.caches`` to the returned pytree and never touches the donated
input again, so XLA reuses the cache buffers in place (PR 5's donation
contract — without it every macro-step copies the full KV cache).  A
``jax.jit`` whose wrapped function takes a cache/state-named parameter
and does not declare ``donate_argnums``/``donate_argnames`` covering
it silently doubles cache memory traffic; nothing else fails.

The check resolves the wrapped callable when it can see it: decorated
defs, ``jax.jit(fn)`` over a local def, ``jax.jit(lambda ...)``, and
``functools.partial(jax.jit, ...)`` decorators.  Cross-module targets
(``jax.jit(self.model.prefill_chunk)``) are *not* resolvable
statically — those stay covered by the dispatch/compile regressions in
tests/test_engine_macro.py.

Known exemption: profiling jits must NOT donate (they would consume
the live serving caches) — suppressed inline where deliberate.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, List, Optional, Tuple

from tools.reprolint import config
from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register

#: parameter names that hold engine cache / linear state
CACHE_PARAM_RE = re.compile(r"(^|_)(caches?|state|carry)(_|$|s$)")


def _param_names(fn: ast.AST) -> List[str]:
    args = fn.args
    return [a.arg for a in args.posonlyargs + args.args]


def _donated(call: ast.Call) -> Tuple[Optional[set], Optional[set]]:
    """(donated indices, donated names) declared on a jax.jit call —
    (None, None) when neither kwarg is present."""
    idxs = names = None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            idxs = _int_tuple(kw.value)
        elif kw.arg == "donate_argnames":
            names = _str_tuple(kw.value)
    return idxs, names


def _int_tuple(node: ast.AST) -> set:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, int)}
    return set()


def _str_tuple(node: ast.AST) -> set:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        return {e.value for e in node.elts
                if isinstance(e, ast.Constant)
                and isinstance(e.value, str)}
    return set()


@register
class JitDonation(Rule):
    name = "jit-donation"
    description = ("jax.jit over a function with a cache/state-named "
                   "parameter must donate it "
                   "(donate_argnums/donate_argnames)")
    motivation = ("PR 5's cache-donation contract: a non-donating "
                  "cache jit silently copies the whole KV cache every "
                  "macro-step")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_decorated(ctx, node)
            elif isinstance(node, ast.Call) \
                    and ctx.call_qualname(node) == "jax.jit":
                yield from self._check_wrap(ctx, node)

    # -- @functools.partial(jax.jit, ...) / @jax.jit -------------------
    def _check_decorated(self, ctx, fn) -> Iterator[Finding]:
        for dec in fn.decorator_list:
            if isinstance(dec, ast.Call) \
                    and ctx.call_qualname(dec) == "functools.partial" \
                    and dec.args \
                    and ctx.qualname(dec.args[0]) == "jax.jit":
                yield from self._verify(ctx, dec, fn, fn.name,
                                        *_donated(dec))
            elif ctx.qualname(dec) == "jax.jit":
                # bare decorator: nothing can be donated
                yield from self._verify(ctx, dec, fn, fn.name,
                                        None, None)

    # -- jax.jit(fn, ...) ----------------------------------------------
    def _check_wrap(self, ctx, call) -> Iterator[Finding]:
        if not call.args:
            return
        target = call.args[0]
        fn: Optional[ast.AST] = None
        label = "<callable>"
        if isinstance(target, ast.Lambda):
            fn, label = target, "<lambda>"
        elif isinstance(target, ast.Name):
            # nearest local def with that name (the engines build their
            # jits right next to the defs they wrap)
            defs = [n for n in ast.walk(ctx.tree)
                    if isinstance(n, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))
                    and n.name == target.id]
            if not defs:
                return  # imported/cross-module target: not resolvable
            fn, label = defs[-1], target.id
        else:
            return  # attribute chains etc.: not statically resolvable
        yield from self._verify(ctx, call, fn, label, *_donated(call))

    def _verify(self, ctx, call, fn, label, idxs, names) \
            -> Iterator[Finding]:
        if label in config.JIT_DONATION_EXEMPT:
            return
        cache_idx = [(i, p) for i, p in enumerate(_param_names(fn))
                     if CACHE_PARAM_RE.search(p)]
        for i, p in cache_idx:
            covered = ((idxs is not None and i in idxs)
                       or (names is not None and p in names))
            if not covered:
                yield self.finding(
                    ctx, call,
                    f"jax.jit({label}) does not donate cache-carrying "
                    f"parameter {p!r} (index {i}) — declare "
                    f"donate_argnums=({i},) and rebind the caller's "
                    f"reference, or suppress with the reason the state "
                    f"must survive (e.g. profiling)")
