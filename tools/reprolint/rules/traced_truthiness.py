"""traced-truthiness: no Python `if`/`while` on traced jnp values.

Inside a jit-compiled function (or a ``lax.scan`` body), a jnp
expression is a *tracer*: ``if jnp.any(mask):`` either raises a
ConcretizationTypeError at trace time or — worse, when the value is
accidentally concrete on the first call — silently bakes one branch
into the compiled program for every future call.  Data-dependent
control flow belongs in ``jnp.where`` / ``lax.cond`` / ``lax.select``
(the macro-step scan's done-masking in ``greedy_scan_update`` is the
canonical in-repo pattern).

To stay quiet on the legitimate *static* branching the kernels and
models do everywhere (``if not use_pallas:``, ``if paged is None:``,
``if cfg.n_layers > ...``), the rule only taints values that
demonstrably come from jnp/jax calls inside the traced function:

* a test expression containing a direct ``jnp.*`` / ``jax.*`` call;
* a name assigned (in the same function) from an expression
  containing one.

``is`` / ``is not`` comparisons and shape/dtype attribute tests are
never flagged.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register

_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}


def _contains_jax_call(ctx: FileContext, node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            q = ctx.call_qualname(sub)
            if q and (q.startswith("jax.numpy.")
                      or q.startswith("jax.lax.")
                      or q == "jax.numpy"):
                # shape/dtype metadata access keeps a test static even
                # when a jnp call produced the array
                parent_attr = any(
                    isinstance(a, ast.Attribute)
                    and a.attr in _STATIC_ATTRS
                    for a in ctx.ancestors(sub))
                if not parent_attr:
                    return True
    return False


def _tainted_names(ctx: FileContext, fn: ast.AST) -> Set[str]:
    """Names assigned from jnp/jax-call expressions within ``fn``."""
    names: Set[str] = set()
    for sub in ast.walk(fn):
        if isinstance(sub, ast.Assign) \
                and _contains_jax_call(ctx, sub.value):
            for t in sub.targets:
                for leaf in ast.walk(t):
                    if isinstance(leaf, ast.Name):
                        names.add(leaf.id)
        elif isinstance(sub, (ast.AugAssign, ast.AnnAssign)) \
                and sub.value is not None \
                and _contains_jax_call(ctx, sub.value) \
                and isinstance(sub.target, ast.Name):
            names.add(sub.target.id)
    return names


def _is_identity_test(node: ast.AST) -> bool:
    return isinstance(node, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops)


@register
class TracedTruthiness(Rule):
    name = "traced-truthiness"
    description = ("no Python if/while on jnp expressions inside "
                   "jit-traced code — use jnp.where/lax.cond/"
                   "lax.select")
    motivation = ("a truthy tracer raises at trace time or silently "
                  "bakes one branch into the compiled program (the "
                  "macro-step masks rows with jnp.where for exactly "
                  "this reason)")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            if not ctx.in_traced(node):
                continue
            test = node.test
            if _is_identity_test(test):
                continue
            fns = ctx.enclosing_functions(node)
            tainted = _tainted_names(ctx, fns[0]) if fns else set()
            direct = _contains_jax_call(ctx, test)
            via_name = any(isinstance(leaf, ast.Name)
                           and isinstance(leaf.ctx, ast.Load)
                           and leaf.id in tainted
                           for leaf in ast.walk(test))
            if direct or via_name:
                kw = "if" if isinstance(node, ast.If) else "while"
                yield self.finding(
                    ctx, node,
                    f"Python `{kw}` on a traced jnp value inside "
                    f"jit-compiled code — branches on tracers either "
                    f"raise or silently specialize; use jnp.where / "
                    f"lax.cond / lax.select")
