"""mutable-default: no shared-mutable default arguments or dataclass
fields.

A ``def f(out=[])`` default (or a ``x: List = []`` dataclass field)
is evaluated once and shared by every call/instance: request lists,
block tables, and hop dicts silently alias across engines — exactly
the co-batched-state-corruption genus the serving stack keeps having
to rule out (``Request.out_tokens`` uses
``field(default_factory=list)`` for this reason).  Dataclasses raise
for bare ``[]`` fields only on *some* annotations; the linter flags
them all uniformly.

Flagged: list/dict/set displays and ``list()``/``dict()``/``set()``
calls as function parameter defaults, and as dataclass field defaults
in ``@dataclass``-decorated classes.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register

_MUTABLE_DISPLAYS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                     ast.DictComp, ast.SetComp)
_MUTABLE_CTORS = {"list", "dict", "set", "bytearray", "defaultdict",
                  "Counter", "deque", "OrderedDict"}


def _is_mutable(node: ast.AST) -> bool:
    if isinstance(node, _MUTABLE_DISPLAYS):
        return True
    if isinstance(node, ast.Call):
        name = None
        if isinstance(node.func, ast.Name):
            name = node.func.id
        elif isinstance(node.func, ast.Attribute):
            name = node.func.attr
        return name in _MUTABLE_CTORS
    return False


def _is_dataclass(ctx: FileContext, cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        q = ctx.qualname(dec.func if isinstance(dec, ast.Call) else dec)
        if q and q.rsplit(".", 1)[-1] == "dataclass":
            return True
    return False


@register
class MutableDefault(Rule):
    name = "mutable-default"
    description = ("no mutable default arguments or mutable dataclass "
                   "field defaults — use None/field(default_factory)")
    motivation = ("a shared default list aliases state across every "
                  "call/instance — the same corruption genus as the "
                  "co-batched SSM-row bug, but at the Python layer")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                yield from self._check_defaults(ctx, node)
            elif isinstance(node, ast.ClassDef) \
                    and _is_dataclass(ctx, node):
                yield from self._check_fields(ctx, node)

    def _check_defaults(self, ctx, fn) -> Iterator[Finding]:
        defaults = list(fn.args.defaults) + [
            d for d in fn.args.kw_defaults if d is not None]
        for d in defaults:
            if _is_mutable(d):
                name = getattr(fn, "name", "<lambda>")
                yield self.finding(
                    ctx, d,
                    f"mutable default argument in {name}() is "
                    f"evaluated once and shared by every call — "
                    f"default to None (or a tuple) and construct "
                    f"inside")

    def _check_fields(self, ctx, cls) -> Iterator[Finding]:
        for stmt in cls.body:
            if isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                    and _is_mutable(stmt.value):
                yield self.finding(
                    ctx, stmt,
                    f"mutable dataclass field default in "
                    f"{cls.name} is shared across instances — use "
                    f"field(default_factory=...)")
            elif isinstance(stmt, ast.Assign) and _is_mutable(stmt.value):
                yield self.finding(
                    ctx, stmt,
                    f"mutable class-level default in {cls.name} is "
                    f"shared across instances — use "
                    f"field(default_factory=...)")
