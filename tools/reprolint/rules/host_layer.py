"""host-layer-jax: the scheduling/simulation layer must not import JAX.

``serving/scheduler.py`` (policy decisions), ``serving/testbed.py``
(FakeEngine), and ``core/simulator*.py`` (the evaluation loop) are the
repo's *host* layer: pure numpy state machines that must stay
importable — and unit-testable in milliseconds — on a box with no JAX,
and must never accidentally trigger device work from a scheduling
decision (policies choose WHICH rows run, never WHAT they compute).
The 22-test policy suite and the goodput baseline both depend on this:
FakeEngine exists precisely so every policy decision runs with "no
model, no parameters, and no JAX dispatch".

Any ``import jax`` / ``from jax import ...`` (top-level or nested
inside a function) in a configured host-layer file is a finding.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register


@register
class HostLayerJax(Rule):
    name = "host-layer-jax"
    description = ("the scheduler/testbed/simulator host layer must "
                   "not import jax (pure-numpy state machines, "
                   "JAX-free testable)")
    motivation = ("PR 6's testbed contract: FakeEngine runs the real "
                  "scheduler with zero JAX dispatch; a jax import "
                  "here couples policy decisions to device state")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        for node in ast.walk(ctx.tree):
            mod = None
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "jax" or a.name.startswith("jax."):
                        mod = a.name
            elif isinstance(node, ast.ImportFrom):
                if node.module and (node.module == "jax"
                                    or node.module.startswith("jax.")):
                    mod = node.module
            if mod is not None:
                yield self.finding(
                    ctx, node,
                    f"host-layer module imports {mod} — scheduler/"
                    f"testbed/simulator code is a pure-numpy state "
                    f"machine (move device work behind an engine "
                    f"hook)")
