"""ledger-privacy: PagedCache's underscore state is the ledger's own.

The paged-cache ledger (``models/kvcache.py::PagedCache``) maintains a
web of invariants over its private fields — ``_free`` LIFO lists,
``_held`` per-row block sets, ``_ref`` refcounts, ``_prefix_index`` /
``_block_key`` content addressing, the ``_version`` counter that keys
incremental device-table uploads.  Every public method
(``admit``/``ensure``/``release``/``check``/``meta``) preserves them
together; an engine or benchmark reaching into ``pc._free`` directly
can break refcount/occupancy consistency in ways only a long
preemption+sharing trace would surface (the PR 7 COW machinery is
exactly this kind of coupling).

Flagged: any read or write of an underscore-prefixed attribute on a
receiver that is PagedCache-shaped — a name bound from
``PagedCache(...)``, or the conventional ``pc`` / ``*.pc`` handle.
Exempt by path config: the ledger itself and its dedicated test
harnesses (tests/test_paged*.py, tests/test_prefix_sharing.py), which
assert on private state by design.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from tools.reprolint.context import FileContext
from tools.reprolint.framework import Finding, Rule, register


@register
class LedgerPrivacy(Rule):
    name = "ledger-privacy"
    description = ("PagedCache underscore-prefixed fields are private "
                   "to models/kvcache.py (and its tests) — use the "
                   "public ledger API")
    motivation = ("PR 7: refcount/COW consistency spans _free/_held/"
                  "_ref/_prefix_index together; partial outside "
                  "mutation breaks invariants only long traces catch")

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        cache_names = self._paged_cache_names(ctx)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Attribute):
                continue
            attr = node.attr
            if not attr.startswith("_") or attr.startswith("__"):
                continue
            if self._is_cache_receiver(node.value, cache_names):
                yield self.finding(
                    ctx, node,
                    f"access to private ledger field "
                    f"PagedCache.{attr} outside models/kvcache.py — "
                    f"go through the public API (admit/ensure/release/"
                    f"meta/check) so refcount and free-list "
                    f"invariants stay maintained together")

    @staticmethod
    def _paged_cache_names(ctx: FileContext) -> Set[str]:
        """Variables assigned (anywhere in the file) from a direct
        ``PagedCache(...)`` construction."""
        names: Set[str] = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                q = ctx.call_qualname(node.value)
                if q and q.rsplit(".", 1)[-1] == "PagedCache":
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            names.add(t.id)
        return names

    @staticmethod
    def _is_cache_receiver(value: ast.AST, cache_names: Set[str]) \
            -> bool:
        # pc._x / <tracked var>._x
        if isinstance(value, ast.Name):
            return value.id == "pc" or value.id in cache_names
        # self.pc._x / eng.pc._x / anything.pc._x
        if isinstance(value, ast.Attribute):
            return value.attr == "pc"
        return False
