"""Rule modules — importing this package registers every rule."""
from tools.reprolint.rules import (host_layer, host_sync,  # noqa: F401
                                   jit_donation, ledger_privacy,
                                   mutable_default, quant_static_weights,
                                   seeded_rng, step_clock,
                                   traced_truthiness)
