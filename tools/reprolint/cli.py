"""Command-line front end.

    python -m tools.reprolint [paths...]      # default: src benchmarks tests
    python -m tools.reprolint --json src      # machine-readable output
    python -m tools.reprolint --show-suppressed src
    python -m tools.reprolint --list-rules

Exit codes: 0 = clean (suppressed findings allowed), 1 = unsuppressed
findings (including bad/unused suppressions and parse errors),
2 = usage error.  The ``--json`` document is stable for dashboards:

    {"version": ..., "files": N, "clean": bool,
     "counts": {"<rule>": n, ...},
     "findings": [{"path", "line", "rule", "message",
                   "suppressed", "suppress_reason"}, ...]}
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from tools.reprolint import framework
from tools.reprolint.framework import Finding

DEFAULT_PATHS = ("src", "benchmarks", "tests")


def repo_root() -> str:
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _list_rules() -> str:
    lines = ["reprolint rules:"]
    for name, cls in sorted(framework.all_rules().items()):
        lines.append(f"  {name:<20} {cls.description}")
        if cls.motivation:
            lines.append(f"  {'':<20} why: {cls.motivation}")
    lines.append("meta:")
    for name, desc in sorted(framework.META_RULES.items()):
        lines.append(f"  {name:<20} {desc}")
    lines.append(
        "\nsuppress inline (reason required):\n"
        "  x = f()  # reprolint: disable=<rule>[,<rule>] -- <why>\n"
        "  # reprolint: disable-next=<rule> -- <why>")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.reprolint",
        description="AST-based invariant linter for the serving stack "
                    "(rule catalogue: --list-rules; docs: TOOLING.md)")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files/directories to lint "
                         "(default: src benchmarks tests)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable output on stdout")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="also print findings silenced by inline "
                         "suppressions")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    ap.add_argument("--root", default=None,
                    help="repo root (default: the checkout containing "
                         "this tool)")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    root = os.path.abspath(args.root) if args.root else repo_root()
    for p in args.paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if not os.path.exists(full):
            print(f"reprolint: no such path: {p}", file=sys.stderr)
            return 2

    findings = framework.lint_paths(args.paths, root)
    nfiles = len(framework.target_files(args.paths, root))
    unsuppressed = [f for f in findings if not f.suppressed]
    suppressed = [f for f in findings if f.suppressed]

    if args.as_json:
        counts: dict = {}
        for f in unsuppressed:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        print(json.dumps({
            "version": 1,
            "files": nfiles,
            "clean": not unsuppressed,
            "counts": counts,
            "findings": [f.to_json() for f in findings],
        }, indent=2, sort_keys=True))
        return 1 if unsuppressed else 0

    shown = findings if args.show_suppressed else unsuppressed
    for f in sorted(shown, key=lambda f: (f.path, f.line, f.rule)):
        print(f.render())
    status = "OK" if not unsuppressed else "FAIL"
    extra = f", {len(suppressed)} suppressed" if suppressed else ""
    print(f"reprolint: {nfiles} files, {len(unsuppressed)} "
          f"finding(s){extra}: {status}")
    return 1 if unsuppressed else 0
