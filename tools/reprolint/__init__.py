"""reprolint: AST-based invariant linter for the serving stack.

The repo's load-bearing invariants — jit cache donation, the <= 1/K
host-sync bound, SeedSequence-only randomness, the engine-step clock,
the JAX-free scheduler/testbed layer, PagedCache ledger privacy —
were enforced by convention and after-the-fact parity tests; every one
of them has burned a review cycle (the PYTHONHASHSEED crc32 fix in
PR 1, the donation-contract retrofit in PR 5, the masked-row host-sync
subtlety).  reprolint machine-checks them at commit time:

    python -m tools.reprolint src benchmarks tests
    python -m tools.reprolint --json src      # machine-readable
    python -m tools.reprolint --list-rules    # rule catalogue

Each rule is a small module under ``tools/reprolint/rules/`` registered
with the framework (``framework.register``); which rules run on which
paths is declared in ``config.py``.  Deliberate violations are
suppressed inline — a "why" is required, reasonless suppressions fail
the run:

    x = np.asarray(toks)  # reprolint: disable=host-sync -- the one
                          # deliberate sync per macro-step

TOOLING.md documents every rule, the invariant it encodes, and the PR
that motivated it.  ``make lint`` wires the linter into ``make ci``.
"""
from tools.reprolint.framework import (Finding, Rule, all_rules,  # noqa: F401
                                       lint_file, lint_paths, register)

__version__ = "1.0"
