"""Rule registry + per-file runner.

A rule is a subclass of :class:`Rule` registered with
:func:`register`; its :meth:`Rule.check` yields raw findings over one
:class:`~tools.reprolint.context.FileContext`.  The runner applies the
per-path rule sets from ``config.py``, matches findings against inline
suppressions (``# reprolint: disable=<rule> -- <why>``), and emits the
framework's own meta-findings:

* ``bad-suppression`` — a directive with no ``-- <why>`` reason, or
  naming a rule that does not exist (typo-proofing);
* ``unused-suppression`` — a directive that suppressed nothing (the
  violation it excused is gone: delete the directive);
* ``parse-error`` — a file that does not parse (CI fails loudly
  instead of silently skipping it).

Suppressed findings are kept (with their reason) so ``--json`` can
report them; only *unsuppressed* findings affect the exit code.
"""
from __future__ import annotations

import ast
import os
from dataclasses import asdict, dataclass
from typing import Dict, Iterable, Iterator, List, Optional, Type

from tools.reprolint import config
from tools.reprolint.context import FileContext

META_RULES = {
    "bad-suppression": "suppression directives need a '-- <why>' reason "
                       "and must name real rules",
    "unused-suppression": "a directive that suppresses nothing must be "
                          "deleted",
    "parse-error": "every linted file must parse",
}


@dataclass
class Finding:
    path: str          # repo-root-relative, posix
    line: int
    rule: str
    message: str
    suppressed: bool = False
    suppress_reason: Optional[str] = None

    def render(self) -> str:
        tag = "  [suppressed: {}]".format(self.suppress_reason) \
            if self.suppressed else ""
        return f"{self.path}:{self.line}: [{self.rule}] " \
               f"{self.message}{tag}"

    def to_json(self) -> dict:
        return asdict(self)


class Rule:
    """One invariant check.  Subclasses set ``name``/``description``
    (and optionally ``motivation`` — the PR/bug that earned the rule a
    place here) and implement :meth:`check`."""

    name: str = ""
    description: str = ""
    motivation: str = ""

    def check(self, ctx: FileContext) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST,
                message: str) -> Finding:
        return Finding(path=ctx.relpath, line=node.lineno,
                       rule=self.name, message=message)


_REGISTRY: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    assert cls.name and cls.name not in _REGISTRY, cls
    _REGISTRY[cls.name] = cls
    return cls


def all_rules() -> Dict[str, Type[Rule]]:
    # import for side effect: rule modules self-register
    from tools.reprolint import rules  # noqa: F401
    return dict(_REGISTRY)


def known_rule_names() -> set:
    return set(all_rules()) | set(META_RULES)


# ----------------------------------------------------------------------
# runner
# ----------------------------------------------------------------------
def lint_file(path: str, root: str) -> List[Finding]:
    """Lint one file: run its per-path rule set, apply suppressions,
    emit meta-findings."""
    rel = os.path.relpath(path, root).replace(os.sep, "/")
    try:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        ctx = FileContext(path, rel, source)
    except (SyntaxError, ValueError, UnicodeDecodeError) as e:
        line = getattr(e, "lineno", None) or 1
        return [Finding(path=rel, line=line, rule="parse-error",
                        message=f"cannot parse: {e}")]

    rules = all_rules()
    findings: List[Finding] = []
    for name in sorted(config.rules_for(rel)):
        for f in rules[name]().check(ctx):
            sup = ctx.suppression_for(f.rule, f.line)
            if sup is not None:
                sup.used = True
                f.suppressed = True
                f.suppress_reason = sup.reason or "(no reason given)"
            findings.append(f)

    known = known_rule_names()
    for sup in ctx.suppressions:
        unknown = [r for r in sup.rules if r != "all" and r not in known]
        if unknown:
            findings.append(Finding(
                path=rel, line=sup.line, rule="bad-suppression",
                message=f"unknown rule(s) {', '.join(unknown)} in "
                        f"suppression (known: "
                        f"{', '.join(sorted(known))})"))
        if not sup.reason:
            findings.append(Finding(
                path=rel, line=sup.line, rule="bad-suppression",
                message="suppression without a reason — append "
                        "'-- <why this violation is deliberate>'"))
        elif not sup.used and not unknown:
            findings.append(Finding(
                path=rel, line=sup.line, rule="unused-suppression",
                message=f"suppression for "
                        f"{', '.join(sup.rules)} matches no finding — "
                        f"delete it"))
    findings.sort(key=lambda f: (f.line, f.rule))
    return findings


def iter_py_files(paths: Iterable[str], root: str) -> Iterator[str]:
    for p in paths:
        p = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d not in config.EXCLUDE_DIRS
                                 and not d.startswith("."))
            for f in sorted(filenames):
                if f.endswith(".py"):
                    yield os.path.join(dirpath, f)


def target_files(paths: Iterable[str], root: str) -> List[str]:
    """The non-excluded .py files a run will lint."""
    out = []
    for path in iter_py_files(paths, root):
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        if not config.excluded(rel):
            out.append(path)
    return out


def lint_paths(paths: Iterable[str], root: str) -> List[Finding]:
    findings: List[Finding] = []
    for path in target_files(paths, root):
        findings.extend(lint_file(path, root))
    return findings
