"""Docs link-checker: every relative markdown link/reference resolves.

Scans all *.md files in the repo (skipping hidden dirs) for inline
links `[text](target)`, checks that non-URL targets exist relative to
the containing file, and verifies the backtick-quoted file paths the
docs lean on (``src/...``, ``tests/...``, ``benchmarks/...``,
``examples/...``, ``tools/...``) point at real files.  Exits non-zero
listing every broken reference.

  python tools/check_docs.py [root]
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s#]+)(?:#[^)]*)?\)")
PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|tools)/[\w./-]+\.\w+)`")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if not d.startswith(".") and d != "__pycache__"]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check(root: str):
    errors = []
    for path in sorted(md_files(root)):
        rel = os.path.relpath(path, root)
        text = open(path, encoding="utf-8").read()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                errors.append(f"{rel}: broken link -> {target}")
        for m in PATH_RE.finditer(text):
            if not os.path.exists(os.path.join(root, m.group(1))):
                errors.append(f"{rel}: missing path -> {m.group(1)}")
    return errors


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    errors = check(root)
    for e in errors:
        print(e)
    n = sum(1 for _ in md_files(root))
    print(f"checked {n} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken'}")
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
