"""Docs checker: links resolve AND quoted file references exist.

Two layers of rot protection, both part of ``make ci`` (``make docs``):

1. **Links, all markdown** — every inline ``[text](target)`` in every
   *.md file (hidden dirs skipped) must resolve relative to the
   containing file, and every backtick-quoted top-level path
   (``src/...``, ``tests/...``, ``benchmarks/...``, ``examples/...``,
   ``tools/...``) must exist.
2. **File references, curated docs** — in the living documentation set
   (README / ARCHITECTURE / EXPERIMENTS / SERVING / TOOLING), *any*
   backtick
   reference that looks like a source path — ``core/simulator.py``,
   ``repro/experiments/scenarios.py``, ``serving/engine.py::step`` —
   must point at a real file, tried relative to the repo root,
   ``src/`` and ``src/repro/`` (module-style shorthand is how these
   docs cite code).  A renamed or deleted module fails CI instead of
   silently rotting the guide.

Exits non-zero listing every broken reference.

  python tools/check_docs.py [root]
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s#]+)(?:#[^)]*)?\)")
PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|tools)/[\w./-]+\.\w+)`")
# any backtick path-with-a-slash ending in a source/doc extension,
# optionally carrying a ::member suffix
REL_PATH_RE = re.compile(
    r"`([\w][\w./-]*/[\w.-]+\.(?:py|md|json|txt|toml|cfg))(?:::[\w.]+)?`")
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")
# the curated documentation set held to the stricter file-reference bar
CURATED = ("README.md", "ARCHITECTURE.md", "EXPERIMENTS.md", "SERVING.md",
           "TOOLING.md")
REL_ROOTS = ("", "src", os.path.join("src", "repro"))


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if not d.startswith(".") and d != "__pycache__"]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def resolve_rel(root: str, target: str) -> bool:
    return any(os.path.exists(os.path.join(root, base, target))
               for base in REL_ROOTS)


def check(root: str):
    errors = []
    for path in sorted(md_files(root)):
        rel = os.path.relpath(path, root)
        text = open(path, encoding="utf-8").read()
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(SKIP_SCHEMES):
                continue
            resolved = os.path.normpath(
                os.path.join(os.path.dirname(path), target))
            if not os.path.exists(resolved):
                errors.append(f"{rel}: broken link -> {target}")
        for m in PATH_RE.finditer(text):
            if not os.path.exists(os.path.join(root, m.group(1))):
                errors.append(f"{rel}: missing path -> {m.group(1)}")
        if os.path.basename(path) in CURATED:
            for m in REL_PATH_RE.finditer(text):
                if not resolve_rel(root, m.group(1)):
                    errors.append(
                        f"{rel}: missing file reference -> {m.group(1)}")
    return errors


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))
    errors = sorted(set(check(root)))
    for e in errors:
        print(e)
    n = sum(1 for _ in md_files(root))
    print(f"checked {n} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken'}")
    sys.exit(1 if errors else 0)


if __name__ == "__main__":
    main()
