#!/usr/bin/env python3
"""Typecheck gate over the curated host-layer modules.

The serving stack's host layer (scheduler, testbed, simulator core,
cache ledger) is plain typed Python — no jax pytrees, no traced
values — so it is exactly the code a standard typechecker can hold to
its annotations.  This gate runs the strongest checker available:

1. ``pyright`` (config: pyrightconfig.json, basic mode), else
2. ``mypy``   (config: mypy.ini, basic mode), else
3. a syntax-only fallback (``compile()`` every curated file) so the
   gate *degrades* in minimal environments instead of silently
   passing — it prints exactly which checker ran.

The curated list below is the expansion frontier, documented in
TOOLING.md: modules are added as their annotations are tightened,
never removed.  Keep it in sync with pyrightconfig.json / mypy.ini.

Exit codes: 0 clean (or fallback succeeded), 1 type/syntax errors,
2 usage or configuration error.
"""
import os
import shutil
import subprocess
import sys

# Expansion frontier: host-layer modules whose annotations are
# complete enough to enforce.  Mirrors pyrightconfig.json include=
# and the mypy invocation below.
CURATED = [
    "src/repro/core",
    "src/repro/serving/scheduler.py",
    "src/repro/serving/testbed.py",
    "src/repro/models/kvcache.py",
]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def curated_files():
    files = []
    for rel in CURATED:
        path = os.path.join(ROOT, rel)
        if os.path.isdir(path):
            for dirpath, dirnames, filenames in os.walk(path):
                dirnames[:] = sorted(d for d in dirnames
                                     if d != "__pycache__")
                files.extend(os.path.join(dirpath, f)
                             for f in sorted(filenames)
                             if f.endswith(".py"))
        elif os.path.isfile(path):
            files.append(path)
        else:
            print(f"typecheck: curated path missing: {rel}",
                  file=sys.stderr)
            sys.exit(2)
    return files


def try_pyright():
    exe = shutil.which("pyright")
    if exe is None:
        return None
    proc = subprocess.run([exe, "--project", ROOT], cwd=ROOT)
    print(f"typecheck: pyright over {len(CURATED)} curated targets")
    return proc.returncode


def try_mypy():
    try:
        import mypy  # noqa: F401
    except ImportError:
        return None
    proc = subprocess.run(
        [sys.executable, "-m", "mypy", "--config-file", "mypy.ini",
         *CURATED],
        cwd=ROOT)
    print(f"typecheck: mypy over {len(CURATED)} curated targets")
    return proc.returncode


def syntax_fallback():
    files = curated_files()
    failed = 0
    for path in files:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        try:
            compile(source, path, "exec")
        except SyntaxError as e:
            rel = os.path.relpath(path, ROOT)
            print(f"{rel}:{e.lineno}: syntax error: {e.msg}",
                  file=sys.stderr)
            failed += 1
    if failed:
        print(f"typecheck: {failed} file(s) failed the syntax check",
              file=sys.stderr)
        return 1
    print(f"typecheck: no pyright/mypy in this environment — "
          f"syntax-checked {len(files)} curated files instead "
          f"(install either to enforce annotations)")
    return 0


def main() -> int:
    for runner in (try_pyright, try_mypy):
        rc = runner()
        if rc is not None:
            return 1 if rc else 0
    return syntax_fallback()


if __name__ == "__main__":
    sys.exit(main())
