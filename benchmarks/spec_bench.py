"""Draft-verify speculative decoding bench: tokens/s vs the macro-step
baseline, acceptance rate, verify dispatches and host syncs per token.

Measures what speculation buys over the fused macro-step it replaces
(SERVING.md §Speculative decoding): the baseline K=16 paged engine
pays one sequential model step per token (amortizing only *dispatch*
overhead across the scan), while a verify round scores all K+1
positions of a draft chunk in one parallel dispatch and emits the
accepted prefix — per emitted token the model runs ~1/accept_mean
chunk passes instead of one full step.  The win is therefore gated on
the acceptance rate, which is a property of the *trace*: this bench
replays a deliberately high-acceptance workload (greedy smoke streams
collapse into short cycles after a wandering head, which the n-gram
draft then predicts near-perfectly), so the committed numbers show the
mechanism's headroom, not a fleet average.  Columns:

* ``tok_per_s``           wall-clock generated tokens per second,
* ``acceptance_rate``     accepted draft tokens / proposed draft tokens,
* ``accept_mean``         tokens emitted per live row per verify round
                          (accepted + 1 bonus; what EC admission sees),
* ``verify_per_token``    verify-chunk jit dispatches / generated token
                          (the speculative analogue of disp/tok —
                          between 1/(K+1) and 1),
* ``syncs_per_token``     device->host materializations / token (one
                          per verify round: the <= 1/K-style bound),
* ``outputs_match``       greedy token streams byte-identical to the
                          non-speculative baseline cell — speculation
                          must never trade exactness for speed.

Wall-clock tok/s is host-dependent (engine_bench caveats apply); the
acceptance/dispatch/sync columns and the outputs are deterministic
given ``--seed``.  The acceptance gate in the committed baseline:
spec K=8 must clear ``MIN_SPEEDUP``x the paged K=16 macro-step cell
with ``outputs_match`` true (tests do not assert the wall-clock part;
the committed JSON documents it).

  PYTHONPATH=src python -m benchmarks.spec_bench --quick
  PYTHONPATH=src python -m benchmarks.spec_bench --out bench_spec.json
"""
from __future__ import annotations

import argparse
import time

from repro.configs import get_smoke_config
from repro.experiments.results import save_results
from repro.serving import PagedServingEngine, Request
from repro.serving.instrument import instrument

#: committed-baseline criterion: best speculative cell over the paged
#: K=16 macro-step baseline on the high-acceptance trace
MIN_SPEEDUP = 1.3
DEFAULT_SPEC_KS = "4,8,16"


def build_trace(n_requests: int, new_tokens: int, seed: int = 0,
                vocab: int = 512) -> list:
    """(submit_step, prompt, max_new) trace tuned for high acceptance:
    short cyclic prompts (the n-gram table is seeded immediately) and
    long generations (the stream's constant tail dominates the
    unpredictable head).  Deterministic in ``seed`` via a tiny LCG —
    the point is distinct per-request prompts, not realism."""
    reqs, s = [], seed * 9973 + 12345
    for i in range(n_requests):
        s = (1103515245 * s + 12345) % (1 << 31)
        base = [3 + (s + 7 * i) % (vocab // 4),
                50 + (s // 7 + 11 * i) % (vocab // 4),
                200 + (s // 11 + 13 * i) % (vocab // 4)]
        reqs.append((4 * i, (base * 4)[:9], new_tokens))
    return reqs


def make_engine(cfg, *, speculative, decode_steps, max_rows, max_len,
                block_size, num_blocks, prefill_chunk):
    return PagedServingEngine(cfg, seed=0, speculative=speculative,
                              max_rows=max_rows, max_len=max_len,
                              block_size=block_size, num_blocks=num_blocks,
                              prefill_chunk=prefill_chunk,
                              decode_steps=decode_steps)


def warmup(eng, k: int, prefill_chunk: int):
    """Compile outside the timed phase.  One long-enough request covers
    every prefill tail shape and — speculative engines — the single
    fixed-width verify{K+1} program; macro-step baselines additionally
    need the pow2 scan ladder (engine_bench.warmup rationale)."""
    p_len = 2 * prefill_chunk
    lengths, n = [], 1
    while n < k:
        lengths.append(n)
        n *= 2
    lengths.append(max(k, 17))  # long tail: spec reaches steady rounds
    for n in lengths:
        eng.submit(Request(id=-1000 - n, prompt=list(range(1, p_len + 1)),
                           max_new_tokens=n))
        eng.run()
    eng.max_macro_tokens = 0


def drive(eng, trace, k: int, prefill_chunk: int, reps: int = 3) -> dict:
    """Replay ``trace`` ``reps`` times on one warmed-up engine, fastest
    pass wins the wall-clock columns (engine_bench.drive rationale);
    acceptance/dispatch/sync columns are per-pass deltas and identical
    across passes, as are the outputs (asserted)."""
    warmup(eng, k, prefill_chunk)
    counts = instrument(eng)
    spec_on = eng.spec is not None
    best = None
    outputs = None
    for _ in range(max(1, reps)):
        sync0, tok0 = eng.n_host_syncs, eng.tokens_generated
        d0, a0, e0 = eng.spec_drafted, eng.spec_accepted, eng.spec_emitted
        rr0, rounds0 = eng._spec_row_rounds, eng.spec_rounds
        ver0, dec0 = counts.verify_dispatches, counts.decode_dispatches

        t0_step = eng.t
        pending = [(t + t0_step, Request(id=i, prompt=list(p),
                                         max_new_tokens=n))
                   for i, (t, p, n) in enumerate(trace)]
        done = []
        t0 = time.perf_counter()
        while pending or eng.queue or not eng._idle():
            while pending and pending[0][0] <= eng.t:
                eng.submit(pending.pop(0)[1])
            done += eng.step()
        wall = time.perf_counter() - t0

        done = [r for r in done if r.id >= 0]
        outs = {r.id: list(r.out_tokens) for r in done}
        if outputs is None:
            outputs = outs
        elif outs != outputs:
            raise RuntimeError("outputs drifted across bench passes")
        toks = eng.tokens_generated - tok0
        syncs = eng.n_host_syncs - sync0
        drafted = eng.spec_drafted - d0
        row_rounds = eng._spec_row_rounds - rr0
        row = {
            "completed": len(done),
            "tokens": toks,
            "wall_s": wall,
            "tok_per_s": toks / wall,
            "spec_rounds": eng.spec_rounds - rounds0,
            "acceptance_rate": ((eng.spec_accepted - a0) / drafted
                                if drafted else 0.0),
            "accept_mean": ((eng.spec_emitted - e0) / row_rounds
                            if row_rounds else 1.0),
            "verify_dispatches": counts.verify_dispatches - ver0,
            "verify_per_token": ((counts.verify_dispatches - ver0)
                                 / max(toks, 1)),
            "decode_dispatches": counts.decode_dispatches - dec0,
            "host_syncs": syncs,
            "syncs_per_token": syncs / max(toks, 1),
        }
        if spec_on:
            # the <= 1/K-style contract, checked live: one host sync
            # per verify round, never per token
            assert row["host_syncs"] == row["spec_rounds"], \
                "speculative sync accounting drifted"
        if best is None or row["tok_per_s"] > best["tok_per_s"]:
            best = row
    best["outputs"] = outputs
    return best


def main(configs: str = "smollm-360m", n_requests: int = 6,
         new_tokens: int = 176, baseline_k: int = 16,
         spec_ks: str = DEFAULT_SPEC_KS, max_rows: int = 2,
         max_len: int = 256, block_size: int = 16, num_blocks: int = 32,
         prefill_chunk: int = 8, reps: int = 3, seed: int = 0,
         draft: str = "ngram", out: str | None = None):
    k_list = [int(s) for s in str(spec_ks).split(",")]
    geom = dict(max_rows=max_rows, max_len=max_len, block_size=block_size,
                num_blocks=num_blocks, prefill_chunk=prefill_chunk)
    rows = []
    for arch in str(configs).split(","):
        cfg = get_smoke_config(arch)
        trace = build_trace(n_requests, new_tokens, seed,
                            vocab=cfg.vocab_size)
        print(f"\n== {arch} {n_requests} reqs x {new_tokens} new tokens, "
              f"baseline paged K={baseline_k}, spec K in {k_list} "
              f"({draft} draft) ==")
        print(f"{'cell':>14s} {'K':>3s} {'tok/s':>8s} {'accept':>7s} "
              f"{'acc_mean':>8s} {'verify/tok':>10s} {'sync/tok':>9s} "
              f"{'match':>6s}")

        def cell(name, k, r, ref=None):
            r = dict(r)
            outputs = r.pop("outputs")
            r["k"] = k
            r["outputs_match"] = ref is None or outputs == ref
            print(f"{name:>14s} {k:3d} {r['tok_per_s']:8.1f} "
                  f"{r['acceptance_rate']:7.3f} {r['accept_mean']:8.2f} "
                  f"{r['verify_per_token']:10.4f} "
                  f"{r['syncs_per_token']:9.4f} "
                  f"{str(r['outputs_match']):>6s}")
            rows.append({"arch": arch, "cell": name, **r})
            return outputs, r

        base = drive(make_engine(cfg, speculative=None,
                                 decode_steps=baseline_k, **geom),
                     trace, baseline_k, prefill_chunk, reps=reps)
        ref, base_row = cell("baseline", baseline_k, base)
        best = None
        for k in k_list:
            spec = k if draft == "ngram" else {"k": k, "draft": "model",
                                               "draft_cfg": "smollm-360m"}
            _, r = cell(f"spec-{draft}", k,
                        drive(make_engine(cfg, speculative=spec,
                                          decode_steps=1, **geom),
                              trace, k, prefill_chunk, reps=reps),
                        ref=ref)
            if r["outputs_match"] and (best is None
                                       or r["tok_per_s"]
                                       > best["tok_per_s"]):
                best = r
        if best is not None:
            gain = best["tok_per_s"] / base_row["tok_per_s"]
            print(f"best spec K={best['k']} vs paged K={baseline_k}: "
                  f"{gain:.2f}x tokens/s (criterion >= {MIN_SPEEDUP}x), "
                  f"acceptance {best['acceptance_rate']:.3f}, "
                  f"syncs/token {best['syncs_per_token']:.4f}")
            rows.append({"arch": arch, "cell": "summary",
                         "k": best["k"], "speedup_vs_baseline": gain,
                         "min_speedup": MIN_SPEEDUP,
                         "meets_criterion": gain >= MIN_SPEEDUP,
                         "outputs_match": best["outputs_match"]})
    if out:
        save_results(out, rows, meta={
            "section": "spec_bench", "configs": configs,
            "n_requests": n_requests, "new_tokens": new_tokens,
            "baseline_k": baseline_k, "spec_ks": spec_ks, "draft": draft,
            "seed": seed, "reps": reps, **geom,
            "note": "wall_s/tok_per_s are host-dependent; acceptance/"
                    "dispatch/sync columns and outputs are deterministic "
                    "given the seed; the trace is tuned for high n-gram "
                    "acceptance (mechanism headroom, not fleet average)"})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=176,
                    help="generation length per request (longer = more "
                         "of the stream is its predictable tail)")
    ap.add_argument("--baseline-k", type=int, default=16,
                    help="macro-step size of the non-speculative "
                         "baseline cell")
    ap.add_argument("--spec-ks", default=DEFAULT_SPEC_KS,
                    help="comma list of draft lengths K")
    ap.add_argument("--draft", default="ngram", choices=["ngram", "model"],
                    help="draft provider for the speculative cells")
    ap.add_argument("--rows", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=32)
    ap.add_argument("--reps", type=int, default=3,
                    help="timed passes per cell; fastest wins")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: fewer/shorter requests, K in {4,8}")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quick:
        args.requests = 3
        args.new_tokens = 48
        args.spec_ks = "4,8"
        args.reps = 2
    main(configs=args.configs, n_requests=args.requests,
         new_tokens=args.new_tokens, baseline_k=args.baseline_k,
         spec_ks=args.spec_ks, max_rows=args.rows, max_len=args.max_len,
         block_size=args.block_size, num_blocks=args.num_blocks,
         reps=args.reps, seed=args.seed, draft=args.draft, out=args.out)
