"""Ablation: the diversity constraint C6 (kappa) under node failure.

The paper motivates C4-C6 by single-point vulnerability: solvers
"consolidate all instances of an MS onto a single node".  The
`failure_churn` scenario rolls a staggered outage window over every
edge server, so any concentrated backbone is guaranteed to be hit;
sweeping kappa shows completion surviving (at extra cost) as instances
spread.  The (kappa x scenario x seed) grid runs through the parallel
replication runner.

  PYTHONPATH=src python -m benchmarks.ablation_kappa
"""
from __future__ import annotations

import argparse

from repro.experiments.results import save_results, summarize_rows
from repro.experiments.runner import make_grid, run_grid

KAPPAS = (0, 6, 12)
SCENARIOS = ("baseline", "failure_churn")


def main(trials: int = 3, horizon: int = 60, out: str | None = None,
         n_workers: int | None = None):
    specs = make_grid(seeds=range(trials), strategies=("proposal",),
                      scenarios=SCENARIOS, horizon_slots=horizon,
                      kappas=KAPPAS)
    rows = run_grid(specs, n_workers=n_workers)
    print("kappa,scenario,on_time_mean,completed_mean,cost_mean")
    for s in summarize_rows(rows, keys=("kappa", "scenario")):
        print(f"{s['kappa']},{s['scenario']},{s['on_time_mean']:.4f},"
              f"{s['completed_mean']:.4f},{s['cost_mean']:.1f}",
              flush=True)
    if out:
        save_results(out, rows, meta={"section": "ablation_kappa",
                                      "kappas": KAPPAS,
                                      "scenarios": SCENARIOS,
                                      "n_trials": trials,
                                      "horizon_slots": horizon})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    ap.add_argument("--horizon", type=int, default=60)
    ap.add_argument("--out", default=None)
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args()
    main(args.trials, args.horizon, args.out, n_workers=args.workers)
