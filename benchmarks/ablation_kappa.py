"""Ablation: the diversity constraint C6 (kappa) under node failure.

The paper motivates C4–C6 by single-point vulnerability: solvers
"consolidate all instances of an MS onto a single node".  We inject an
edge-server failure mid-run and sweep kappa: with kappa=0 the static
backbone concentrates and the failure takes out whole core-MS types;
higher kappa spreads instances and completion survives, at extra cost.

  PYTHONPATH=src python -m benchmarks.ablation_kappa
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import paper_params as pp
from repro.core.graph import make_application
from repro.core.network import make_network
from repro.core.online_controller import ProposalStrategy
from repro.core.simulator import Simulator


def run(kappa: int, seed: int, fail: bool, horizon: int = 60):
    rng = np.random.default_rng(seed)
    app = make_application(rng)
    net = make_network(rng)
    # fail the busiest ES halfway through
    fail_node = pp.N_EDS if fail else None  # first edge server
    sim = Simulator(app, net, ProposalStrategy(kappa=kappa),
                    rng=np.random.default_rng(seed + 77),
                    horizon_slots=horizon,
                    fail_node=fail_node,
                    fail_at=horizon // 2 if fail else None)
    return sim.run()


def main(trials: int = 3):
    print("kappa,failure,on_time_mean,completed_mean,cost_mean")
    for kappa in (0, 6, 12):
        for fail in (False, True):
            ms = [run(kappa, s, fail) for s in range(trials)]
            ot = np.mean([m["on_time"] for m in ms])
            comp = np.mean([m["completed"] for m in ms])
            cost = np.mean([m["total_cost"] for m in ms])
            print(f"{kappa},{fail},{ot:.4f},{comp:.4f},{cost:.1f}",
                  flush=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=3)
    args = ap.parse_args()
    main(args.trials)
