"""Benchmark orchestrator — one section per paper table/figure.

  fig3   four-strategy violin distributions  (Sec. IV, Fig. 3)
  fig4   load scaling proposal vs PropAvg    (Sec. IV, Fig. 4)
  kernels  Pallas hot-spot microbenches      (name,us_per_call,derived)

Roofline (EXPERIMENTS.md §Roofline) is a separate entry point because it
needs the 512-device XLA flag *before* jax init:
  PYTHONPATH=src python -m benchmarks.roofline

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer trials (CI-sized)")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig3", "fig4", "kernels"])
    args = ap.parse_args()
    trials3 = 4 if args.quick else 8
    trials4 = 2 if args.quick else 4
    horizon = 50 if args.quick else 70

    if args.only in (None, "fig3"):
        print("=" * 72)
        print("## Fig. 3 — strategy distributions "
              "(on-time completion, total cost)")
        from benchmarks.fig3_strategies import main as fig3
        fig3(n_trials=trials3, horizon=horizon, out="bench_fig3.json")

    if args.only in (None, "fig4"):
        print("=" * 72)
        print("## Fig. 4 — escalating load (1.0x / 1.5x / 2.0x)")
        from benchmarks.fig4_load_scaling import main as fig4
        fig4(n_trials=trials4, horizon=horizon, out="bench_fig4.json")

    if args.only in (None, "kernels"):
        print("=" * 72)
        print("## Kernel microbenches")
        from benchmarks.kernels_bench import main as kb
        kb()

    print("=" * 72)
    print("done. roofline: PYTHONPATH=src python -m benchmarks.roofline")


if __name__ == "__main__":
    main()
