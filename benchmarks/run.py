"""Benchmark orchestrator — one section per paper table/figure.

  fig3     four-strategy violin distributions  (Sec. IV, Fig. 3)
  fig4     load scaling proposal vs PropAvg    (Sec. IV, Fig. 4)
  ablation kappa-diversity under failure churn (Sec. IV, C6)
  kernels  Pallas hot-spot microbenches        (name,us_per_call,derived)
  pipeline pipelined executor: tokens/s + per-hop transfer vs placement
  paged    paged KV + continuous batching vs dense slots (SERVING.md)
  engine   decode hot loop: macro-step K sweep, dispatches/syncs per
           token, all four engines (SERVING.md §The decode hot loop)
  spec     draft-verify speculative decoding vs the paged macro-step
           baseline: tokens/s, acceptance rate, verify dispatches
           (SERVING.md §Speculative decoding)
  goodput  SLO-goodput: FIFO vs EDF vs EDF+effective-capacity on a
           mixed-QoS overload trace (SERVING.md §Scheduling)
  quant    weight-only int8/int4 vs bf16 on the paged K=16 decode
           loop: tokens/s, MFU/MBU, golden gates
           (SERVING.md §Quantization)
  simbench vectorized simulator core vs scalar reference (trials/s)
  scale    scale_load population sweep via experiments.report

Simulation sections fan trials out across processes through the
replication runner (EXPERIMENTS.md §Harness) and write versioned JSON;
`--scenario` selects any registered workload/environment dynamics
(EXPERIMENTS.md §Scenario registry; `--list-scenarios` enumerates).

Roofline (EXPERIMENTS.md §Roofline) is a separate entry point because it
needs the 512-device XLA flag *before* jax init:
  PYTHONPATH=src python -m benchmarks.roofline

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
           [--scenario NAME] [--only SECTION] [--workers N]
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer trials (CI-sized)")
    ap.add_argument("--only", default=None,
                    choices=[None, "fig3", "fig4", "ablation", "kernels",
                             "pipeline", "paged", "engine", "spec",
                             "goodput", "quant", "simbench", "scale"])
    ap.add_argument("--scenario", default="baseline",
                    help="registered scenario for fig3/fig4 "
                         "(see --list-scenarios)")
    ap.add_argument("--workers", type=int, default=None,
                    help="worker processes (default: cpu count)")
    ap.add_argument("--list-scenarios", action="store_true")
    args = ap.parse_args()

    if args.list_scenarios:
        from repro.experiments.scenarios import list_scenarios
        for name, desc in list_scenarios().items():
            print(f"{name:16s} {desc}")
        return

    from repro.experiments.scenarios import get_scenario, list_scenarios
    try:
        get_scenario(args.scenario)   # fail fast on unknown names
    except KeyError:
        ap.error(f"unknown scenario {args.scenario!r} "
                 f"(registered: {', '.join(list_scenarios())})")

    trials3 = 4 if args.quick else 8
    trials4 = 2 if args.quick else 4
    trials_abl = 2 if args.quick else 3
    horizon = 50 if args.quick else 70

    if args.only in (None, "fig3"):
        print("=" * 72)
        print("## Fig. 3 — strategy distributions "
              f"(on-time completion, total cost) [{args.scenario}]")
        from benchmarks.fig3_strategies import main as fig3
        fig3(n_trials=trials3, horizon=horizon, out="bench_fig3.json",
             scenario=args.scenario, n_workers=args.workers)

    if args.only in (None, "fig4"):
        print("=" * 72)
        print("## Fig. 4 — escalating load (1.0x / 1.5x / 2.0x) "
              f"[{args.scenario}]")
        from benchmarks.fig4_load_scaling import main as fig4
        fig4(n_trials=trials4, horizon=horizon, out="bench_fig4.json",
             scenario=args.scenario, n_workers=args.workers)

    # under --quick the simbench smoke runs as its own `make ci` step
    # (`make simbench`), so the smoke chain skips it to avoid doubling up
    if args.only == "simbench" or (args.only is None and not args.quick):
        print("=" * 72)
        print("## Simulator core — vectorized engine vs scalar reference "
              "(metric equality gates; the trials/s floor is "
              "informational)")
        from benchmarks.sim_bench import main as sb
        sb(scenario="baseline", out="bench_sim.json", quick=args.quick)

    if args.only in (None, "scale"):
        print("=" * 72)
        print("## scale_load — population sweep "
              "(reported via repro.experiments.report)")
        from benchmarks.scale_load import main as sl
        if args.quick:
            sl(users=(10, 25), n_trials=1, n_workers=args.workers)
        else:
            sl(n_workers=args.workers)

    if args.only in (None, "ablation"):
        print("=" * 72)
        print("## Ablation — kappa diversity under failure churn")
        from benchmarks.ablation_kappa import main as abl
        abl(trials=trials_abl, horizon=horizon,
            out="bench_ablation_kappa.json", n_workers=args.workers)

    if args.only in (None, "kernels"):
        print("=" * 72)
        print("## Kernel microbenches")
        from benchmarks.kernels_bench import main as kb
        kb()

    if args.only in (None, "pipeline"):
        print("=" * 72)
        print(f"## Pipelined executor — placement transfer cost + "
              f"chunked prefill [{args.scenario}]")
        from benchmarks.pipeline_bench import main as pb
        if args.quick:
            pb(configs="smollm-360m", stages="1,2", n_requests=4,
               prompt_len=33, new_tokens=6, scenario=args.scenario,
               out="bench_pipeline.json")
        else:
            pb(scenario=args.scenario, out="bench_pipeline.json")

    if args.only in (None, "paged"):
        print("=" * 72)
        print(f"## Paged KV + continuous batching — sustained concurrency "
              f"vs dense slots at equal cache memory [{args.scenario}]")
        from benchmarks.paged_bench import main as paged
        if args.quick:
            # CI-sized output goes to a scratch name: bench_paged.json
            # is the committed full-run baseline and must not be
            # clobbered by every `make ci`
            paged(configs="smollm-360m", n_requests=16,
                  scenario=args.scenario, out="bench_paged_quick.json")
        else:
            paged(scenario=args.scenario, out="bench_paged.json")

    if args.only in (None, "engine"):
        print("=" * 72)
        print(f"## Decode hot loop — fused macro-step K sweep, "
              f"dispatches + host syncs per token [{args.scenario}]")
        from benchmarks.engine_bench import main as engine
        if args.quick:
            # CI-sized output goes to a scratch name (the committed
            # full-run baseline is bench_engine.json, per the
            # bench_paged_quick convention)
            engine(n_requests=12, ks="1,4", engines="dense,paged",
                   reps=2, scenario=args.scenario,
                   out="bench_engine_quick.json")
        else:
            engine(scenario=args.scenario, out="bench_engine.json")

    if args.only in (None, "spec"):
        print("=" * 72)
        print("## Speculative decoding — draft-verify vs macro-step "
              "baseline on a high-acceptance trace")
        from benchmarks.spec_bench import main as spec
        if args.quick:
            # CI-sized output goes to a scratch name; bench_spec.json
            # is the committed full-run baseline
            spec(n_requests=3, new_tokens=48, spec_ks="4,8", reps=2,
                 out="bench_spec_quick.json")
        else:
            spec(out="bench_spec.json")

    if args.only in (None, "goodput"):
        print("=" * 72)
        print("## SLO goodput — FIFO vs EDF vs EDF+effective-capacity "
              "admission on a mixed-QoS overload trace")
        from benchmarks.goodput_bench import main as gp
        if args.quick:
            # CI-sized output goes to a scratch name; bench_goodput.json
            # is the committed full-run baseline
            gp(n_requests=24, span_steps=48,
               out="bench_goodput_quick.json")
        else:
            gp(out="bench_goodput.json")

    if args.only in (None, "quant"):
        print("=" * 72)
        print("## Weight-only quantization — int8/int4 vs bf16, paged "
              "K=16 decode loop + golden gates")
        from benchmarks.quant_bench import main as qb
        if args.quick:
            # CI-sized output goes to a scratch name; bench_quant.json
            # is the committed full-run baseline (make quant-bench)
            qb(d_model=512, d_ff=2048, fmts="bf16,int8", n_requests=4,
               reps=1, out="bench_quant_quick.json")
        else:
            qb(out="bench_quant.json")

    print("=" * 72)
    print("done. roofline: PYTHONPATH=src python -m benchmarks.roofline")


if __name__ == "__main__":
    main()
