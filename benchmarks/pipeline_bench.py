"""Pipelined serving bench: tokens/s and per-hop transfer cost vs the
number of core stages and the placement strategy, plus the chunked- vs
token-by-token prefill wall-clock gap.

For every (config, n_stages, placement) cell the driver runs the real
profile→place→execute loop (ARCHITECTURE.md §Pipeline executor):

  1. build a :class:`~repro.serving.pipeline.PipelinedEngine` on the
     scenario's network topology,
  2. measure per-stage decode latency (``profile``), feed it through
     ``partition.to_application``,
  3. place the stages (``static_ip`` solves the paper's IP; baselines:
     colocate / round_robin / random),
  4. serve a fixed request batch, reporting measured tokens/s and the
     simulated per-hop transfer cost the placement pays.

Compute walltimes are host-dependent (like kernels_bench); the
simulated transfer columns are deterministic given the seed.

  PYTHONPATH=src python -m benchmarks.pipeline_bench --quick
  PYTHONPATH=src python -m benchmarks.pipeline_bench \\
      --configs smollm-360m,mixtral-8x7b --stages 1,2 \\
      --placements static_ip,round_robin --scenario tiered --out p.json
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_smoke_config
from repro.experiments.results import save_results
from repro.experiments.scenarios import get_scenario
from repro.serving import PipelinedEngine, Request, ServingEngine
from repro.serving.pipeline import place_stages

DEFAULT_CONFIGS = "smollm-360m,mixtral-8x7b,falcon-mamba-7b"


def _requests(n: int, prompt_len: int, new_tokens: int, vocab: int,
              seed: int):
    rng = np.random.default_rng(seed)
    return [Request(id=i,
                    prompt=[int(t) for t in
                            rng.integers(1, vocab, size=prompt_len)],
                    max_new_tokens=new_tokens) for i in range(n)]


def _serve(eng, reqs, warmup: bool = True) -> dict:
    """Run requests through an engine; separately times the admission
    (prefill) phase of the first wave.  A warmup request triggers all
    jit compiles first so the timings compare steady-state execution."""
    import jax
    if warmup:
        eng.submit(Request(id=-1, prompt=list(reqs[0].prompt),
                           max_new_tokens=1))
        eng.run()
    for r in reqs:
        eng.submit(Request(id=r.id, prompt=list(r.prompt),
                           max_new_tokens=r.max_new_tokens))
    t0 = time.perf_counter()
    eng._admit()
    caches = (eng.caches if hasattr(eng, "caches")
              else [st.caches for st in eng.stages])
    jax.block_until_ready(jax.tree.leaves(caches))
    t_admit = time.perf_counter() - t0
    admitted = sum(1 for s in eng.slots if s is not None)
    prefill_toks = sum(len(s.prompt) - 1 for s in eng.slots
                       if s is not None)
    done = eng.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in done if r.id >= 0)
    return {"wall_s": dt, "tokens": toks, "tok_per_s": toks / dt,
            "admit_s": t_admit, "admitted": admitted,
            "prefill_tok_per_s": prefill_toks / max(t_admit, 1e-9),
            "outputs": {r.id: list(r.out_tokens) for r in done
                        if r.id >= 0}}


def main(configs=DEFAULT_CONFIGS, stages="1,2", placements="static_ip,"
         "round_robin", scenario: str = "baseline", n_requests: int = 6,
         prompt_len: int = 49, new_tokens: int = 8, chunk: int = 16,
         max_batch: int = 4, cache_len: int = 96, seed: int = 0,
         out: str | None = None):
    scen = get_scenario(scenario)
    net = scen.build_network(np.random.default_rng(seed))
    stage_list = [int(s) for s in str(stages).split(",")]
    placement_list = str(placements).split(",")
    rows = []

    for arch in str(configs).split(","):
        cfg = get_smoke_config(arch)
        reqs = _requests(n_requests, prompt_len, new_tokens,
                         cfg.vocab_size, seed)

        # ---- chunked vs token-by-token prefill (monolithic engine) ----
        mono = {}
        for label, c in (("chunked", chunk), ("token_by_token", 1)):
            eng = ServingEngine(cfg, max_batch=max_batch,
                                cache_len=cache_len, prefill_chunk=c)
            mono[label] = _serve(eng, reqs)
        speedup = (mono["token_by_token"]["admit_s"]
                   / mono["chunked"]["admit_s"])
        match = mono["chunked"]["outputs"] == mono["token_by_token"]["outputs"]
        print(f"\n== {arch} [{scenario}] ==")
        print(f"prefill wave of {mono['chunked']['admitted']}: "
              f"chunked({chunk}) {mono['chunked']['admit_s']*1e3:.0f}ms "
              f"({mono['chunked']['prefill_tok_per_s']:.0f} tok/s) vs "
              f"token-by-token {mono['token_by_token']['admit_s']*1e3:.0f}ms "
              f"({mono['token_by_token']['prefill_tok_per_s']:.0f} tok/s) "
              f"-> {speedup:.2f}x, outputs identical: {match}")
        rows.append({"arch": arch, "section": "prefill",
                     "chunk": chunk, "speedup": speedup,
                     "chunked_admit_s": mono["chunked"]["admit_s"],
                     "token_admit_s": mono["token_by_token"]["admit_s"],
                     "chunked_wall_s": mono["chunked"]["wall_s"],
                     "token_wall_s": mono["token_by_token"]["wall_s"],
                     "outputs_identical": match})

        # ---- pipeline: stages x placement -----------------------------
        print(f"{'stages':>6s} {'placement':>12s} {'tok/s':>8s} "
              f"{'net ms/tok':>10s} {'net MB':>8s} {'sites':>6s} match")
        for n_st in stage_list:
            for strat in placement_list:
                eng = PipelinedEngine(
                    cfg, n_stages=n_st, max_batch=max_batch,
                    cache_len=cache_len, prefill_chunk=chunk, net=net)
                measured = eng.profile()
                app = eng.to_application(np.random.default_rng(seed),
                                         measured_ms=measured)
                eng.set_placement(place_stages(
                    app, net, strat, rng=np.random.default_rng(seed)))
                res = _serve(eng, reqs)
                ok = res["outputs"] == mono["chunked"]["outputs"]
                net_per_tok = eng.transfer_ms / max(res["tokens"], 1)
                sites = len(set(eng.placement.values()))
                print(f"{n_st:6d} {strat:>12s} {res['tok_per_s']:8.1f} "
                      f"{net_per_tok:10.3f} {eng.transfer_mb:8.3f} "
                      f"{sites:6d} {ok}")
                rows.append({
                    "arch": arch, "section": "pipeline",
                    "n_stages": n_st, "placement": strat,
                    "tok_per_s": res["tok_per_s"],
                    "transfer_ms_per_tok": net_per_tok,
                    "transfer_ms": eng.transfer_ms,
                    "transfer_mb": eng.transfer_mb,
                    "stage_nodes": eng.placement,
                    "stage_ms": measured,
                    "hops": {f"{s}->{d}": v
                             for (s, d), v in sorted(eng.hops.items())},
                    "outputs_match_monolithic": ok})
    if out:
        save_results(out, rows, meta={
            "section": "pipeline_bench", "scenario": scenario,
            "configs": configs, "stages": stages,
            "placements": placements, "chunk": chunk, "seed": seed,
            "n_requests": n_requests, "prompt_len": prompt_len,
            "new_tokens": new_tokens})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default=DEFAULT_CONFIGS)
    ap.add_argument("--stages", default="1,2")
    ap.add_argument("--placements", default="static_ip,round_robin")
    ap.add_argument("--scenario", default="baseline",
                    help="registered scenario supplying the network "
                         "topology (see benchmarks.run --list-scenarios)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=49,
                    help="chunk-aligned default (48 prefill tokens = 3 "
                         "full chunks of 16) so the chunked path "
                         "compiles one program shape")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="one config, fewer/shorter requests")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quick:
        args.configs = "smollm-360m"
        args.requests, args.prompt_len, args.new_tokens = 4, 33, 6
    main(configs=args.configs, stages=args.stages,
         placements=args.placements, scenario=args.scenario,
         n_requests=args.requests, prompt_len=args.prompt_len,
         new_tokens=args.new_tokens, chunk=args.chunk, seed=args.seed,
         out=args.out)
