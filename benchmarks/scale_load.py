"""scale_load sweep: strategy robustness as the user population grows.

Sweeps the ``scale_load_N`` / ``scale_load_tiered_N`` scenario family
(10 -> 500 users on proportionally scaled topologies) through the
parallel replication runner and reports per-(scenario, strategy)
summaries via `repro.experiments.report`.  This is the load-scaling
story the paper leads with — and the grid the scalar engine could not
sweep (the vectorized core is what makes N >= 200 tractable; see
benchmarks/sim_bench.py).

The horizon shrinks as N grows (fixed ~per-trial event budget) so the
sweep completes in minutes; the drain window is capped likewise.

Usage: PYTHONPATH=src python -m benchmarks.scale_load
           [--users 10,25,50,100,200] [--trials 2] [--tiered]
           [--out bench_scale_load.json] [--workers N]
"""
from __future__ import annotations

import argparse
from typing import List, Sequence

from repro.experiments.report import report
from repro.experiments.results import save_results
from repro.experiments.runner import TrialSpec, run_grid

DEFAULT_USERS = (10, 25, 50, 100, 200)
STRATEGIES = ("proposal", "lbrr")
SEED_BASE = 3000   # disjoint from fig3 (0..) / fig4 (1000..)
EVENT_BUDGET = 4800   # ~users * horizon kept constant across the sweep


def horizon_for(n_users: int) -> int:
    return min(60, max(10, EVENT_BUDGET // n_users))


def make_specs(users: Sequence[int], n_trials: int,
               tiered: bool = False,
               strategies: Sequence[str] = STRATEGIES) -> List[TrialSpec]:
    fam = "scale_load_tiered_{}" if tiered else "scale_load_{}"
    return [TrialSpec(seed=SEED_BASE + s, strategy=name,
                      scenario=fam.format(n),
                      horizon_slots=horizon_for(n), drain_slots=150)
            for n in users
            for s in range(n_trials)
            for name in strategies]


def main(users: Sequence[int] = DEFAULT_USERS, n_trials: int = 2,
         tiered: bool = False, out: str | None = "bench_scale_load.json",
         n_workers: int | None = None) -> List[dict]:
    specs = make_specs(users, n_trials, tiered=tiered)
    print(f"# scale_load sweep: users={tuple(users)}, "
          f"{n_trials} seeds x {STRATEGIES}, "
          f"{'tiered' if tiered else 'two-tier'} topology "
          f"({len(specs)} trials)")
    rows = run_grid(specs, n_workers=n_workers, progress=True)
    if out:
        save_results(out, rows, meta={
            "section": "scale_load", "users": tuple(users),
            "n_trials": n_trials, "tiered": tiered,
            "horizons": {n: horizon_for(n) for n in users}})
        print(report([out], by=("scenario", "strategy")))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", default=",".join(map(str, DEFAULT_USERS)),
                    help="comma-separated population sizes (must be "
                         "registered scale_load_N scenarios)")
    ap.add_argument("--trials", type=int, default=2,
                    help="seeds per (population, strategy) cell")
    ap.add_argument("--tiered", action="store_true",
                    help="sweep the four-tier scale_load_tiered family")
    ap.add_argument("--out", default="bench_scale_load.json")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args()
    main([int(u) for u in args.users.split(",")], args.trials,
         tiered=args.tiered, out=args.out, n_workers=args.workers)
