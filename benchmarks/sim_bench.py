"""Simulator-core bench: vectorized engine vs. the scalar reference.

Runs the same baseline-scenario trial grid through

  * `repro.core.simulator.Simulator` (vectorized flat-array engine), and
  * `repro.core.simulator_scalar.ScalarSimulator` (the fixed-semantics
    pre-vectorization engine, same RNG stream),

asserts the metrics agree **trial-for-trial** (the scalar engine is the
semantic oracle — any drift is a bug, not noise), and reports trials/s
for both plus the wall-clock speedup.  The acceptance floor for this PR
is a 5x speedup on the 20-trial grid; the CI smoke (`--quick`) prints
the measured ratio against the floor but does not gate on it (shared CI
boxes are noisy) — it *does* gate on metric equality.

Timing JSON (via --out) embeds walltimes and is therefore NOT
byte-identical across replays — only the metric rows are.

Usage: PYTHONPATH=src python -m benchmarks.sim_bench
           [--quick] [--trials N] [--horizon H] [--out sim_bench.json]
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from repro.core.simulator_scalar import run_one_scalar
from repro.experiments.results import metrics_equal, save_results
from repro.experiments.runner import TrialSpec, run_one

SPEEDUP_FLOOR = 5.0
STRATEGIES = ("proposal", "lbrr")


def make_specs(n_trials: int, horizon: int,
               scenario: str = "baseline") -> List[TrialSpec]:
    n_seeds = -(-n_trials // len(STRATEGIES))
    specs = [TrialSpec(seed=s, strategy=name, scenario=scenario,
                       horizon_slots=horizon)
             for s in range(n_seeds) for name in STRATEGIES]
    return specs[:n_trials]


def _diff(a: Dict, b: Dict) -> List[str]:
    return [f"{k}: vectorized={a[k]!r} scalar={b[k]!r}"
            for k in a if not metrics_equal({k: a[k]}, {k: b.get(k)})]


def main(n_trials: int = 20, horizon: int = 40, scenario: str = "baseline",
         out: str | None = None, quick: bool = False) -> dict:
    if quick:
        n_trials, horizon = 4, 16
    specs = make_specs(n_trials, horizon, scenario)
    print(f"# sim_bench: {len(specs)} trials, scenario={scenario}, "
          f"horizon={horizon}, strategies={STRATEGIES}")

    t0 = time.perf_counter()
    vec = [run_one(s) for s in specs]
    t_vec = time.perf_counter() - t0

    t0 = time.perf_counter()
    scal = [run_one_scalar(s) for s in specs]
    t_scal = time.perf_counter() - t0

    mismatches = []
    for spec, a, b in zip(specs, vec, scal):
        if not metrics_equal(a, b):
            mismatches.append((spec, _diff(a, b)))
    if mismatches:
        for spec, diffs in mismatches:
            print(f"MISMATCH {spec.scenario}/{spec.strategy}/s{spec.seed}:")
            for d in diffs:
                print(f"  {d}")
        raise SystemExit(
            f"{len(mismatches)}/{len(specs)} trials diverged from the "
            f"scalar reference — the vectorized engine broke semantics")

    speedup = t_scal / max(t_vec, 1e-9)
    tps_vec = len(specs) / max(t_vec, 1e-9)
    tps_scal = len(specs) / max(t_scal, 1e-9)
    verdict = "meets" if speedup >= SPEEDUP_FLOOR else "BELOW"
    print(f"metrics: all {len(specs)} trials identical to the scalar "
          f"reference")
    print(f"vectorized: {t_vec:8.2f}s  ({tps_vec:7.2f} trials/s)")
    print(f"scalar ref: {t_scal:8.2f}s  ({tps_scal:7.2f} trials/s)")
    print(f"speedup:    {speedup:8.2f}x  ({verdict} the "
          f"{SPEEDUP_FLOOR:.0f}x floor; informational in CI)")
    summary = {"n_trials": len(specs), "scenario": scenario,
               "horizon_slots": horizon, "wall_s_vectorized": t_vec,
               "wall_s_scalar": t_scal, "speedup": speedup,
               "trials_per_s_vectorized": tps_vec,
               "trials_per_s_scalar": tps_scal,
               "speedup_floor": SPEEDUP_FLOOR}
    if out:
        save_results(out, vec, meta={"section": "sim_bench", **summary})
    return summary


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=20)
    ap.add_argument("--horizon", type=int, default=40)
    ap.add_argument("--scenario", default="baseline")
    ap.add_argument("--out", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: 4 trials, horizon 16")
    args = ap.parse_args()
    main(args.trials, args.horizon, args.scenario, args.out,
         quick=args.quick)
