"""SLO-goodput bench: FIFO vs EDF vs EDF+effective-capacity admission.

Replays one deterministic mixed-class trace (QoS tiers from
``repro.serving.scheduler.QOS_CLASSES``, arrivals bunched into an
overload burst) through the paged scheduler state machine under each
scheduling policy and reports **goodput** — the fraction of submitted
requests meeting both their TTFT and TPOT deadlines — plus the
per-class on-time breakdown (`benchmarks/report.py --goodput` renders
the table).

The engine is the `src/repro/serving/testbed.py` FakeEngine: the real
``_PagedEngine`` admission / growth / preemption machinery over a
scripted integer decoder, so every number here is a pure function of
the trace — engine-step deadlines, no wall-clock, no JAX — and the
committed baseline (``bench_goodput.json``) is reproducible on any
host.  ``outputs_match`` asserts both that every completed stream
equals the testbed's golden recurrence *and* that requests completed
under several policies produced identical streams: scheduling changes
which rows run, never what they compute.

What the trace is built to show (the paper's Sec. III-B story at the
serving layer):

* **FIFO** head-of-line admission lets early batch hogs starve the
  interactive tier straight through its TTFT budget;
* **EDF** recovers most of it by deadline order + slack aging;
* **EDF+EC** (the effective-capacity admission test, eq. 21) goes
  further under overload: requests whose block deficit cannot
  statistically clear within their remaining TTFT slack are rejected
  up front, so the pool serves only requests that can still make
  their deadlines — trading a few early rejections for a higher
  fraction of on-time completions.

  PYTHONPATH=src python -m benchmarks.goodput_bench --quick
  PYTHONPATH=src python -m benchmarks.goodput_bench --out bench_goodput.json
"""
from __future__ import annotations

import argparse
from typing import List, Tuple

import numpy as np

from repro.experiments.results import save_results
from repro.serving.engine import Request
from repro.serving.scheduler import (QOS_CLASSES, goodput, make_policy,
                                     per_class_stats)
from repro.serving.testbed import FakeEngine, fake_stream

POLICY_NAMES = ("fifo", "edf", "edf_ec")

#: class mix and per-class sizing: interactive = chat turns (short
#: prompt, short answer), standard = tool calls, batch = long
#: summarization hogs (long prompt, long generation)
CLASS_MIX: List[Tuple[str, float, Tuple[int, int], Tuple[int, int]]] = [
    ("interactive", 0.45, (3, 10), (4, 8)),
    ("standard", 0.30, (8, 24), (8, 16)),
    ("batch", 0.25, (24, 48), (24, 40)),
]


def build_mixed_trace(seed: int, n_requests: int, span_steps: int):
    """Deterministic mixed-class arrivals: ``(t, qos, prompt, max_new)``
    sorted by arrival step.  The first third of the span carries twice
    the arrival density (the overload burst that separates the
    policies); prompt tokens are drawn in-vocab for the testbed."""
    rng = np.random.default_rng(np.random.SeedSequence(seed))
    names = [c[0] for c in CLASS_MIX]
    probs = np.asarray([c[1] for c in CLASS_MIX])
    out = []
    for i in range(n_requests):
        qos = names[int(rng.choice(len(names), p=probs / probs.sum()))]
        _, _, (plo, phi), (nlo, nhi) = CLASS_MIX[names.index(qos)]
        plen = int(rng.integers(plo, phi + 1))
        burst = rng.random() < 0.5
        t = int(rng.integers(0, span_steps // 3 if burst else span_steps))
        out.append((t, qos,
                    [int(x) for x in rng.integers(1, 900, size=plen)],
                    int(rng.integers(nlo, nhi + 1))))
    out.sort(key=lambda e: e[0])
    return out


def drive(policy_name: str, trace, *, max_rows: int, max_len: int,
          block_size: int, num_blocks: int, decode_steps: int) -> dict:
    """One fresh engine + policy per pass (policies carry virtual-queue
    and service-model state — never share across passes)."""
    eng = FakeEngine(max_rows=max_rows, max_len=max_len,
                     block_size=block_size, num_blocks=num_blocks,
                     decode_steps=decode_steps,
                     policy=make_policy(policy_name))
    pending = [(t, Request(id=i, prompt=list(p), max_new_tokens=n, qos=q))
               for i, (t, q, p, n) in enumerate(trace)]
    reqs = [r for _, r in pending]
    done: List[Request] = []
    while pending or eng.queue or not eng._idle():
        while pending and pending[0][0] <= eng.t:
            eng.submit(pending.pop(0)[1])
        done += eng.step()
    # every emitted stream must equal the testbed's golden recurrence —
    # scheduling must never perturb computed tokens
    oracle_ok = all(r.out_tokens == fake_stream(r.prompt, len(r.out_tokens))
                    for r in done)
    stats = per_class_stats(reqs)
    row = {
        "policy": policy_name,
        "n_requests": len(reqs),
        "completed": len(done),
        "rejected": len(eng.rejected),
        "preemptions": eng.n_preemptions,
        "engine_steps": eng.t,
        "tokens": eng.tokens_generated,
        "goodput": goodput(reqs),
        "outputs_match": oracle_ok,
        "outputs": {r.id: list(r.out_tokens) for r in done},
    }
    for cls, s in sorted(stats.items()):
        row[f"{cls}_n"] = s["n"]
        row[f"{cls}_on_time"] = s["on_time"]
        row[f"{cls}_rejected"] = s["rejected"]
        row[f"{cls}_goodput"] = s["goodput"]
        row[f"{cls}_ttft_mean"] = s["ttft_mean"]
    return row


def main(n_requests: int = 64, span_steps: int = 72, seed: int = 0,
         max_rows: int = 4, max_len: int = 96, block_size: int = 8,
         num_blocks: int = 20, decode_steps: int = 4,
         policies: str = ",".join(POLICY_NAMES), out: str | None = None):
    trace = build_mixed_trace(seed, n_requests, span_steps)
    geom = dict(max_rows=max_rows, max_len=max_len, block_size=block_size,
                num_blocks=num_blocks, decode_steps=decode_steps)
    names = [s.strip() for s in str(policies).split(",")]
    print(f"== goodput: {n_requests} reqs over {span_steps} steps, "
          f"pool {num_blocks}x{block_size} tokens, {max_rows} rows, "
          f"K={decode_steps}, seed {seed} ==")
    print(f"{'policy':>8s} {'goodput':>8s} {'done':>5s} {'rej':>4s} "
          f"{'preempt':>7s} " + " ".join(
              f"{c[0][:5]:>8s}" for c in CLASS_MIX) + "  match")
    rows = []
    for name in names:
        r = drive(name, trace, **geom)
        rows.append(r)
        per_cls = " ".join(
            f"{r.get(f'{c[0]}_goodput', 0.0):8.3f}" for c in CLASS_MIX)
        print(f"{name:>8s} {r['goodput']:8.3f} {r['completed']:5d} "
              f"{r['rejected']:4d} {r['preemptions']:7d} {per_cls}  "
              f"{r['outputs_match']}")
    # cross-policy stream identity on commonly-completed requests
    ids = set.intersection(*(set(r["outputs"]) for r in rows)) if rows \
        else set()
    cross = all(rows[0]["outputs"][i] == r["outputs"][i]
                for r in rows[1:] for i in ids)
    for r in rows:
        r["outputs_match"] = bool(r["outputs_match"] and cross)
        del r["outputs"]      # streams verified; don't bloat the JSON
    print(f"cross-policy streams identical on {len(ids)} shared "
          f"completions: {cross}")
    if out:
        save_results(out, rows, meta={
            "section": "goodput_bench", "seed": seed,
            "n_requests": n_requests, "span_steps": span_steps,
            "policies": ",".join(names), **geom,
            "qos_classes": {n: {"ttft": c.ttft, "tpot": c.tpot,
                                "eps": c.eps, "phi": c.phi}
                            for n, c in QOS_CLASSES.items()},
            "note": "engine-step-clock metrics; deterministic given the "
                    "seed (FakeEngine testbed, no wall-clock terms)"})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--span", type=int, default=72,
                    help="arrival window in engine steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rows", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=20)
    ap.add_argument("--decode-steps", type=int, default=4)
    ap.add_argument("--policies", default=",".join(POLICY_NAMES))
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized trace (same qualitative ordering)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quick:
        args.requests, args.span = 24, 48
    main(n_requests=args.requests, span_steps=args.span, seed=args.seed,
         max_rows=args.rows, max_len=args.max_len,
         block_size=args.block_size, num_blocks=args.num_blocks,
         decode_steps=args.decode_steps, policies=args.policies,
         out=args.out)
