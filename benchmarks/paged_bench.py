"""Paged vs dense serving at equal cache memory on a scenario trace.

The dense engines reserve one full ``cache_len`` KV row per slot, so
memory — not compute — caps concurrency: a mixed-length workload
strands most of the cache inside over-provisioned rows.  The paged
engine spends the *same* token-slot budget as a block pool
(``num_blocks * block_size == max_batch * cache_len``) with token-level
admission, so short requests pack densely and concurrency is bounded
by actual usage (SERVING.md §Paged vs dense).

For each configured architecture the driver

  1. synthesizes a deterministic mixed-length request trace whose
     arrival process comes from a registered scenario's modulation
     (`src/repro/experiments/scenarios.py` — bursty_mmpp gives the
     paged engine the most to absorb),
  2. replays the identical trace through ``ServingEngine`` (dense
     slots) and ``PagedServingEngine`` (continuous batching) at equal
     cache memory,
  3. reports sustained/peak concurrency, cache utilization, tokens/s,
     per-request queueing and completion latency (step units, from the
     ``Request.t_*`` stamps), preemption count, and greedy-output
     parity.

Wall-clock tokens/s is host-dependent (like pipeline_bench); the
concurrency/utilization/latency columns and the outputs are
deterministic given ``--seed`` (EXPERIMENTS.md §Reading bench JSON).

Config caveats.  The default architectures are full-attention AND
*batch-decoupled*, for two reasons:

* the equal-memory framing is only exact when the cache is KV-
  dominated — SSM/conv state (and per-request SWA rings / cross
  blocks) scale with decode *rows*, not pooled tokens, so on e.g. a
  pure-SSM config the pool constrains nothing and a ``max_rows``
  advantage is a memory grant, not paging;
* capacity-factor MoE routing (`src/repro/models/moe.py`) prioritizes
  expert slots across the whole co-batched token set, so under
  capacity pressure a MoE request's outputs legitimately depend on
  what it is batched with — there ``outputs_match`` would compare
  scheduling policies, not cache correctness (the paged↔dense parity
  tests pin MoE equality at matched small-batch regimes,
  tests/test_paged.py).

  PYTHONPATH=src python -m benchmarks.paged_bench --quick
  PYTHONPATH=src python -m benchmarks.paged_bench \\
      --scenario bursty_mmpp --requests 48 --out bench_paged.json
"""
from __future__ import annotations

import argparse
import time
import zlib

import numpy as np

from repro.configs import get_smoke_config
from repro.experiments.results import save_results
from repro.experiments.scenarios import get_scenario
from repro.serving import PagedServingEngine, Request, ServingEngine

DEFAULT_CONFIGS = "smollm-360m,qwen2-72b"


def build_trace(scenario: str, seed: int, n_requests: int, max_len: int,
                span_steps: int | None = None, short_frac: float = 0.7,
                new_lo: int = 4, new_hi: int = 21,
                shared_prefix_frac: float = 0.0,
                shared_prefix_len: int = 48):
    """Deterministic mixed-length request trace: (arrival_step, prompt,
    max_new_tokens) tuples, arrival counts modulated by the scenario's
    workload dynamics (stationary scenarios fall back to Poisson).

    The default span packs ~2 arrivals per engine step so the offered
    load exceeds the dense engine's slot count — the regime where
    block-granular admission matters.  ``new_lo``/``new_hi`` bound the
    sampled ``max_new_tokens`` — the defaults keep this bench's
    admission-heavy mix; `benchmarks/engine_bench.py` raises them for a
    decode-dominant (steady-state) variant of the same trace.

    ``shared_prefix_frac`` models system-prompt traffic: that fraction
    of requests carries one deterministic ``shared_prefix_len``-token
    common stem plus a short random tail — the workload prefix-sharing
    admission (SERVING.md §Prefix sharing) turns into mapped blocks and
    skipped prefill.  At 0.0 (the default) the draw stream is untouched
    and traces are bit-identical to the pre-knob bench."""
    if span_steps is None:
        span_steps = max(8, n_requests // 2)
    ss = np.random.SeedSequence(
        [seed, zlib.crc32(scenario.encode()), zlib.crc32(b"paged_bench")])
    r_arr, r_len, r_mod = [np.random.default_rng(s) for s in ss.spawn(3)]
    modulation = get_scenario(scenario).arrival_modulation(r_mod)
    stem = [int(x) for x in np.random.default_rng(ss.spawn(1)[0])
            .integers(1, 500, size=shared_prefix_len)]
    rate = n_requests / span_steps
    trace = []
    t = 0
    while len(trace) < n_requests:
        mult = modulation(t) if modulation is not None else 1.0
        for _ in range(r_arr.poisson(rate * mult)):
            if len(trace) >= n_requests:
                break
            if (shared_prefix_frac
                    and r_len.random() < shared_prefix_frac):
                new = min(int(r_len.integers(new_lo, new_hi)), max_len - 2)
                t_len = int(r_len.integers(4, 14))
                t_len = max(1, min(t_len,
                                   max_len - new - shared_prefix_len))
                prompt = stem + [int(x) for x in
                                 r_len.integers(1, 500, size=t_len)]
                trace.append((t, prompt, new))
                continue
            if r_len.random() < short_frac:
                p_len = int(r_len.integers(6, 17))
            else:
                p_len = int(r_len.integers(40, 65))
            new = min(int(r_len.integers(new_lo, new_hi)), max_len - 2)
            p_len = max(1, min(p_len, max_len - new))
            prompt = [int(x) for x in r_len.integers(1, 500, size=p_len)]
            trace.append((t, prompt, new))
        t += 1
    return trace


def drive(eng, trace, is_paged: bool) -> dict:
    """Replay a trace through an engine; a warmup request triggers the
    jit compiles so the timed phase compares steady-state execution."""
    import jax
    long_prompt = max((p for _, p, _ in trace), key=len)
    eng.submit(Request(id=-1, prompt=list(long_prompt), max_new_tokens=1))
    eng.run()
    caches = (eng.caches if hasattr(eng, "caches")
              else [st.caches for st in eng.stages])
    jax.block_until_ready(jax.tree.leaves(caches))

    t0_step = eng.t
    # counter snapshots: the warmup request's prefill (and any prefix
    # registration it left behind is already drained — its blocks
    # deindexed at release) must not pollute the timed-phase stats
    pf0 = eng.prefill_tokens
    share0 = ((eng.pc.n_prefix_hits, eng.pc.prefix_tokens_hit,
               eng.pc.blocks_saved, eng.pc.n_cow_copies)
              if is_paged else (0, 0, 0, 0))
    pending = [(t + t0_step, Request(id=i, prompt=list(p), max_new_tokens=n))
               for i, (t, p, n) in enumerate(trace)]
    done, conc, util = [], [], []
    t0 = time.perf_counter()
    while pending or eng.queue or any(
            s is not None for s in (eng.rows if is_paged else eng.slots)):
        while pending and pending[0][0] <= eng.t:
            eng.submit(pending.pop(0)[1])
        done += eng.step()
        active = (eng.active_rows if is_paged
                  else sum(1 for s in eng.slots if s is not None))
        conc.append(active)
        if is_paged:
            util.append(eng.pc.utilization())
        else:
            used = sum(int(eng.pos[i]) + 1 for i, s in enumerate(eng.slots)
                       if s is not None)
            util.append(used / (eng.max_batch * eng.cache_len))
    wall = time.perf_counter() - t0

    done = [r for r in done if r.id >= 0]
    toks = sum(len(r.out_tokens) for r in done)
    busy = [c for c in conc if c > 0]
    queue_d = np.array([r.t_admit - r.t_submit for r in done], float)
    complete = np.array([r.t_done - r.t_submit for r in done], float)
    row = {
        "completed": len(done),
        "rejected": len(eng.rejected),
        "tokens": toks,
        "wall_s": wall,
        "tok_per_s": toks / wall,
        "steps": len(conc),
        "concurrency_mean": float(np.mean(busy)) if busy else 0.0,
        "concurrency_peak": int(max(conc, default=0)),
        "cache_util_mean": float(np.mean([u for c, u in zip(conc, util)
                                          if c > 0]) if busy else 0.0),
        "queue_delay_mean": float(queue_d.mean()) if done else 0.0,
        "queue_delay_p95": (float(np.percentile(queue_d, 95))
                            if done else 0.0),
        "complete_mean": float(complete.mean()) if done else 0.0,
        "complete_p95": (float(np.percentile(complete, 95))
                         if done else 0.0),
        "preemptions": eng.n_preemptions if is_paged else 0,
        "outputs": {r.id: list(r.out_tokens) for r in done},
    }
    prefilled = eng.prefill_tokens - pf0
    row["prefill_tokens"] = prefilled
    if is_paged:
        hits = eng.pc.n_prefix_hits - share0[0]
        hit_tok = eng.pc.prefix_tokens_hit - share0[1]
        # admissions = every completion reached the rows once, plus one
        # re-admission per preemption (rejects never admit)
        admits = len(done) + eng.n_preemptions
        row.update({
            "prefix_hits": hits,
            "admit_hit_rate": hits / admits if admits else 0.0,
            "prefill_skip_frac": (hit_tok / (hit_tok + prefilled)
                                  if hit_tok + prefilled else 0.0),
            "blocks_saved": eng.pc.blocks_saved - share0[2],
            "cow_copies": eng.pc.n_cow_copies - share0[3],
        })
    else:
        row.update({"prefix_hits": 0, "admit_hit_rate": 0.0,
                    "prefill_skip_frac": 0.0, "blocks_saved": 0,
                    "cow_copies": 0})
    return row


def main(configs=DEFAULT_CONFIGS, scenario: str = "bursty_mmpp",
         n_requests: int = 32, max_batch: int = 4, cache_len: int = 96,
         max_rows: int = 12, block_size: int = 16, prefill_chunk: int = 16,
         watermark_blocks: int = 0, seed: int = 0,
         shared_prefix_frac: float = 0.7, shared_prefix_len: int = 48,
         out: str | None = None):
    num_blocks = max_batch * cache_len // block_size  # equal token-slots
    rows = []
    for arch in str(configs).split(","):
        cfg = get_smoke_config(arch)
        trace = build_trace(scenario, seed, n_requests, cache_len,
                            shared_prefix_frac=shared_prefix_frac,
                            shared_prefix_len=shared_prefix_len)

        def paged_engine(sharing):
            return PagedServingEngine(
                cfg, max_rows=max_rows, max_len=cache_len,
                block_size=block_size, num_blocks=num_blocks,
                prefill_chunk=prefill_chunk,
                watermark_blocks=watermark_blocks,
                prefix_sharing=sharing)

        # three engines at EQUAL cache memory: dense slots, the paged
        # pool with exclusive block ownership, and the paged pool with
        # prefix sharing — so the bench separates the paging gain
        # (paged/dense) from the sharing gain (shared/paged)
        res = {}
        for label, mk in (
                ("dense", lambda: ServingEngine(
                    cfg, max_batch=max_batch, cache_len=cache_len,
                    prefill_chunk=prefill_chunk)),
                ("paged", lambda: paged_engine(False)),
                ("shared", lambda: paged_engine(True))):
            res[label] = drive(mk(), trace, is_paged=(label != "dense"))
        match = (res["dense"]["outputs"] == res["paged"]["outputs"]
                 == res["shared"]["outputs"])
        gain_paged = (res["paged"]["concurrency_mean"]
                      / max(res["dense"]["concurrency_mean"], 1e-9))
        gain_shared = (res["shared"]["concurrency_mean"]
                       / max(res["paged"]["concurrency_mean"], 1e-9))
        print(f"\n== {arch} [{scenario}] {n_requests} reqs "
              f"(shared-prefix frac {shared_prefix_frac}), "
              f"{num_blocks} blocks x {block_size} == "
              f"{max_batch} slots x {cache_len} tokens ==")
        print(f"{'engine':>6s} {'tok/s':>8s} {'conc':>6s} {'peak':>5s} "
              f"{'util':>6s} {'q_mean':>7s} {'q_p95':>6s} {'preempt':>7s} "
              f"{'hits':>5s} {'skip':>5s} {'saved':>6s}")
        for label in ("dense", "paged", "shared"):
            r = res[label]
            print(f"{label:>6s} {r['tok_per_s']:8.1f} "
                  f"{r['concurrency_mean']:6.2f} {r['concurrency_peak']:5d} "
                  f"{r['cache_util_mean']:6.2f} {r['queue_delay_mean']:7.1f} "
                  f"{r['queue_delay_p95']:6.1f} {r['preemptions']:7d} "
                  f"{r['prefix_hits']:5d} {r['prefill_skip_frac']:5.2f} "
                  f"{r['blocks_saved']:6d}")
        print(f"outputs identical: {match}; sustained concurrency "
              f"paged/dense = {gain_paged:.2f}x, "
              f"shared/paged = {gain_shared:.2f}x")
        for label in ("dense", "paged", "shared"):
            row = {"arch": arch, "engine": label, **res[label]}
            row.pop("outputs")
            row["outputs_match"] = match
            rows.append(row)
    if out:
        save_results(out, rows, meta={
            "section": "paged_bench", "scenario": scenario,
            "configs": configs, "n_requests": n_requests,
            "max_batch": max_batch, "cache_len": cache_len,
            "max_rows": max_rows, "block_size": block_size,
            "num_blocks": num_blocks, "seed": seed,
            "shared_prefix_frac": shared_prefix_frac,
            "shared_prefix_len": shared_prefix_len,
            "note": "wall_s/tok_per_s are host-dependent; all other "
                    "columns are deterministic given the seed"})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default=DEFAULT_CONFIGS)
    ap.add_argument("--scenario", default="bursty_mmpp",
                    help="registered scenario supplying arrival "
                         "modulation (see benchmarks.run --list-scenarios)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="dense slots; the paged pool gets the same "
                         "token-slot budget")
    ap.add_argument("--cache-len", type=int, default=96)
    ap.add_argument("--rows", type=int, default=12,
                    help="paged decode rows (batch width)")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--watermark", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shared-prefix-frac", type=float, default=0.7,
                    help="fraction of requests carrying the common "
                         "system-prompt stem (0 disables the knob)")
    ap.add_argument("--shared-prefix-len", type=int, default=48,
                    help="stem length in tokens (a multiple of "
                         "--block-size shares every stem block)")
    ap.add_argument("--quick", action="store_true",
                    help="one config, fewer requests")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.quick:
        args.configs = "smollm-360m"
        args.requests = 16
    main(configs=args.configs, scenario=args.scenario,
         n_requests=args.requests, max_batch=args.max_batch,
         cache_len=args.cache_len, max_rows=args.rows,
         block_size=args.block_size, watermark_blocks=args.watermark,
         seed=args.seed, shared_prefix_frac=args.shared_prefix_frac,
         shared_prefix_len=args.shared_prefix_len, out=args.out)
