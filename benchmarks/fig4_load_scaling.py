"""Fig. 4: proposal vs PropAvg under escalating load (1.0x / 1.5x / 2.0x
multipliers on the mean task-arrival rate).

The (multiplier x seed x strategy) grid fans out across processes via
the replication runner; `--scenario` layers any registered dynamics
(e.g. bursty_mmpp) under the load sweep.

Reports total + on-time completion (bars in the paper) and system cost
(markers).  Paper claims: PropAvg's total/on-time gap widens with load;
the proposal keeps both high with controlled cost scaling.
"""
from __future__ import annotations

import argparse

from repro.experiments.results import save_results, summarize_rows
from repro.experiments.runner import make_grid, run_grid

MULTIPLIERS = (1.0, 1.5, 2.0)

SEED_BASE = 1000   # disjoint from fig3's seed range


def main(n_trials: int = 6, horizon: int = 80, out: str | None = None,
         scenario: str = "baseline", n_workers: int | None = None,
         bytes_per_param: float | None = None):
    specs = make_grid(seeds=range(SEED_BASE, SEED_BASE + n_trials),
                      strategies=("proposal", "prop_avg"),
                      scenarios=(scenario,),
                      rate_multipliers=MULTIPLIERS,
                      horizon_slots=horizon,
                      bytes_per_param=bytes_per_param)
    rows = run_grid(specs, n_workers=n_workers, progress=True)
    print("load,strategy,completed_mean,completed_std,on_time_mean,"
          "on_time_std,gap_mean,cost_mean,cost_std")
    for s in summarize_rows(rows, keys=("rate_multiplier", "strategy")):
        print(f"{s['rate_multiplier']},{s['strategy']},"
              f"{s['completed_mean']:.4f},{s['completed_std']:.4f},"
              f"{s['on_time_mean']:.4f},{s['on_time_std']:.4f},"
              f"{s['gap_mean']:.4f},{s['cost_mean']:.1f},"
              f"{s['cost_std']:.1f}")
    if out:
        save_results(out, rows, meta={"section": "fig4",
                                      "scenario": scenario,
                                      "n_trials": n_trials,
                                      "horizon_slots": horizon,
                                      "rate_multipliers": MULTIPLIERS,
                                      "bytes_per_param": bytes_per_param})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=6)
    ap.add_argument("--horizon", type=int, default=80)
    ap.add_argument("--out", default=None)
    ap.add_argument("--scenario", default="baseline")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--bytes-per-param", type=float, default=None,
                    help="weight bytes/param for core-service memory "
                         "demand (2.0 bf16 baseline, 1.0 int8, 0.5 "
                         "int4 — SERVING.md §Quantization)")
    args = ap.parse_args()
    main(args.trials, args.horizon, args.out, scenario=args.scenario,
         n_workers=args.workers, bytes_per_param=args.bytes_per_param)
