"""Fig. 4: proposal vs PropAvg under escalating load (1.0x / 1.5x / 2.0x
multipliers on the mean task-arrival rate).

Reports total + on-time completion (bars in the paper) and system cost
(markers).  Paper claims: PropAvg's total/on-time gap widens with load;
the proposal keeps both high with controlled cost scaling.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.experiment import run_trial

MULTIPLIERS = (1.0, 1.5, 2.0)


def main(n_trials: int = 6, horizon: int = 80, out: str | None = None):
    recs = []
    for mult in MULTIPLIERS:
        for seed in range(n_trials):
            recs += run_trial(seed + 1000, strategy_names=["proposal",
                                                           "prop_avg"],
                              rate_multiplier=mult, horizon_slots=horizon)
            print(f"# x{mult} trial {seed + 1}/{n_trials}", flush=True)
    print("load,strategy,completed_mean,completed_std,on_time_mean,"
          "on_time_std,gap_mean,cost_mean,cost_std")
    for mult in MULTIPLIERS:
        for strat in ("proposal", "prop_avg"):
            rs = [r for r in recs if r["rate_multiplier"] == mult
                  and r["strategy"] == strat]
            comp = np.array([r["completed"] for r in rs])
            ont = np.array([r["on_time"] for r in rs])
            cost = np.array([r["total_cost"] for r in rs])
            print(f"{mult},{strat},{comp.mean():.4f},{comp.std():.4f},"
                  f"{ont.mean():.4f},{ont.std():.4f},"
                  f"{(comp - ont).mean():.4f},{cost.mean():.1f},"
                  f"{cost.std():.1f}")
    if out:
        with open(out, "w") as f:
            json.dump(recs, f)
    return recs


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=6)
    ap.add_argument("--horizon", type=int, default=80)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(args.trials, args.horizon, args.out)
