"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
JSONL outputs of launch.dryrun and benchmarks.roofline.

Adds an analytic *kernel-model* memory bound per (arch, shape): the CPU
backend's cost_analysis() counts un-fused per-op bytes (no TPU fusion,
f32 score materialization, etc.), which inflates the memory term by
1–2 orders of magnitude.  The kernel model counts the traffic a
TPU-fused implementation (our Pallas kernels) must move:

  inference: params once + KV-cache r/w + 4 activation streams/layer
  train:     params fwd+bwd reads + update write + f32 moments r/w
             + remat activation store/reload (4 streams/layer)

Dominance is reported under BOTH memory columns.

Also renders EXPERIMENTS.md §JSON-schema result files from the
replication runner (bench_fig3.json / bench_fig4.json / ...) as
markdown summary tables via `--experiments`.

Usage: PYTHONPATH=src python -m benchmarks.report \
           [--dryrun dryrun_results.jsonl] [--roofline roofline_results.jsonl] \
           [--experiments bench_fig3.json bench_fig4.json]
"""
from __future__ import annotations

import argparse
import json

from repro.config import SHAPES
from repro.configs import get_config
from repro.launch.hlo_analysis import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

MODEL_SHARDS = 16
DATA_SHARDS = 16


def kernel_model_bytes(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.num_params()
    p_local = 2 * n / MODEL_SHARDS                    # bf16 params/device
    b_local = max(1, shape.global_batch // DATA_SHARDS)
    d = cfg.d_model
    if shape.kind == "train":
        s = shape.seq_len
        opt_local = 8 * n / MODEL_SHARDS              # two f32 moments
        param_io = 3 * p_local + 2 * opt_local
        act_io = 4 * cfg.n_layers * b_local * s * d * 2
        moe_io = 0.0
        if cfg.mlp_kind == "moe":
            cap = b_local * s * cfg.experts_per_token * 1.25
            e_loc = max(1, cfg.n_experts // MODEL_SHARDS)
            moe_io = 4 * min(cap, cap) * d * 2 * cfg.n_layers
        return param_io + act_io + moe_io
    # inference
    if shape.is_decode:
        from repro.models.kvcache import cache_bytes
        kv = cache_bytes(cfg, b_local, shape.seq_len) / MODEL_SHARDS \
            if cfg.has_attention else cache_bytes(cfg, b_local, 1)
        return p_local + 2 * kv + 8 * cfg.n_layers * b_local * d * 2
    # prefill
    s = shape.seq_len
    act_io = 4 * cfg.n_layers * b_local * s * d * 2
    kv_write = 2 * cfg.n_layers * b_local * s * cfg.n_kv_heads * \
        cfg.head_dim * 2 if cfg.has_attention else 0
    return p_local + act_io + kv_write


def load(path):
    try:
        rows = [json.loads(l) for l in open(path)]
    except FileNotFoundError:
        return []
    seen = {}
    for r in rows:  # dedupe, keep the latest record per key
        seen[(r.get("arch"), r.get("shape"), r.get("mesh"),
              r.get("layout"))] = r
    return list(seen.values())


def engine_table(path: str) -> None:
    """Markdown summary of a benchmarks.engine_bench JSON: the
    PR-over-PR perf trajectory of the decode hot loop (tokens/s and
    host-overhead-per-token by engine and macro-step K), plus the K=max
    vs K=1 speedup per engine."""
    from repro.experiments.results import load_results
    try:
        rows, meta = load_results(path)
    except FileNotFoundError:
        print(f"\n### §Decode hot loop — {path}: missing, skipped\n")
        return
    print(f"\n### §Decode hot loop — {path} "
          f"(scenario={meta.get('scenario', '?')}, "
          f"trace={meta.get('n_requests', '?')} reqs, "
          f"batch={meta.get('max_batch', '?')})\n")
    print("| arch | engine | K | tok/s | MFU | MBU | disp/token | "
          "syncs/token | steady syncs | uploads/token | match |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        # MFU/MBU columns exist from the quantization PR on; older
        # committed baselines render as '-'
        mfu_s = f"{r['mfu']:.1e}" if "mfu" in r else "-"
        mbu_s = f"{r['mbu']:.1e}" if "mbu" in r else "-"
        print(f"| {r['arch']} | {r['engine']} | {r['k']} "
              f"| {r['tok_per_s']:.0f} | {mfu_s} | {mbu_s} "
              f"| {r['dispatches_per_token']:.4f} "
              f"| {r['syncs_per_token']:.4f} "
              f"| {r['steady_syncs_per_token']:.4f} "
              f"| {r['uploads_per_token']:.4f} "
              f"| {r['outputs_match']} |")
    by = {}
    for r in rows:
        by.setdefault((r["arch"], r["engine"]), {})[r["k"]] = r["tok_per_s"]
    lines = []
    for (arch, eng), ks in sorted(by.items()):
        if len(ks) > 1:
            k1, kmax = min(ks), max(ks)
            lines.append(f"{arch}/{eng}: K={kmax} is "
                         f"{ks[kmax] / ks[k1]:.2f}x K={k1}")
    if lines:
        print("\n" + "; ".join(lines))


def spec_table(path: str) -> None:
    """Markdown summary of a benchmarks.spec_bench JSON: tokens/s,
    acceptance rate, and verify-dispatch/host-sync overhead per token
    for the draft-verify cells vs the macro-step baseline, plus the
    committed speedup-criterion line."""
    from repro.experiments.results import load_results
    try:
        rows, meta = load_results(path)
    except FileNotFoundError:
        print(f"\n### §Speculative decoding — {path}: missing, skipped\n")
        return
    print(f"\n### §Speculative decoding — {path} "
          f"({meta.get('n_requests', '?')} reqs x "
          f"{meta.get('new_tokens', '?')} new tokens, "
          f"{meta.get('draft', '?')} draft, baseline paged "
          f"K={meta.get('baseline_k', '?')})\n")
    print("| arch | cell | K | tok/s | acceptance | accept mean | "
          "verify/token | syncs/token | match |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["cell"] == "summary":
            continue
        print(f"| {r['arch']} | {r['cell']} | {r['k']} "
              f"| {r['tok_per_s']:.0f} | {r['acceptance_rate']:.3f} "
              f"| {r['accept_mean']:.2f} | {r['verify_per_token']:.4f} "
              f"| {r['syncs_per_token']:.4f} | {r['outputs_match']} |")
    for r in rows:
        if r["cell"] == "summary":
            print(f"\n{r['arch']}: best spec K={r['k']} is "
                  f"{r['speedup_vs_baseline']:.2f}x the macro-step "
                  f"baseline (criterion >= {r['min_speedup']}x: "
                  f"{'met' if r['meets_criterion'] else 'NOT met'}, "
                  f"outputs_match={r['outputs_match']})")


def goodput_table(path: str) -> None:
    """Markdown summary of a benchmarks.goodput_bench JSON: overall and
    per-QoS-class goodput by scheduling policy, plus the on-time /
    rejected breakdown the SLO story turns on."""
    from repro.experiments.results import load_results
    try:
        rows, meta = load_results(path)
    except FileNotFoundError:
        print(f"\n### §SLO goodput — {path}: missing, skipped\n")
        return
    classes = sorted(meta.get("qos_classes",
                              {"interactive": 0, "standard": 0,
                               "batch": 0}))
    print(f"\n### §SLO goodput — {path} "
          f"({meta.get('n_requests', '?')} reqs over "
          f"{meta.get('span_steps', '?')} steps, seed "
          f"{meta.get('seed', '?')})\n")
    print("| policy | goodput | done | rej | preempt | "
          + " | ".join(classes) + " | match |")
    print("|---" * (6 + len(classes)) + "|")
    for r in rows:
        per_cls = " | ".join(f"{r.get(f'{c}_goodput', 0.0):.3f}"
                             for c in classes)
        print(f"| {r['policy']} | {r['goodput']:.3f} | {r['completed']} "
              f"| {r['rejected']} | {r['preemptions']} | {per_cls} "
              f"| {r['outputs_match']} |")
    print("\n| policy | class | n | on-time | rejected | TTFT mean |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        for c in classes:
            if f"{c}_n" not in r:
                continue
            ttft = r.get(f"{c}_ttft_mean")
            print(f"| {r['policy']} | {c} | {r[f'{c}_n']} "
                  f"| {r[f'{c}_on_time']} | {r[f'{c}_rejected']} "
                  f"| {'-' if ttft is None else f'{ttft:.1f}'} |")


def quant_table(path: str) -> None:
    """Markdown summary of a benchmarks.quant_bench JSON: tokens/s,
    speedup vs the bf16 cell, MFU/MBU, resident weight bytes, and the
    golden-gate verdicts per format, plus the committed
    speedup-criterion line (SERVING.md §Quantization)."""
    from repro.experiments.results import load_results
    try:
        rows, meta = load_results(path)
    except FileNotFoundError:
        print(f"\n### §Quantization — {path}: missing, skipped\n")
        return
    print(f"\n### §Quantization — {path} "
          f"({meta.get('arch', '?')} paged K={meta.get('k', '?')}, "
          f"{meta.get('d_model', '?')}x{meta.get('d_ff', '?')}, "
          f"{meta.get('n_requests', '?')} reqs)\n")
    print("| cell | tok/s | vs bf16 | MFU | MBU | weight MB | "
          "golden pin | token match |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["cell"] == "summary":
            continue
        pin = "-" if "golden_match" not in r else str(r["golden_match"])
        tm = ("-" if "token_match_frac" not in r else
              f"{r['token_match_frac']:.2f} >= {r['token_match_floor']}")
        print(f"| {r['cell']} | {r['tok_per_s']:.0f} "
              f"| {r['speedup_vs_bf16']:.2f}x | {r['mfu']:.1e} "
              f"| {r['mbu']:.1e} | {r['weight_bytes'] / 1e6:.1f} "
              f"| {pin} | {tm} |")
    for r in rows:
        if r["cell"] == "summary" and "speedup_int8_vs_bf16" in r:
            print(f"\n{r['arch']}: int8 paged K={r['k']} is "
                  f"{r['speedup_int8_vs_bf16']:.2f}x the bf16 cell "
                  f"(criterion >= {r['min_speedup']}x: "
                  f"{'met' if r['meets_criterion'] else 'NOT met'}, "
                  f"goldens_ok={r['goldens_ok']})")


def experiments_tables(paths) -> None:
    """Markdown summaries of replication-runner JSON result files."""
    from repro.experiments.results import (load_results, markdown_table,
                                           summarize_rows)
    for path in paths:
        try:
            rows, meta = load_results(path)
        except FileNotFoundError:
            print(f"\n### §Experiments — {path}: missing, skipped\n")
            continue
        section = meta.get("section", path)
        scen = meta.get("scenario") or meta.get("scenarios", "?")
        keys = ["scenario", "strategy", "rate_multiplier"]
        if any(r.get("kappa") is not None for r in rows):
            keys.append("kappa")   # don't collapse ablation sweeps
        print(f"\n### §Experiments — {section} "
              f"({len(rows)} trials, scenario={scen})\n")
        print(markdown_table(summarize_rows(rows, keys=keys), keys=keys))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="dryrun_results.jsonl")
    ap.add_argument("--roofline", default="roofline_results.jsonl")
    ap.add_argument("--experiments", nargs="*", default=[],
                    help="replication-runner JSON files to summarize")
    ap.add_argument("--engine", default=None,
                    help="benchmarks.engine_bench JSON to summarize "
                         "(e.g. bench_engine.json)")
    ap.add_argument("--goodput", default=None,
                    help="benchmarks.goodput_bench JSON to summarize "
                         "(e.g. bench_goodput.json)")
    ap.add_argument("--spec", default=None,
                    help="benchmarks.spec_bench JSON to summarize "
                         "(e.g. bench_spec.json)")
    ap.add_argument("--quant", default=None,
                    help="benchmarks.quant_bench JSON to summarize "
                         "(e.g. bench_quant.json)")
    args = ap.parse_args()

    if args.experiments:
        experiments_tables(args.experiments)
    if args.engine:
        engine_table(args.engine)
    if args.goodput:
        goodput_table(args.goodput)
    if args.spec:
        spec_table(args.spec)
    if args.quant:
        quant_table(args.quant)
    if (args.engine or args.goodput or args.spec or args.quant) \
            and not args.experiments:
        return

    dry = load(args.dryrun)
    roof = load(args.roofline)

    print("### §Dry-run (full models, scan-stacked, both meshes)\n")
    print("| arch | shape | mesh | status | HLO flops/dev | HBM B/dev | "
          "coll B/dev | peak GB/dev | compile s |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in dry:
        if r.get("status") == "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                  f"{r['flops']:.2e} | {r['hbm_bytes']:.2e} | "
                  f"{r['collective_bytes']:.2e} | "
                  f"{r['peak_bytes']/1e9:.1f} | {r['compile_s']} |")
        else:
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{r['status']} | | | | | |")

    print("\n### §Roofline (unit-extrapolated audit, single-pod 16x16)\n")
    print("| arch | shape | t_compute s | t_mem(raw) s | t_mem(kernel) s | "
          "t_coll s | dominant(kernel) | MFU(kernel) | MBU(kernel) | "
          "MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in roof:
        if r.get("status") != "ok":
            print(f"| {r['arch']} | {r['shape']} | | | | | {r['status']} "
                  f"| | | |")
            continue
        km = kernel_model_bytes(r["arch"], r["shape"])
        t_mk = km / HBM_BW
        terms = {"compute": r["t_compute_s"], "memory": t_mk,
                 "collective": r["t_collective_s"]}
        dom = max(terms, key=terms.get)
        # distance-to-roof under the *kernel-model* memory column: the
        # fraction of a roofline-optimal step each pipe is busy
        t_step = max(terms.values())
        mfu_k = terms["compute"] / t_step if t_step else 0.0
        mbu_k = terms["memory"] / t_step if t_step else 0.0
        print(f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
              f"{r['t_memory_s']:.2e} | {t_mk:.2e} | "
              f"{r['t_collective_s']:.2e} | {dom} | "
              f"{mfu_k:.3f} | {mbu_k:.3f} | "
              f"{r['useful_ratio']:.3f} |")


if __name__ == "__main__":
    main()
