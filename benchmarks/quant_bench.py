"""Weight-only quantization bench: the bytes-per-weight race at the
decode roofline (SERVING.md §Quantization).

Cells: the paged engine at K=16 replays the same deterministic
decode-dominant trace with weights in

* ``bf16`` — the production dtype, quantization off (the baseline the
  committed criterion is measured against),
* ``f32``  — transparency cell: XLA *emulates* bf16 on this CPU host
  (per-op upcast), so the bf16 walltime is pessimistic relative to TPU;
  the f32 row shows the native-dtype dense speed for calibration,
* ``int8`` / ``int4`` — packed weight-only formats via
  ``quantization=`` (models/quantize.py).

The cells run a widened variant of the smoke config (d_model x d_ff
large enough that weight streaming dominates a decode step — the
regime quantization targets; at smoke dims the step is overhead-bound
and no format can win).  Each row reports tokens/s, MFU and MBU
(nominal v5e distance-to-roof per `launch.hlo_analysis` — note the
quantized cells' weight_bytes shrink, so equal tokens/s costs less
MBU), and the speedup vs the bf16 cell.

Golden gates ride along at the committed harness geometry (the *plain*
smoke config — the goldens' recipe):

* quantization off must reproduce ``tests/golden_decode.json``
  byte-identically,
* each quantized format must reproduce its own
  ``tests/golden_decode_quant.json`` stream exactly AND clear the
  absolute-token-match floor vs the dense golden
  (``quantize.golden_token_match_floor``; policy in SERVING.md).

Committed baseline: ``make quant-bench`` -> bench_quant.json; the CI
smoke chain (`benchmarks.run --quick`) writes a CI-sized cell to the
scratch bench_quant_quick.json instead.  Criterion: int8 paged K=16
>= 1.4x the bf16 cell's tokens/s.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib

from benchmarks.engine_bench import drive, make_engine
from benchmarks.paged_bench import build_trace
from repro.configs import get_smoke_config
from repro.experiments.results import save_results
from repro.models import quantize
from repro.serving import Request, ServingEngine

K = 16
MIN_SPEEDUP = 1.4
FMTS = ("bf16", "f32", "int8", "int4")
PROMPTS = [[5, 6, 7, 2, 9, 3, 8, 1], [9, 10, 4], [11, 3, 5, 7, 2]]
_TESTS = pathlib.Path(__file__).resolve().parent.parent / "tests"


def bench_config(arch: str, d_model: int, d_ff: int,
                 dtype: str = "bfloat16"):
    """Widen the smoke config until weight streaming dominates a decode
    step (head_dim stays modest: the MLP is the byte budget)."""
    cfg = get_smoke_config(arch)
    return dataclasses.replace(cfg, d_model=d_model, d_ff=d_ff,
                               head_dim=64, dtype=dtype)


def _golden_outputs(cfg, quantization=None):
    eng = ServingEngine(cfg, max_batch=3, cache_len=32, prefill_chunk=4,
                        quantization=quantization)
    for i, p in enumerate(PROMPTS):
        eng.submit(Request(id=i, prompt=list(p), max_new_tokens=5))
    return {int(r.id): list(map(int, r.out_tokens)) for r in eng.run()}


def golden_gates(arch: str, fmts) -> dict:
    """Run the committed golden recipe per format; returns the gate
    fields merged into the bench rows."""
    cfg = get_smoke_config(arch)
    dense_golden = {int(i): t for i, t in json.loads(
        (_TESTS / "golden_decode.json").read_text())[arch].items()}
    quant_golden = json.loads(
        (_TESTS / "golden_decode_quant.json").read_text()).get(arch, {})
    gates = {}
    for fmt in fmts:
        if fmt == "f32":
            continue   # speed-transparency cell only, no golden claim
        if fmt == "bf16":
            outs = _golden_outputs(cfg, quantization=None)
            gates[fmt] = {"golden_match": outs == dense_golden}
            continue
        outs = _golden_outputs(cfg, quantization=fmt)
        pinned = {int(i): t for i, t in quant_golden[fmt].items()}
        match = tot = 0
        for i, toks in outs.items():
            for a, b in zip(toks, dense_golden[i]):
                tot += 1
                match += int(a == b)
        floor = quantize.golden_token_match_floor(arch, fmt)
        gates[fmt] = {
            "golden_match": outs == pinned,
            "token_match_frac": match / tot,
            "token_match_floor": floor,
            "token_match_ok": match / tot >= floor,
        }
    return gates


def main(arch: str = "smollm-360m", d_model: int = 1024, d_ff: int = 4096,
         fmts: str = ",".join(FMTS), scenario: str = "bursty_mmpp",
         n_requests: int = 6, cache_len: int = 64, new_lo: int = 24,
         new_hi: int = 33, reps: int = 2, seed: int = 0,
         out: str | None = None):
    fmt_list = [f.strip() for f in str(fmts).split(",")]
    trace = build_trace(scenario, seed, n_requests, cache_len,
                        short_frac=1.0, new_lo=new_lo, new_hi=new_hi)
    geom = dict(max_batch=2, cache_len=cache_len, max_rows=2,
                block_size=16, num_blocks=2 * cache_len // 16,
                prefill_chunk=8)
    gates = golden_gates(arch, fmt_list)
    print(f"\n== quant bench: {arch} paged K={K}, "
          f"{d_model}x{d_ff}, {n_requests} reqs ==")
    print(f"{'cell':>6s} {'tok/s':>8s} {'vs bf16':>8s} {'mfu':>8s} "
          f"{'mbu':>8s} {'weightMB':>9s} {'golden':>7s}")
    rows, base = [], None
    for fmt in fmt_list:
        dtype = "float32" if fmt == "f32" else "bfloat16"
        q = fmt if fmt in ("int8", "int4") else None
        cfg = bench_config(arch, d_model, d_ff, dtype=dtype)
        eng = make_engine("paged", cfg, K, **geom, quantization=q)
        r = drive(eng, trace, K, geom["prefill_chunk"], reps=reps)
        r.pop("outputs")
        if fmt == "bf16":
            base = r["tok_per_s"]
        r.update({"arch": arch, "cell": fmt, "k": K, "quantization": q,
                  "speedup_vs_bf16": r["tok_per_s"] / base if base else 0.0,
                  **gates.get(fmt, {})})
        gstr = ("-" if fmt == "f32"
                else str(r["golden_match"]
                         and r.get("token_match_ok", True)))
        print(f"{fmt:>6s} {r['tok_per_s']:8.1f} "
              f"{r['speedup_vs_bf16']:7.2f}x {r['mfu']:8.1e} "
              f"{r['mbu']:8.1e} {r['weight_bytes'] / 1e6:9.2f} "
              f"{gstr:>7s}")
        rows.append(r)
    by = {r["cell"]: r for r in rows}
    summary = {"arch": arch, "cell": "summary", "k": K,
               "min_speedup": MIN_SPEEDUP}
    if "int8" in by and "bf16" in by:
        sp = by["int8"]["speedup_vs_bf16"]
        goldens_ok = all(
            r["golden_match"] and r.get("token_match_ok", True)
            for r in rows if "golden_match" in r)
        summary.update(
            speedup_int8_vs_bf16=sp,
            meets_criterion=sp >= MIN_SPEEDUP and goldens_ok,
            goldens_ok=goldens_ok)
        print(f"\nint8 paged K={K} is {sp:.2f}x the bf16 cell "
              f"(criterion >= {MIN_SPEEDUP}x: "
              f"{'met' if sp >= MIN_SPEEDUP else 'NOT met'}); "
              f"golden gates {'pass' if goldens_ok else 'FAIL'}")
    rows.append(summary)
    if out:
        save_results(out, rows, meta={
            "section": "quant_bench", "arch": arch, "k": K,
            "d_model": d_model, "d_ff": d_ff, "scenario": scenario,
            "n_requests": n_requests, "cache_len": cache_len,
            "new_lo": new_lo, "new_hi": new_hi, "reps": reps,
            "seed": seed, "fmts": fmts,
            "note": "tok_per_s is host-dependent; XLA emulates bf16 on "
                    "CPU (see the f32 transparency cell) — on TPU the "
                    "bf16 baseline is the fast dense path and the "
                    "quant win is the bytes term (MBU column). Golden "
                    "gate fields are deterministic."})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--d-model", type=int, default=1024)
    ap.add_argument("--d-ff", type=int, default=4096)
    ap.add_argument("--fmts", default=",".join(FMTS))
    ap.add_argument("--scenario", default="bursty_mmpp")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized: narrower model, fewer requests, "
                         "bf16+int8 cells only")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    kw = dict(arch=args.arch, d_model=args.d_model, d_ff=args.d_ff,
              fmts=args.fmts, scenario=args.scenario,
              n_requests=args.requests, cache_len=args.cache_len,
              reps=args.reps, seed=args.seed, out=args.out)
    if args.quick:
        kw.update(d_model=512, d_ff=2048, fmts="bf16,int8",
                  n_requests=4, reps=1)
    main(**kw)
