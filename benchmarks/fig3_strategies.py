"""Fig. 3: violin-style distribution of on-time completion rate and total
system cost across the four deployment strategies.

Output: one CSV row per (strategy, trial) + a distribution summary that
maps onto the paper's violins (mean / p10 / p50 / p90 / std).
Paper claims validated here:
  * proposal: compact distribution, on-time > 84%
  * LBRR: low-cost / low-performance regime
  * GA: widely distributed both metrics
  * PropAvg: slightly cheaper, broader + lower tail on completion
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core.experiment import run_trial, summarize


def main(n_trials: int = 12, horizon: int = 80, out: str | None = None,
         strategies=None):
    rows = []
    for seed in range(n_trials):
        rows += run_trial(seed, strategy_names=strategies,
                          horizon_slots=horizon)
        print(f"# trial {seed + 1}/{n_trials} done", flush=True)
    print("strategy,seed,on_time,completed,total_cost,p95_latency_ms")
    for r in rows:
        print(f"{r['strategy']},{r['seed']},{r['on_time']:.4f},"
              f"{r['completed']:.4f},{r['total_cost']:.1f},"
              f"{r['p95_latency_ms']:.2f}")
    print("\n# distribution summary (the violins)")
    print("strategy,on_time_mean,on_time_p10,on_time_p50,on_time_p90,"
          "on_time_std,cost_mean,cost_std")
    summ = summarize(rows)
    for k, v in summ.items():
        ot = np.array([r["on_time"] for r in rows if r["strategy"] == k])
        print(f"{k},{v['on_time_mean']:.4f},{v['on_time_p10']:.4f},"
              f"{np.median(ot):.4f},{v['on_time_p90']:.4f},"
              f"{v['on_time_std']:.4f},{v['cost_mean']:.1f},"
              f"{v['cost_std']:.1f}")
    if out:
        with open(out, "w") as f:
            json.dump(rows, f)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=12)
    ap.add_argument("--horizon", type=int, default=80)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    main(args.trials, args.horizon, args.out)
