"""Fig. 3: violin-style distribution of on-time completion rate and total
system cost across the four deployment strategies.

Trials fan out across processes via the replication runner
(`repro.experiments.runner`); pass `--scenario` to evaluate under any
registered workload/environment dynamics (EXPERIMENTS.md).

Output: one CSV row per (strategy, trial) + a distribution summary that
maps onto the paper's violins (mean / p10 / p50 / p90 / std), plus the
versioned JSON results file when `--out` is given.
Paper claims validated here:
  * proposal: compact distribution, on-time > 84%
  * LBRR: low-cost / low-performance regime
  * GA: widely distributed both metrics
  * PropAvg: slightly cheaper, broader + lower tail on completion
"""
from __future__ import annotations

import argparse

from repro.experiments.results import save_results, summarize_rows
from repro.experiments.runner import make_grid, run_grid


def main(n_trials: int = 12, horizon: int = 80, out: str | None = None,
         strategies=None, scenario: str = "baseline",
         n_workers: int | None = None,
         bytes_per_param: float | None = None):
    specs = make_grid(seeds=range(n_trials), strategies=strategies,
                      scenarios=(scenario,), horizon_slots=horizon,
                      bytes_per_param=bytes_per_param)
    rows = run_grid(specs, n_workers=n_workers, progress=True)
    print("scenario,strategy,seed,on_time,completed,total_cost,"
          "p95_latency_ms")
    for r in rows:
        print(f"{r['scenario']},{r['strategy']},{r['seed']},"
              f"{r['on_time']:.4f},{r['completed']:.4f},"
              f"{r['total_cost']:.1f},{r['p95_latency_ms']:.2f}")
    print("\n# distribution summary (the violins)")
    print("strategy,on_time_mean,on_time_p10,on_time_p50,on_time_p90,"
          "on_time_std,cost_mean,cost_std")
    for s in summarize_rows(rows, keys=("strategy",)):
        print(f"{s['strategy']},{s['on_time_mean']:.4f},"
              f"{s['on_time_p10']:.4f},{s['on_time_p50']:.4f},"
              f"{s['on_time_p90']:.4f},{s['on_time_std']:.4f},"
              f"{s['cost_mean']:.1f},{s['cost_std']:.1f}")
    if out:
        save_results(out, rows, meta={"section": "fig3",
                                      "scenario": scenario,
                                      "n_trials": n_trials,
                                      "horizon_slots": horizon,
                                      "bytes_per_param": bytes_per_param})
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=12)
    ap.add_argument("--horizon", type=int, default=80)
    ap.add_argument("--out", default=None)
    ap.add_argument("--scenario", default="baseline")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--bytes-per-param", type=float, default=None,
                    help="weight bytes/param for core-service memory "
                         "demand (2.0 bf16 baseline, 1.0 int8, 0.5 "
                         "int4 — SERVING.md §Quantization)")
    args = ap.parse_args()
    main(args.trials, args.horizon, args.out, scenario=args.scenario,
         n_workers=args.workers, bytes_per_param=args.bytes_per_param)
