import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis (EXPERIMENTS.md §Roofline).

`cost_analysis()` counts lax.scan bodies ONCE (calibrated in
EXPERIMENTS.md §Dry-run), so lowering the full stacked-layer model
undercounts FLOPs by ~n_layers.  We instead compile small *audit* models
with layers unrolled (Python loop) and extrapolate exactly:

  pattern has cyclic period p, n_layers = units*p + remainder
  cost(total) = cost(unit) + (units-1) * [cost(2*unit) - cost(unit)]
                + sum_{k in remainder} [cost(unit + k) - cost(unit)]

This is exact for per-layer-additive quantities (flops, bytes, collective
bytes) because each audit compile shares the mesh/shardings of the real
model; embed/head/encoder costs live in cost(unit) and cancel in the
differences.

Mamba time-scan correction: the recurrence inside a mamba block is a
lax.scan over T which the audit cannot unroll (T up to 512k).  We add the
kernel-model analytic terms (the deployable Pallas path keeps state in
VMEM):  flops += 8*B*T*di*ds,  hbm += B*T*(3*di+2*ds)*2.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402

from repro.config import SHAPES  # noqa: E402
from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch.hlo_analysis import (  # noqa: E402
    HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16, collective_stats,
    roofline_terms)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_step  # noqa: E402
from repro.sharding.specs import use_mesh_rules  # noqa: E402


def pattern_period(pattern) -> int:
    n = len(pattern)
    for p in range(1, n + 1):
        if all(pattern[i] == pattern[i % p] for i in range(n)):
            return p
    return n


def _audit_cfg(cfg, pattern):
    return dataclasses.replace(cfg, n_layers=len(pattern),
                               block_pattern=tuple(pattern))


def _measure(cfg, shape, mesh, layout="heads") -> dict:
    from repro.launch import steps as steps_mod
    from repro.models import model as model_mod
    # build with unrolled layers so every block's FLOPs are counted
    orig = model_mod.build_model

    def build_unrolled(c, unroll=False):
        return orig(c, unroll=True)

    steps_mod.build_model = build_unrolled
    try:
        fn, args = make_step(cfg, shape, mesh, decode_cache_layout=layout)
        lowered = jax.jit(fn).lower(*args)
        compiled = lowered.compile()
    finally:
        steps_mod.build_model = orig
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "hbm": sum(float(v) for k, v in cost.items()
                   if k.startswith("bytes accessed")),
        "coll": float(coll.total_bytes),
    }


def _mamba_correction(cfg, shape, mesh) -> dict:
    """Analytic kernel-model terms for the in-block time recurrence."""
    n_mamba = sum(1 for b in cfg.block_pattern if b.startswith("mamba"))
    if n_mamba == 0:
        return {"flops": 0.0, "hbm": 0.0, "coll": 0.0}
    di, ds = cfg.d_inner_eff, cfg.ssm_state
    t = 1 if shape.is_decode else shape.seq_len
    # per-device batch (batch shards over data(+pod) axes)
    bsh = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            bsh *= mesh.shape[ax]
    b_local = max(1, shape.global_batch // bsh)
    di_local = di // mesh.shape.get("model", 1) if (
        di % mesh.shape.get("model", 1) == 0) else di
    flops = 8.0 * b_local * t * di_local * ds * n_mamba
    hbm = b_local * t * (3 * di_local + 2 * ds) * 2.0 * n_mamba
    return {"flops": flops, "hbm": hbm, "coll": 0.0}


def audit(arch: str, shape_name: str, layout: str = "heads",
          multi_pod: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "layout": layout,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not cfg.supports_shape(shape):
        rec["status"] = "skipped"
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    pattern = list(cfg.block_pattern)
    p = pattern_period(pattern)
    units = cfg.n_layers // p
    rem = pattern[units * p:]

    with mesh, use_mesh_rules(mesh):
        c_u = _measure(_audit_cfg(cfg, pattern[:p]), shape, mesh, layout)
        if units > 1 or rem:
            c_2u = _measure(_audit_cfg(cfg, pattern[:p] * 2), shape, mesh,
                            layout)
        else:
            c_2u = c_u
        rem_costs = []
        for k in rem:
            c_k = _measure(_audit_cfg(cfg, pattern[:p] + [k]), shape, mesh,
                           layout)
            rem_costs.append({x: c_k[x] - c_u[x] for x in c_u})

    unit_delta = {x: c_2u[x] - c_u[x] for x in c_u}
    total = {x: c_u[x] + (units - 1) * unit_delta[x]
             + sum(rc[x] for rc in rem_costs) for x in c_u}
    corr = _mamba_correction(cfg, shape, mesh)
    total = {x: total[x] + corr[x] for x in total}

    terms = roofline_terms(total["flops"], total["hbm"], total["coll"],
                           mesh.devices.size)
    # analytic distance-to-roof at the roofline-optimal step time: the
    # fraction of a max(terms) step each pipe is busy.  mfu==1 means
    # compute-bound (the roof), mbu==1 memory-bound; both shrink as the
    # third term dominates.
    t_step = max(terms["t_compute_s"], terms["t_memory_s"],
                 terms["t_collective_s"])
    terms["mfu"] = terms["t_compute_s"] / t_step if t_step else 0.0
    terms["mbu"] = terms["t_memory_s"] / t_step if t_step else 0.0
    # MODEL_FLOPS: useful per-device flops
    tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    n_active = cfg.num_active_params()
    factor = 6 if shape.kind == "train" else 2
    model_flops_global = factor * n_active * tokens
    model_flops_dev = model_flops_global / mesh.devices.size
    rec.update(terms)
    rec.update({
        "status": "ok",
        "flops": total["flops"],
        "hbm_bytes": total["hbm"],
        "collective_bytes": total["coll"],
        "model_flops_per_dev": model_flops_dev,
        "useful_ratio": model_flops_dev / total["flops"]
        if total["flops"] else 0.0,
        "period": p,
        "units": units,
    })
    return rec


BOTTLENECK_HINT = {
    "compute": "more chips or lower-precision matmuls; check remat ratio",
    "memory": "fuse/kernelize the dominant bandwidth op (attention/scan) "
              "or shard the biggest resident tensor further",
    "collective": "reshard to cut the dominant collective, overlap it "
                  "with compute, or move it to a faster axis",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--layout", default="heads")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    print("arch,shape,layout,status,t_compute_s,t_memory_s,t_collective_s,"
          "dominant,mfu,mbu,useful_ratio,hint")
    for a in archs:
        for s in shapes:
            r = audit(a, s, layout=args.layout)
            if r.get("status") != "ok":
                print(f"{a},{s},{args.layout},{r.get('status')},,,,,,,,")
                continue
            print(f"{a},{s},{args.layout},ok,{r['t_compute_s']:.3e},"
                  f"{r['t_memory_s']:.3e},{r['t_collective_s']:.3e},"
                  f"{r['dominant']},{r['mfu']:.3f},{r['mbu']:.3f},"
                  f"{r['useful_ratio']:.3f},"
                  f"\"{BOTTLENECK_HINT[r['dominant']]}\"", flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
